#!/usr/bin/env python
"""Determinism lint runner (the CI ``simlint`` gate).

  python -m tools.simlint src/repro          # exit 1 on any finding
  python -m tools.simlint --json src/repro   # machine-readable report

Thin wrapper around :mod:`repro.analysis.lint` so the gate runs from a
repo checkout without installing the package; see docs/determinism.md
for the SIMxxx rule catalog and suppression syntax.
"""
from __future__ import annotations

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.analysis.lint import main  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(main())
