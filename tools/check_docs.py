#!/usr/bin/env python
"""Docs consistency checker (the CI ``docs`` job).

Two checks, so the docs cannot drift from the code:

  * every intra-repo markdown link in ``README.md`` and ``docs/*.md``
    resolves to an existing file (anchors stripped; external schemes
    skipped);
  * the README strategy table between the ``strategy-table`` markers
    matches what the live strategy registry generates
    (``repro.core.registry_entries``) — run with ``--write`` to update
    it after registering or re-documenting a strategy.

  python tools/check_docs.py [--write]
"""
from __future__ import annotations

import argparse
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

TABLE_BEGIN = "<!-- strategy-table:begin -->"
TABLE_END = "<!-- strategy-table:end -->"
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")


def doc_files():
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        files += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                        if f.endswith(".md"))
    return files


def check_links() -> list:
    errors = []
    for path in doc_files():
        with open(path) as f:
            text = f.read()
        for target in LINK_RE.findall(text):
            if target.startswith(SKIP_SCHEMES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), rel))
            if not os.path.exists(resolved):
                errors.append(f"{os.path.relpath(path, REPO)}: "
                              f"broken link -> {target}")
    return errors


def strategy_table() -> str:
    """The canonical README strategy table, generated from the registry."""
    from repro.core import registry_entries

    lines = [
        "| strategy | flags | summary |",
        "|---|---|---|",
    ]
    for row in registry_entries():
        flags = ", ".join(
            f for f, on in (("cutoff", row["wants_cutoff"]),
                            ("identity", row["handles_identity"])) if on)
        lines.append(f"| `{row['name']}` | {flags or '—'} "
                     f"| {row['summary']} |")
    return "\n".join(lines)


def check_table(write: bool) -> list:
    readme = os.path.join(REPO, "README.md")
    with open(readme) as f:
        text = f.read()
    if TABLE_BEGIN not in text or TABLE_END not in text:
        return [f"README.md: missing {TABLE_BEGIN} / {TABLE_END} markers"]
    head, rest = text.split(TABLE_BEGIN, 1)
    current, tail = rest.split(TABLE_END, 1)
    want = "\n" + strategy_table() + "\n"
    if current == want:
        return []
    if write:
        with open(readme, "w") as f:
            f.write(head + TABLE_BEGIN + want + TABLE_END + tail)
        print("README.md strategy table regenerated")
        return []
    return ["README.md: strategy table is stale vs the live registry "
            "(run: python tools/check_docs.py --write)"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true",
                    help="rewrite the README strategy table in place")
    args = ap.parse_args(argv)

    errors = check_links() + check_table(write=args.write)
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if not errors:
        print(f"docs OK ({len(doc_files())} files checked)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
