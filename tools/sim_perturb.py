#!/usr/bin/env python
"""Schedule-perturbation runner (the CI ``sim-perturb`` job).

  python -m tools.sim_perturb               # 5 seeds, both sweeps
  python -m tools.sim_perturb --seeds 3 --skip-chaos --json

Thin wrapper around :mod:`repro.analysis.perturb`; see
docs/determinism.md for what a divergence means and how to debug one.
"""
from __future__ import annotations

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)  # benchmarks.chaos for the chaos sweep
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.analysis.perturb import main  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(main())
