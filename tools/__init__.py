# Makes the repo-level tools runnable as modules (python -m tools.simlint).
