"""Quickstart: train a small model, serve it, then live-migrate the serving
replica with MS2M — the paper's pipeline end-to-end in one script.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import make_jax_worker_factory, run_migration_experiment
from repro.data import DataConfig, SyntheticTokenDataset
from repro.models import transformer as T
from repro.models.common import split_params
from repro.optim import adamw
from repro.train import step as steplib


def main():
    # --- 1. train a tiny LM for a few steps --------------------------------
    cfg = configs.get_smoke("smollm_360m")
    tcfg = steplib.TrainStepConfig(remat="none", lr_peak=3e-3,
                                   warmup_steps=5, total_steps=30)
    params, _ = split_params(T.init_lm(jax.random.PRNGKey(0), cfg))
    opt = adamw.adamw_init(params, tcfg.opt)
    ds = SyntheticTokenDataset(DataConfig(cfg.vocab_size, 64, 8))
    step_fn = jax.jit(steplib.build_train_step(cfg, tcfg),
                      donate_argnums=(0, 1))
    for s in range(30):
        batch = jax.tree.map(jnp.asarray, ds.batch(s))
        params, opt, m = step_fn(params, opt, batch, jnp.asarray(s, jnp.int32))
        if s % 10 == 0:
            print(f"[quickstart] train step {s}: loss {float(m['loss']):.3f}")

    # --- 2. serve: prefill + a few decode steps ----------------------------
    cache = T.init_cache(cfg, 2, 64)
    prompt = {"tokens": jnp.asarray(ds.batch(99)["tokens"][:2, :16])}
    logits, cache = T.lm_prefill(params, prompt, cfg, cache)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for i in range(8):
        logits, cache = T.lm_decode_step(
            params, tok, jnp.full((2, 1), 16 + i, jnp.int32), cfg, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    print(f"[quickstart] served 8 decode steps; sample token {int(tok[0,0])}")

    # --- 3. live-migrate a stateful serving replica (MS2M) -----------------
    make_worker, _ = make_jax_worker_factory(max_seq=512)
    with tempfile.TemporaryDirectory() as reg:
        r = run_migration_experiment(
            "ms2m_individual", message_rate=6.0, registry_root=reg,
            worker_factory=make_worker, seed=0)
    print(f"[quickstart] MS2M migration: migration_time={r.migration_time:.2f}s"
          f" downtime={r.downtime:.2f}s (stop-and-copy would be ~49s)")
    print(f"[quickstart] migrated state verified bit-exact: {r.verified}")


if __name__ == "__main__":
    main()
