"""Live migration of a continuous-batching SERVING ENGINE.

The engine (slot KV caches + slot table) is itself an MS2M worker: its
message log is the admitted request stream.  We serve traffic, migrate the
whole engine with MS2M-individual, and verify the migrated engine equals an
uninterrupted reference fold.

  PYTHONPATH=src python examples/serving_engine_migration.py
"""
import tempfile

import jax

from repro import configs
from repro.core import run_migration_experiment
from repro.models import transformer as T
from repro.serving import ServingEngine


def main():
    cfg = configs.get_smoke("paper_consumer")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)

    def make_engine():
        return ServingEngine(cfg, params, num_slots=2, max_seq=128)

    with tempfile.TemporaryDirectory() as reg:
        r = run_migration_experiment(
            "ms2m_individual", message_rate=3.0, registry_root=reg,
            worker_factory=make_engine, seed=0, processing_ms=120.0,
            t_migrate=6.0, settle_time=3.0)
    print(f"[demo] engine migration: migration_time={r.migration_time:.2f}s "
          f"downtime={r.downtime:.2f}s")
    print(f"[demo] requests served by target engine: "
          f"{r.processed_by_target}")
    print(f"[demo] migrated engine state verified: {r.verified}")
    print(f"[demo] image: wrote {r.report.image_written_bytes/1e6:.2f}MB "
          f"(KV slots + slot table)")


if __name__ == "__main__":
    main()
