"""MS2M for a TRAINING worker with StatefulSet identity: the FT/elasticity
story.  A trainer holding (params, optimizer state) is live-migrated across
nodes via checkpoint image + batch-journal replay; a straggler detector
triggers the migration.

  PYTHONPATH=src python examples/statefulset_trainer_migration.py
"""
import tempfile

from repro import configs
from repro.cluster.cluster import Cluster
from repro.core.migration import MigrationManager
from repro.core.trainer_worker import TrainerWorker
from repro.data import DataConfig
from repro.optim import adamw
from repro.train import step as steplib


def main():
    cfg = configs.get_smoke("smollm_360m")
    tcfg = steplib.TrainStepConfig(
        remat="none", lr_peak=1e-3, warmup_steps=5, total_steps=10_000,
        opt=adamw.AdamWConfig(weight_decay=0.01))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)

    def make_worker():
        return TrainerWorker(cfg, tcfg, dcfg)

    with tempfile.TemporaryDirectory() as tmp:
        cluster = Cluster(tmp, num_nodes=3)
        sim, api, broker = cluster.sim, cluster.api, cluster.broker
        broker.declare_queue("batches")

        # producer: the data-dispatcher emits batch ids at 5/s
        def producer():
            i = 0
            while sim.now < 300.0:
                yield 0.2
                broker.publish("batches", {"batch_id": i})
                i += 1

        sim.process(producer())

        worker = make_worker()
        holder = {}

        def boot():
            pod = yield from api.create_pod(
                "trainer-0", "node0", worker, broker.queues["batches"],
                statefulset_identity="trainer-0")
            pod.start()
            holder["pod"] = pod

        sim.process(boot())
        sim.run(until=15.0)
        pod = holder["pod"]
        print(f"[demo] trainer at virtual t=15s: step={worker.step} "
              f"loss={worker.last_loss:.3f}")

        # straggler detector: pretend node0 degraded -> live-migrate
        print("[demo] straggler detected on node0 -> MS2M StatefulSet "
              "migration to node1")
        mgr = MigrationManager(api, make_worker, "batches")
        done = mgr.migrate("ms2m_statefulset", pod, "node1",
                           statefulset_identity="trainer-0")
        sim.run(stop_when=done)
        report, target = done.value
        sim.run(until=sim.now + 10.0)
        print(f"[demo] migration done: migration_time="
              f"{report.migration_time:.2f}s downtime={report.downtime:.2f}s")
        print(f"[demo] target trainer resumed: step={target.worker.step} "
              f"loss={target.worker.last_loss:.3f}")
        print(f"[demo] image bytes written {report.image_written_bytes/1e6:.1f}MB"
              f" (deduped {report.image_deduped_bytes/1e6:.1f}MB)")

        # verification: fold all batch ids into a fresh trainer
        from repro.broker.broker import Message
        ref = make_worker()
        for i in range(target.worker.last_msg_id + 1):
            ref.process(Message(i, {"batch_id": i}, 0.0))
        print(f"[demo] replayed reference fold matches migrated trainer: "
              f"{ref.state_equal(target.worker)}")


if __name__ == "__main__":
    main()
