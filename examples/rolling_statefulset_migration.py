"""Rolling migration of a 3-replica StatefulSet with sticky-identity
handoff, driven by the ClusterMigrationOrchestrator.

Each replica owns a dedicated queue (paper §III-C); replicas are moved one
at a time with ms2m_statefulset + iterative delta pre-copy, so replica k+1
waits for replica k's target to hold its identity, and each stop phase
replays only the last pre-copy round's traffic.

  PYTHONPATH=src python examples/rolling_statefulset_migration.py
"""
import tempfile

from repro.cluster.cluster import Cluster
from repro.core import (
    ClusterMigrationOrchestrator,
    HashConsumer,
    MigrationPolicy,
    PodMigrationSpec,
)

N_REPLICAS = 3


def main():
    with tempfile.TemporaryDirectory() as reg:
        cluster = Cluster(reg, num_nodes=3)
        sim, api, broker = cluster.sim, cluster.api, cluster.broker
        stop = {"flag": False}
        sources = {}

        for i in range(N_REPLICAS):
            qname = f"orders-{i}"
            broker.declare_queue(qname)

            def producer(i=i, qname=qname):
                while not stop["flag"]:
                    yield 0.125  # 8 msg/s per replica
                    broker.publish(qname, {"token": (i * 131) % 997})

            sim.process(producer())

            def boot(i=i, qname=qname):
                pod = yield from api.create_pod(
                    f"consumer-{i}", f"node{i % 2}", HashConsumer(),
                    broker.queues[qname],
                    statefulset_identity=f"consumer-{i}")
                pod.start()
                sources[i] = pod

            sim.process(boot())

        sim.run(until=10.0)
        print(f"[rolling] {N_REPLICAS} replicas serving; identities:",
              dict(api.statefulsets.identities))

        orch = ClusterMigrationOrchestrator(
            api, HashConsumer, policy=MigrationPolicy(precopy=True))
        specs = [PodMigrationSpec(pod=sources[i], queue=f"orders-{i}",
                                  target_node="node2",
                                  identity=f"consumer-{i}")
                 for i in range(N_REPLICAS)]
        done = orch.rolling_statefulset(specs)
        sim.run(stop_when=done)
        fleet = done.value
        stop["flag"] = True
        sim.run(until=sim.now + 1.0)

        for rep, target in zip(fleet.reports, fleet.targets):
            print(f"[rolling] {target.name}: downtime={rep.downtime:.2f}s "
                  f"precopy_rounds={rep.precopy_rounds} "
                  f"replayed={rep.replayed_messages} "
                  f"span=({rep.t_start:.1f}..{rep.t_end:.1f})")
        print(f"[rolling] fleet: span={fleet.span:.1f}s "
              f"peak_concurrency={fleet.peak_concurrency} "
              f"(sequential handoff)")
        print("[rolling] identities after handoff:",
              dict(api.statefulsets.identities))


if __name__ == "__main__":
    main()
