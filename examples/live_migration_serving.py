"""Live migration of a serving replica under load — paper Figs. 6-7 as a
runnable demo with a REAL JAX consumer (KV-cache state), comparing all four
strategies and showing the beyond-paper batched-replay + registry-dedup
effects.

  PYTHONPATH=src python examples/live_migration_serving.py
"""
import tempfile

from repro.core import (
    make_jax_worker_factory,
    measure_replay_speedup,
    run_migration_experiment,
)


def main():
    make_worker, cfg = make_jax_worker_factory(max_seq=2048)
    worker = make_worker()  # builds + caches the params
    speedup = measure_replay_speedup(cfg, worker.params, n=128, max_seq=512)
    print(f"[demo] measured chunk-parallel replay speedup: {speedup:.1f}x")

    rate = 10.0
    print(f"[demo] message rate λ={rate}/s, μ=20/s (paper intermediate)")
    with tempfile.TemporaryDirectory() as tmp:
        for strategy in ("stop_and_copy", "ms2m_individual", "ms2m_cutoff",
                         "ms2m_statefulset"):
            r = run_migration_experiment(
                strategy, rate, registry_root=f"{tmp}/{strategy}",
                worker_factory=make_worker, seed=0)
            phases = ", ".join(f"{k}={v:.1f}s"
                               for k, v in r.report.phases.items())
            print(f"  {strategy:18s} migration={r.migration_time:7.2f}s "
                  f"downtime={r.downtime:6.2f}s verified={r.verified}")
            print(f"      phases: {phases}")
            print(f"      image: wrote {r.report.image_written_bytes/1e6:.1f}MB"
                  f" (deduped {r.report.image_deduped_bytes/1e6:.1f}MB)")

        # beyond-paper: batched replay at high rate
        print("[demo] beyond-paper batched replay at λ=16/s:")
        for label, batched in (("paper-faithful", False), ("batched", True)):
            r = run_migration_experiment(
                "ms2m_cutoff", 16.0, registry_root=f"{tmp}/b{batched}",
                worker_factory=make_worker, seed=0,
                batched_replay=batched, replay_speedup=speedup)
            print(f"  {label:15s} migration={r.migration_time:7.2f}s "
                  f"downtime={r.downtime:6.2f}s cutoff_fired="
                  f"{r.report.cutoff_fired} verified={r.verified}")


if __name__ == "__main__":
    main()
