"""Calibration of the virtual-clock infra constants against the paper.

The paper measures (GCE e2-medium, K8s v1.30, CRI-O + CRIU, Buildah,
Artifact Registry, RabbitMQ; §IV-A):
  * stop-and-copy total/downtime ~= 49.055 s, flat across message rates
    (Fig. 5); 47.077 s in the low-rate comparison (Fig. 9).
  * MS2M individual downtime ~= 1.547 s (96.846-97.178 % reduction).
  * StatefulSet downtime reductions 24.840 % / 16.309 % / 0.242 % at
    4/10/16 msg/s.
  * sub-process shares (Figs. 12-14): message replay grows to >80 % of
    migration time at 16 msg/s without the cutoff; 56.2 % with it;
    "service restoration" dominates the StatefulSet breakdown.

Our constants (cluster.TimingConstants defaults) distribute the 49 s
stop-and-copy budget over checkpoint(8) + build(11) + push(6+bytes/bw) +
create(3) + pull(5+bytes/bw) + restore(13) + delete(2) + switch(0.9)
= 48.9 s + transfer, and set the cutover window (coord 0.5 + switch 0.9)
~= 1.4-1.5 s to match the MS2M downtime.  T_replay_max defaults to 45 s,
reproducing the paper's cutoff behaviour: inactive at 4/s, marginal at
10/s, active at 16/s.

The per-message processing time is the paper's 50 ms (mu = 20 msg/s);
message rates are the paper's {4, 10, 16} plus a sweep grid.
"""
from repro.cluster.cluster import TimingConstants

PAPER_RATES = (4.0, 10.0, 16.0)
SWEEP_RATES = (2.0, 4.0, 6.0, 8.0, 10.0, 12.0, 14.0, 16.0, 18.0)
PROCESSING_MS = 50.0
MU = 1000.0 / PROCESSING_MS
T_REPLAY_MAX = 45.0
REPEATS = 10  # paper: each test case run 10 times

# paper-reported values used by claims.py validation bands
PAPER = {
    "stop_and_copy_total_s": 49.055,
    "stop_and_copy_low_s": 47.077,
    "ms2m_downtime_s": 1.547,
    "downtime_reduction_individual_low": 0.96986,
    "downtime_reduction_individual_mid": 0.97178,
    "downtime_reduction_cutoff_low": 0.96737,
    "downtime_reduction_cutoff_high": 0.36076,
    "downtime_reduction_sts_low": 0.24840,
    "downtime_reduction_sts_mid": 0.16309,
    "downtime_reduction_sts_high": 0.00242,
    "replay_share_high_no_cutoff": 0.803,
    "replay_share_high_with_cutoff": 0.562,
}


def default_timings() -> TimingConstants:
    return TimingConstants()
