"""Paper Figs. 12-14: distribution of migration latency across sub-processes
(checkpoint, image build+push, service restoration, message replay, cutover)
per strategy x message rate."""
from __future__ import annotations

import argparse
import json
import os

from benchmarks import constants as C
from benchmarks.migration_sweep import run_sweep

PHASES = ("checkpoint", "image_build_push", "identity_release",
          "service_restoration", "message_replay", "cutover",
          "source_teardown")


def run_breakdown(repeats=3, out_path=None):
    rows = run_sweep(("ms2m_individual", "ms2m_cutoff", "ms2m_statefulset"),
                     C.PAPER_RATES, repeats)
    out = []
    for r in rows:
        total = sum(r["phases_mean"].values()) or 1.0
        shares = {p: round(r["phases_mean"].get(p, 0.0) / total, 4)
                  for p in PHASES}
        out.append({
            "strategy": r["strategy"], "rate": r["rate"],
            "total_s": round(total, 3),
            "phase_seconds": r["phases_mean"],
            "phase_shares": shares,
        })
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            for row in out:
                f.write(json.dumps(row) + "\n")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=C.REPEATS)
    ap.add_argument("--out", default="results/phase_breakdown.json")
    args = ap.parse_args(argv)
    rows = run_breakdown(args.repeats, args.out)
    for r in rows:
        top = sorted(r["phase_shares"].items(), key=lambda kv: -kv[1])[:3]
        tops = ", ".join(f"{k}={v*100:.1f}%" for k, v in top)
        print(f"{r['strategy']:18s} rate={r['rate']:4.1f} total={r['total_s']:7.2f}s  {tops}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
