"""Shared latency/percentile helpers for benchmark report rows.

Thin re-export of :mod:`repro.analysis.stats` — the implementation lives
in ``src`` so the operator CLI (which runs with ``PYTHONPATH=src`` only)
can use the same deterministic percentile math as the benchmarks; the
report rows never depend on numpy's version-specific quantile methods.
"""
from repro.analysis.stats import (  # noqa: F401
    LATENCY_PERCENTILES,
    latency_summary,
    percentile,
    percentiles,
    summarize_spans,
)
