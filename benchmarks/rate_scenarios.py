"""Paper Figs. 9-11: strategy comparison at low(4)/intermediate(10)/high(16)
message rates, reported as downtime/migration-time deltas vs stop-and-copy
(the paper's headline percentages)."""
from __future__ import annotations

import argparse
import json
import os
import statistics
import tempfile

from benchmarks import constants as C
from benchmarks.migration_sweep import STRATEGIES, run_sweep


def run_scenarios(repeats=3, use_jax_consumer=False, out_path=None,
                  batched_replay=False, replay_speedup=1.0):
    rows = run_sweep(STRATEGIES, C.PAPER_RATES, repeats,
                     use_jax_consumer=use_jax_consumer,
                     batched_replay=batched_replay,
                     replay_speedup=replay_speedup)
    base = {r["rate"]: r for r in rows if r["strategy"] == "stop_and_copy"}
    out = []
    for r in rows:
        b = base[r["rate"]]
        out.append({
            **r,
            "downtime_reduction_vs_sac":
                round(1 - r["downtime_mean"] / b["downtime_mean"], 5),
            "migration_increase_vs_sac":
                round(r["migration_time_mean"] / b["migration_time_mean"] - 1, 5),
        })
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            for row in out:
                f.write(json.dumps(row) + "\n")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=C.REPEATS)
    ap.add_argument("--jax-consumer", action="store_true")
    ap.add_argument("--out", default="results/rate_scenarios.json")
    args = ap.parse_args(argv)
    rows = run_scenarios(args.repeats, args.jax_consumer, args.out)
    print(f"{'strategy':18s} {'rate':>5s} {'down(s)':>8s} {'Δdown':>8s} {'Δmig':>8s}")
    for r in rows:
        print(f"{r['strategy']:18s} {r['rate']:5.1f} {r['downtime_mean']:8.2f} "
              f"{r['downtime_reduction_vs_sac']*100:7.2f}% "
              f"{r['migration_increase_vs_sac']*100:7.2f}%")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
