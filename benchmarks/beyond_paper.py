"""Beyond-paper optimizations, each measured against the paper-faithful
baseline (EXPERIMENTS.md §Perf records both separately):

  1. batched (chunk-parallel) replay — lm_append folds k messages per
     compiled call; the *measured* speedup rescales the replay service rate
     and Eq. 5's threshold (cutoff.batched_cutoff_threshold).  Collapses
     the high-rate regime where paper-MS2M degrades.
  2. content-addressed image dedup — after the first migration, the weight
     chunks are already in the registry; subsequent pushes upload only the
     KV-cache delta (the paper re-pushes full images each time; cf. Ma et
     al. [12] layered-storage motivation).
  3. parallel target provisioning — pod creation overlaps image build+push
     (the paper's Fig. 2 sequence is strictly serial).  [modeled via the
     timing constants; reported as a what-if delta]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

from benchmarks import constants as C
from repro.core import (
    make_jax_worker_factory,
    measure_replay_speedup,
    run_migration_experiment,
)


def run_batched_replay_bench(rates=(10.0, 16.0, 18.0, 19.0), repeats=3,
                             out_path=None):
    make, cfg = make_jax_worker_factory(max_seq=2048)
    worker = make()
    speedup = measure_replay_speedup(cfg, worker.params, n=256, max_seq=512)
    rows = [{"measured_replay_speedup": round(speedup, 2)}]
    with tempfile.TemporaryDirectory() as tmp:
        for rate in rates:
            for label, batched in (("paper_sequential", False),
                                   ("batched_replay", True)):
                migs, downs, ok = [], [], True
                for rep in range(repeats):
                    r = run_migration_experiment(
                        "ms2m_cutoff", rate,
                        registry_root=os.path.join(tmp, f"{label}{rate}{rep}"),
                        processing_ms=C.PROCESSING_MS,
                        t_replay_max=C.T_REPLAY_MAX,
                        seed=rep,
                        batched_replay=batched,
                        replay_speedup=speedup,
                    )
                    migs.append(r.migration_time)
                    downs.append(r.downtime)
                    ok = ok and r.verified
                rows.append({
                    "variant": label, "rate": rate,
                    "migration_time_mean": round(sum(migs) / len(migs), 3),
                    "downtime_mean": round(sum(downs) / len(downs), 3),
                    "all_verified": ok,
                })
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            for row in rows:
                f.write(json.dumps(row) + "\n")
    return rows


def run_dedup_bench(out_path=None):
    """Two consecutive migrations of the same worker: the second push should
    upload ~only the state delta (weights dedup to zero)."""
    import jax
    from repro.checkpoint import Registry
    from repro.core.consumer import StatefulConsumer
    from repro.broker.broker import Message

    make, cfg = make_jax_worker_factory(max_seq=512)
    worker = make()
    msgs = [Message(i, {"token": (13 * i) % cfg.vocab_size}, 0.0)
            for i in range(64)]
    worker.replay_sequential(msgs[:32])
    with tempfile.TemporaryDirectory() as tmp:
        reg = Registry(tmp)
        # MS2M images carry weights (infra payload) + state; model the
        # paper's full-image push as weights+state in one image:
        from repro.models.common import split_params
        weights, _ = split_params(worker.params)
        r1 = reg.push_image({"weights": weights, "state": worker.state_tree()})
        worker.replay_sequential(msgs[32:])  # state advances
        r2 = reg.push_image({"weights": weights, "state": worker.state_tree()})
        rows = [{
            "push": "first", "total_mb": round(r1.total_bytes / 1e6, 2),
            "written_mb": round(r1.written_bytes / 1e6, 2),
            "dedup_ratio": round(r1.deduped_bytes / max(r1.total_bytes, 1), 4),
        }, {
            "push": "second", "total_mb": round(r2.total_bytes / 1e6, 2),
            "written_mb": round(r2.written_bytes / 1e6, 2),
            "dedup_ratio": round(r2.deduped_bytes / max(r2.total_bytes, 1), 4),
        }]
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            for row in rows:
                f.write(json.dumps(row) + "\n")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="results/beyond_paper.json")
    args = ap.parse_args(argv)
    rows = run_batched_replay_bench(repeats=args.repeats,
                                    out_path=args.out)
    print(f"measured replay speedup: {rows[0]['measured_replay_speedup']}x")
    for r in rows[1:]:
        print(f"{r['variant']:18s} rate={r['rate']:4.1f} "
              f"mig={r['migration_time_mean']:8.2f}s "
              f"down={r['downtime_mean']:6.2f}s ok={r['all_verified']}")
    dd = run_dedup_bench(out_path=args.out.replace(".json", "_dedup.json"))
    for r in dd:
        print(f"push {r['push']:6s}: total={r['total_mb']}MB "
              f"written={r['written_mb']}MB dedup={r['dedup_ratio']*100:.1f}%")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
