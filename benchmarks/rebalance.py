"""Rebalance benchmark: predictive controller vs reactive baseline.

For each of three heterogeneous model configs (wildly different migrating
state sizes — an MoE's per-request KV cache, Whisper's encoder-decoder
cross-attention cache, xLSTM's sequence-length-independent recurrent
state) and each rate-modulated arrival schedule (diurnal sine, flash
crowd), the same seeded scenario runs twice:

  * **reactive** — no controller: pods stall through node flaps and catch
    their backlog up after each revive (the status-quo cell);
  * **controller** — :class:`repro.cluster.controller.RebalanceController`
    watches heartbeat flaps, link saturation and queue growth, and drains
    at-risk pods between the first (short) flap and the second (long) one.

Identical seeds drive identical arrival sequences, so the exposure deltas
— downtime avoided (unserved queue-seconds) and messages-at-risk avoided
(backlog integral), each normalized per byte the controller moved — are
attributable to the controller alone.  Every cell is state-verified
against an independent reference fold of each queue's published log.

A second sweep runs seeded-random chaos schedules (survivable kinds:
flaps, link degradation, broker stalls) through both cells and checks the
invariants: verification green, identical publish counts, no lost queue.

Timings: the ``nimble_timings`` profile (fast CRIU/registry path) — the
regime where acting between flaps is physically possible; see
docs/rebalancing.md.

  PYTHONPATH=src python -m benchmarks.rebalance          # full sweep
  ...run.py --quick runs the trimmed CI profile

Output: results/rebalance.json.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, List, Optional

import numpy as np

from repro import configs
from repro.core.workload import _FNV_PRIME, _U64_MASK, HashConsumer

CONFIGS = ("granite_moe_1b_a400m", "whisper_large_v3", "xlstm_350m")

SCHEDULES: Dict[str, Dict[str, Any]] = {
    "diurnal": {"period_s": 60.0, "depth": 0.6},
    "flash_crowd": {"at_s": 40.0, "duration_s": 25.0, "factor": 4.0},
}


def migrating_state_floats(cfg, *, seq: int = 64, scale: int = 64) -> int:
    """Float32 count of one pod's migrating state under config ``cfg``
    (weights are immutable infrastructure — only serving state moves):

      * attention families — the KV cache over ``seq`` tokens;
      * encoder-decoder (whisper) — decoder self-KV plus the cross-KV
        over the full encoder sequence (the dominant term);
      * recurrent (ssm/xlstm) — per-layer fixed-size state, independent
        of sequence length (the architecture's migration advantage).

    ``scale`` shrinks every config by the same factor so a 6-pod fleet
    fits in benchmark memory; the cross-config *ratios* (the point of the
    sweep) are preserved."""
    hd = cfg.head_dim or cfg.d_model // cfg.num_heads
    if cfg.family in ("ssm", "hybrid"):
        toks = 1
    elif cfg.is_encoder_decoder:
        toks = seq + cfg.encoder_seq
    else:
        toks = seq
    floats = cfg.num_layers * 2 * cfg.num_kv_heads * hd * toks
    return max(1024, floats // scale)


class SizedStateConsumer(HashConsumer):
    """Hash fold plus a config-sized state blob; each message dirties one
    stripe.  The blob update is keyed on ``msg_id`` alone and applied on
    all three fold paths (per-message, batched, pair fast path), so the
    fluid and per-message execution regimes stay bit-identical."""

    STRIPE = 64

    def __init__(self, n_floats: int):
        super().__init__()
        self.blob = np.zeros(n_floats, dtype=np.float32)

    def _dirty(self, msg_id: int) -> None:
        i = (msg_id * 257 * self.STRIPE) % max(1, len(self.blob)
                                               - self.STRIPE)
        self.blob[i: i + self.STRIPE] += 1.0

    def process(self, msg):
        super().process(msg)
        self._dirty(msg.msg_id)

    def process_batch(self, msgs):
        d = int(self.digest)
        last = self.last_msg_id
        n = 0
        for m in msgs:
            mid = m.msg_id
            d = ((d ^ (m.payload["token"] ^ (mid + 1))) * _FNV_PRIME) \
                & _U64_MASK
            self._dirty(mid)
            last = mid
            n += 1
        self.digest = np.uint64(d)
        self.pos += n
        self.last_msg_id = last
        self.n_processed += n

    def process_pairs(self, pairs):
        d = int(self.digest)
        last = self.last_msg_id
        n = 0
        for mid, payload in pairs:
            d = ((d ^ (payload["token"] ^ (mid + 1))) * _FNV_PRIME) \
                & _U64_MASK
            self._dirty(mid)
            last = mid
            n += 1
        self.digest = np.uint64(d)
        self.pos += n
        self.last_msg_id = last
        self.n_processed += n

    def state_nbytes(self) -> int:
        return int(self.blob.nbytes) + 64  # copy-free probe for placement

    def state_tree(self):
        tree = super().state_tree()
        tree["blob"] = self.blob.copy()  # snapshot: no aliasing live state
        return tree

    def load_state(self, tree):
        super().load_state(tree)
        self.blob = np.array(tree["blob"], dtype=np.float32)

    def state_equal(self, other, exact: bool = True):
        return (super().state_equal(other, exact)
                and np.array_equal(self.blob, other.blob))


def make_sized_factory(config_name: str):
    cfg = configs.get_config(config_name)
    n_floats = migrating_state_floats(cfg)
    return (lambda: SizedStateConsumer(n_floats)), n_floats * 4


def flap_story(node: str = "node1"):
    """The headline fault narrative: a short flap (the warning the
    controller reads) followed by a long flap of the same node (the
    failure a reactive cluster eats in full)."""
    from repro.cluster.faults import Fault

    return [Fault(kind="node_flap", at=20.0, node=node, duration=8.0),
            Fault(kind="node_flap", at=70.0, node=node, duration=25.0)]


def chaos_schedule(seed: int, n_pods: int, num_nodes: int):
    """Seeded survivable-kind schedule over every node and queue: flaps,
    link degradation and broker stalls never destroy pod state, so both
    cells must stay fully verifiable."""
    from repro.cluster.faults import FaultSchedule

    return FaultSchedule.random(
        seed, n_faults=3, t_window=(10.0, 80.0),
        nodes=tuple(f"node{i}" for i in range(num_nodes)),
        queues=tuple(f"orders-{i}" for i in range(n_pods)),
        kinds=("node_flap", "link_degrade", "broker_stall"),
        flap_s=(2.0, 10.0))


def _pair(config_name: str, schedule: str, seed: int, *, n_pods: int,
          t_end: float, faults_of, message_rate: float = 6.0) -> Dict:
    """One (config, schedule, seed) cell: baseline run + controller run."""
    from repro.cluster.controller import (RebalanceConfig,
                                          run_rebalance_scenario)

    make_worker, state_bytes = make_sized_factory(config_name)
    out: Dict[str, Any] = {"config": config_name, "schedule": schedule,
                           "seed": seed, "state_bytes_per_pod": state_bytes}
    cells = {}
    for label, ctrl in (("reactive", None), ("controller",
                                             RebalanceConfig())):
        with tempfile.TemporaryDirectory() as root:
            r = run_rebalance_scenario(
                registry_root=root, n_pods=n_pods, num_nodes=4,
                message_rate=message_rate, schedule=schedule,
                schedule_kwargs=SCHEDULES[schedule], faults=faults_of(),
                seed=seed, t_end=t_end, controller=ctrl,
                worker_factory=make_worker)
        cells[label] = r
        out[label] = r.row()
    base, ctrl = cells["reactive"], cells["controller"]
    moved_mb = ctrl.moved_wire_bytes / 1e6
    out["downtime_avoided_s"] = round(
        base.unserved_queue_seconds - ctrl.unserved_queue_seconds, 6)
    out["messages_at_risk_avoided"] = round(
        base.backlog_integral_msg_s - ctrl.backlog_integral_msg_s, 6)
    out["downtime_avoided_s_per_MB_moved"] = round(
        out["downtime_avoided_s"] / moved_mb, 6) if moved_mb else None
    out["messages_at_risk_avoided_per_MB_moved"] = round(
        out["messages_at_risk_avoided"] / moved_mb, 6) if moved_mb else None
    out["dominates"] = bool(
        out["downtime_avoided_s"] > 0
        and ctrl.moved_wire_bytes > 0
        and base.all_verified and ctrl.all_verified)
    return out


def _chaos_pair(config_name: str, seed: int, *, n_pods: int,
                t_end: float) -> Dict:
    from repro.cluster.controller import (RebalanceConfig,
                                          run_rebalance_scenario)

    make_worker, _ = make_sized_factory(config_name)
    cells = {}
    for label, ctrl in (("reactive", None), ("controller",
                                             RebalanceConfig())):
        with tempfile.TemporaryDirectory() as root:
            cells[label] = run_rebalance_scenario(
                registry_root=root, n_pods=n_pods, num_nodes=4,
                message_rate=6.0, schedule="steady",
                faults=chaos_schedule(seed, n_pods, 4), seed=seed,
                t_end=t_end, controller=ctrl, worker_factory=make_worker)
    base, ctrl = cells["reactive"], cells["controller"]
    invariant_ok = bool(
        base.all_verified and ctrl.all_verified
        and base.published_total == ctrl.published_total)
    return {"config": config_name, "seed": seed,
            "schedule_rows": chaos_schedule(seed, n_pods, 4).rows(),
            "reactive": base.row(), "controller": ctrl.row(),
            "invariant_ok": invariant_ok}


def run_rebalance(quick: bool = False,
                  out_path: Optional[str] = None) -> Dict:
    seeds = (0,) if quick else (0, 1, 2)
    chaos_seeds = (0, 1) if quick else tuple(range(6))
    n_pods = 4 if quick else 6
    t_end = 120.0

    rows: List[Dict] = []
    for config_name in CONFIGS:
        for schedule in SCHEDULES:
            for seed in seeds:
                rows.append(_pair(config_name, schedule, seed,
                                  n_pods=n_pods, t_end=t_end,
                                  faults_of=flap_story))

    chaos_rows: List[Dict] = []
    for seed in chaos_seeds:
        chaos_rows.append(_chaos_pair(CONFIGS[seed % len(CONFIGS)], seed,
                                      n_pods=n_pods, t_end=t_end))

    out = {
        "timings": "nimble",
        "configs": {name: make_sized_factory(name)[1] for name in CONFIGS},
        "schedules": SCHEDULES,
        "rows": rows,
        "chaos": chaos_rows,
        "dominates_all": bool(all(r["dominates"] for r in rows)),
        "chaos_invariants_ok": bool(all(r["invariant_ok"]
                                        for r in chaos_rows)),
    }
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as fh:
            json.dump(out, fh, indent=1)
    return out


def main() -> int:
    out = run_rebalance(out_path="results/rebalance.json")
    for r in out["rows"]:
        print(f"{r['config']:>22} {r['schedule']:>12} seed={r['seed']} "
              f"downtime_avoided={r['downtime_avoided_s']:+.1f}s "
              f"per_MB={r['downtime_avoided_s_per_MB_moved']} "
              f"dominates={r['dominates']}")
    print(f"dominates_all={out['dominates_all']} "
          f"chaos_invariants_ok={out['chaos_invariants_ok']}")
    return 0 if (out["dominates_all"] and out["chaos_invariants_ok"]) else 1


if __name__ == "__main__":
    raise SystemExit(main())
