"""Benchmark entry point: one function per paper table/figure group.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract, plus
the full result files under results/.

  fig5_8   migration_sweep    — time/downtime vs rate, 4 strategies
  fig9_11  rate_scenarios     — low/mid/high rate comparisons + reductions
  fig12_14 phase_breakdown    — sub-process latency distribution
  claims   claims             — paper headline validation bands
  beyond   beyond_paper       — batched replay + registry dedup (ours)
  delta    delta_precopy      — iterative delta checkpointing (ours)
  fleet    fleet_migration    — N-pod orchestrated migration (ours)
  topo     fleet_topology     — contended-topology scenarios (ours):
                                shared-link concurrency sweep + edge WAN
  chaos    chaos              — seeded fault schedules vs scheme (ours):
                                >= 100 randomized schedules, rollback/retry
                                invariants + same-seed determinism
  serving  serving_handoff    — tail latency under migration (ours):
                                dual-serving KV-cache handoff vs stop-then-
                                replay vs cold, exactly-once audited

``--quick`` is the CI smoke profile: repeats=1, the paper rates only,
hash-fold consumers everywhere (the JAX-compute sections are skipped), and
the adaptive registry strategy exercised alongside the paper's four.  It
still writes the same results/*.json files so CI can upload them.
"""
from __future__ import annotations

import argparse
import sys
import time


def _csv(name: str, seconds: float, derived: str):
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke profile: 1 repeat, paper rates, no "
                         "JAX-compute sections")
    args = ap.parse_args(argv)

    t0 = time.time()
    from benchmarks.migration_sweep import run_sweep
    from benchmarks.rate_scenarios import run_scenarios
    from benchmarks.phase_breakdown import run_breakdown
    from benchmarks.claims import run_claims
    from benchmarks import constants as C

    repeats = 1 if args.quick else 3  # full paper protocol (10): benchmarks.claims
    sweep_kwargs = {}
    if args.quick:
        sweep_kwargs = {
            "strategies": ("stop_and_copy", "ms2m_individual", "ms2m_cutoff",
                           "ms2m_statefulset", "ms2m_precopy",
                           "ms2m_adaptive"),
            "rates": C.PAPER_RATES,
        }

    t = time.time()
    sweep = run_sweep(repeats=repeats, out_path="results/migration_sweep.json",
                      **sweep_kwargs)
    for r in sweep:
        if r["rate"] in C.PAPER_RATES:
            _csv(f"fig5_8/{r['strategy']}@{r['rate']:g}",
                 r["migration_time_mean"],
                 f"downtime={r['downtime_mean']}s verified={r['all_verified']}")
    print(f"# migration_sweep done in {time.time()-t:.1f}s", file=sys.stderr)

    t = time.time()
    scen = run_scenarios(repeats=repeats, out_path="results/rate_scenarios.json")
    for r in scen:
        _csv(f"fig9_11/{r['strategy']}@{r['rate']:g}",
             r["downtime_mean"],
             f"down_reduction={r['downtime_reduction_vs_sac']*100:.2f}%")
    print(f"# rate_scenarios done in {time.time()-t:.1f}s", file=sys.stderr)

    t = time.time()
    brk = run_breakdown(repeats=repeats, out_path="results/phase_breakdown.json")
    for r in brk:
        _csv(f"fig12_14/{r['strategy']}@{r['rate']:g}", r["total_s"],
             f"replay_share={r['phase_shares']['message_replay']*100:.1f}%")
    print(f"# phase_breakdown done in {time.time()-t:.1f}s", file=sys.stderr)

    t = time.time()
    claims = run_claims(repeats=repeats, out_path="results/claims.json")
    npass = sum(1 for c in claims if c["pass"])
    _csv("claims/validated", time.time() - t, f"{npass}/{len(claims)} bands pass")
    print(f"# claims done in {time.time()-t:.1f}s", file=sys.stderr)

    t = time.time()
    # codec roofline: measured fingerprint+encode bytes/s per chunk size,
    # two-pass host flow vs the fused kernel path (also in --quick so CI
    # uploads codec_roofline.json; quick = smaller leaf, 1 repeat)
    from benchmarks.roofline import run_codec_roofline
    cr = run_codec_roofline(quick=args.quick,
                            out_path="results/codec_roofline.json")
    for r in cr["rows"]:
        if r["op"].startswith("fp+encode"):
            _csv(f"roofline/{r['op']}@{r['chunk_bytes']}_{r['path']}",
                 r["elapsed_s"], f"{r['bytes_per_s'] / 1e6:.0f}MB/s")
    cal = cr["calibration"]
    _csv("roofline/calibration", time.time() - t,
         f"codec_Bps={cal['codec_Bps']:.3g} "
         f"fingerprint_Bps={cal['fingerprint_Bps']:.3g}")
    print(f"# codec_roofline done in {time.time()-t:.1f}s", file=sys.stderr)

    if not args.quick:
        t = time.time()
        from benchmarks.beyond_paper import (run_batched_replay_bench,
                                             run_dedup_bench)
        rows = run_batched_replay_bench(repeats=2,
                                        out_path="results/beyond_paper.json")
        speedup = rows[0]["measured_replay_speedup"]
        _csv("beyond/replay_speedup", 0.0, f"{speedup}x chunk-parallel replay")
        for r in rows[1:]:
            _csv(f"beyond/{r['variant']}@{r['rate']:g}",
                 r["migration_time_mean"], f"downtime={r['downtime_mean']}s")
        dd = run_dedup_bench(out_path="results/beyond_paper_dedup.json")
        for r in dd:
            _csv(f"beyond/dedup_push_{r['push']}", 0.0,
                 f"written={r['written_mb']}MB dedup={r['dedup_ratio']*100:.1f}%")
        print(f"# beyond_paper done in {time.time()-t:.1f}s", file=sys.stderr)

    t = time.time()
    from benchmarks.delta_precopy import (run_codec_comparison,
                                          run_delta_bytes, run_precopy_sweep)
    if not args.quick:  # real-JAX consumer: skipped in the smoke profile
        db = run_delta_bytes(out_path="results/delta_bytes.json")
        _csv("delta/bytes", 0.0,
             f"delta={db['delta_written_bytes']}B "
             f"({db['delta_fraction']*100:.1f}% of full) "
             f"smaller={db['delta_strictly_smaller']}")
    for r in run_precopy_sweep(repeats=1 if args.quick else 2,
                               out_path="results/delta_precopy.json"):
        _csv(f"delta/{r['profile']}@{r['rate']:g}r{r['max_rounds']}",
             r["downtime_mean"],
             f"replayed={r['replayed_mean']} "
             f"final_round_bytes={r['final_round_bytes_mean']}")
    # codec comparison: the trainer workload is real-JAX, so the smoke
    # profile runs the blob workload only
    for r in run_codec_comparison(include_trainer=not args.quick,
                                  out_path="results/delta_codecs.json"):
        _csv(f"delta/codec_{r['workload']}_{r['codec']}", 0.0,
             f"wire_reduction=x{r['wire_reduction']} "
             f"delta_rounds=x{r['delta_wire_reduction']} "
             f"verified={r['state_verified']}")
    print(f"# delta_precopy done in {time.time()-t:.1f}s", file=sys.stderr)

    t = time.time()
    from benchmarks.fleet_migration import run_fleet, run_topology
    for r in run_fleet(repeats=1 if args.quick else 2,
                       out_path="results/fleet_migration.json"):
        _csv(f"fleet/{r['scenario']}", r["span_mean"],
             f"peak_conc={r['peak_concurrency']} "
             f"max_downtime={r['max_downtime_mean']}s "
             f"verified={r['all_verified']}")
    print(f"# fleet_migration done in {time.time()-t:.1f}s", file=sys.stderr)

    t = time.time()
    # contended topologies: quick = 1 repeat, 2 sweep points, 2 edge schemes
    # (still writes/uploads results/fleet_topology.json from CI)
    for r in run_topology(repeats=1 if args.quick else 2, quick=args.quick,
                          out_path="results/fleet_topology.json"):
        _csv(f"topo/{r['scenario']}", r["span_mean"],
             f"max_downtime={r['max_downtime_mean']}s "
             f"wire={r['wire_bytes_total']}B wan={r['wan_bytes_total']}B "
             f"verified={r['all_verified']}")
    print(f"# fleet_topology done in {time.time()-t:.1f}s", file=sys.stderr)

    t = time.time()
    # chaos: >= 100 seeded fault schedules across 3 schemes, checking the
    # crash-consistency invariant on every run (also in --quick, so CI
    # exercises the rollback/retry machinery and uploads chaos.json)
    from benchmarks.chaos import run_chaos
    for r in run_chaos(quick=args.quick, out_path="results/chaos.json"):
        if r["fault_level"] == "summary":
            _csv("chaos/summary", 0.0,
                 f"{r['runs']} schedules invariant_ok={r['invariant_ok']} "
                 f"deterministic={r['deterministic']}")
            continue
        _csv(f"chaos/{r['scheme']}@{r['fault_level']}", r["exposure_s"],
             f"failed={r['n_failed']}/{r['n_migrated'] + r['n_failed']} "
             f"attempts={r['attempts']} recovered={r['recovered']} "
             f"invariant_ok={r['invariant_ok']}")
    print(f"# chaos done in {time.time()-t:.1f}s", file=sys.stderr)

    t = time.time()
    # sim engine: epoch-batched (fluid) vs per-message kernel throughput,
    # 10k-pod smoke, one timed chaos seed (also in --quick so CI uploads
    # BENCH_sim.json and the speedup gate has fresh numbers)
    from benchmarks.sim_scale import run_sim_scale
    sim_out = run_sim_scale(quick=args.quick,
                            out_path="results/BENCH_sim.json")
    st = sim_out["steady_1k"]
    _csv("sim/steady_1k", st["fluid"]["wall_s"],
         f"speedup={st['speedup']}x fluid={st['fluid']['msgs_per_wall_s']}"
         f"msg/s baseline={st['baseline']['msgs_per_wall_s']}msg/s")
    _csv("sim/poisson", sim_out["poisson"]["fluid"]["wall_s"],
         f"speedup={sim_out['poisson']['speedup']}x")
    sm = sim_out["smoke_10k"]
    _csv("sim/smoke", sm["wall_total_s"],
         f"pods={sm['n_pods']} msgs={sm['messages']} ok={sm['ok']}")
    ch = sim_out["chaos_seed"]
    _csv("sim/chaos_seed", ch["wall_s"],
         f"pods={ch['n_pods']} invariant_ok={ch['invariant_ok']}")
    print(f"# sim_scale done in {time.time()-t:.1f}s", file=sys.stderr)

    t = time.time()
    # serving: tail latency under migration (dual-serving handoff vs
    # stop-then-replay vs cold) over flat + edge_wan, plus one injected
    # mid-handoff fault with retry (also in --quick so CI exercises the
    # handoff path and uploads serving_handoff.json)
    from benchmarks.serving_handoff import run_serving_bench
    for r in run_serving_bench(quick=args.quick,
                               out_path="results/serving_handoff.json"):
        if r["scheme"] == "VERDICT":
            _csv(f"serving/verdict@{r['topology']}", r["p99_handoff"],
                 f"p99 handoff={r['p99_handoff']}s vs "
                 f"stop_then_replay={r['p99_stop_then_replay']}s "
                 f"win={r['p99_win']}")
            continue
        tag = "+fault" if "fault" in r else ""
        _csv(f"serving/{r['scheme']}@{r['topology']}{tag}",
             r["latency"]["p99"],
             f"p50={r['latency']['p50']}s p999={r['latency']['p999']}s "
             f"exactly_once={r['exactly_once']} "
             f"state_verified={r['state_verified']} lost={r['lost']}")
    print(f"# serving done in {time.time()-t:.1f}s", file=sys.stderr)

    t = time.time()
    # rebalance: predictive controller vs reactive baseline under diurnal
    # and flash-crowd arrivals + seeded chaos schedules, 3 heterogeneous
    # model state sizes (also in --quick so CI exercises the controller
    # and uploads rebalance.json)
    from benchmarks.rebalance import run_rebalance
    reb = run_rebalance(quick=args.quick, out_path="results/rebalance.json")
    for r in reb["rows"]:
        _csv(f"rebalance/{r['config']}@{r['schedule']}s{r['seed']}",
             r["downtime_avoided_s"],
             f"avoided={r['downtime_avoided_s']}qs "
             f"per_MB={r['downtime_avoided_s_per_MB_moved']} "
             f"dominates={r['dominates']}")
    _csv("rebalance/summary", 0.0,
         f"{len(reb['rows'])} cells dominates_all={reb['dominates_all']} "
         f"chaos={len(reb['chaos'])} "
         f"invariants_ok={reb['chaos_invariants_ok']}")
    print(f"# rebalance done in {time.time()-t:.1f}s", file=sys.stderr)

    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
