"""Validation of the paper's headline claims against our reproduction.

Each claim is checked within a tolerance band (the paper's absolute numbers
depend on their GCE testbed; we calibrate infra constants once in
benchmarks/constants.py and then require the *structure* — ratios, trends,
orderings — to reproduce).
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks import constants as C
from benchmarks.migration_sweep import run_sweep
from benchmarks.rate_scenarios import run_scenarios
from benchmarks.phase_breakdown import run_breakdown


def _band(x, target, tol):
    return abs(x - target) <= tol


def run_claims(repeats=3, out_path=None):
    scen = run_scenarios(repeats=repeats)
    brk = run_breakdown(repeats=repeats)
    by = {(r["strategy"], r["rate"]): r for r in scen}
    bby = {(r["strategy"], r["rate"]): r for r in brk}
    P = C.PAPER
    claims = []

    def claim(name, value, target, tol, source):
        claims.append({
            "claim": name, "ours": round(value, 4), "paper": target,
            "tolerance": tol, "pass": _band(value, target, tol),
            "paper_source": source,
        })

    sac = by[("stop_and_copy", 4.0)]
    claim("stop-and-copy total ~= downtime (s)",
          sac["migration_time_mean"], P["stop_and_copy_total_s"], 3.0, "Fig.5")
    claim("stop-and-copy flat across rates (max-min, s)",
          by[("stop_and_copy", 16.0)]["migration_time_mean"]
          - by[("stop_and_copy", 4.0)]["migration_time_mean"], 0.0, 1.0, "Fig.5")

    claim("MS2M-individual downtime (s)",
          by[("ms2m_individual", 4.0)]["downtime_mean"],
          P["ms2m_downtime_s"], 0.8, "Fig.6")
    claim("downtime reduction, individual @4/s",
          by[("ms2m_individual", 4.0)]["downtime_reduction_vs_sac"],
          P["downtime_reduction_individual_low"], 0.02, "Fig.9")
    claim("downtime reduction, individual @10/s",
          by[("ms2m_individual", 10.0)]["downtime_reduction_vs_sac"],
          P["downtime_reduction_individual_mid"], 0.02, "Fig.10")
    claim("downtime reduction, cutoff @4/s",
          by[("ms2m_cutoff", 4.0)]["downtime_reduction_vs_sac"],
          P["downtime_reduction_cutoff_low"], 0.025, "Fig.9")
    claim("downtime reduction, cutoff @16/s",
          by[("ms2m_cutoff", 16.0)]["downtime_reduction_vs_sac"],
          P["downtime_reduction_cutoff_high"], 0.12, "Fig.11")
    claim("downtime reduction, statefulset @4/s",
          by[("ms2m_statefulset", 4.0)]["downtime_reduction_vs_sac"],
          P["downtime_reduction_sts_low"], 0.08, "Fig.9")
    claim("downtime reduction, statefulset @10/s",
          by[("ms2m_statefulset", 10.0)]["downtime_reduction_vs_sac"],
          P["downtime_reduction_sts_mid"], 0.08, "Fig.10")
    claim("downtime reduction, statefulset @16/s",
          by[("ms2m_statefulset", 16.0)]["downtime_reduction_vs_sac"],
          P["downtime_reduction_sts_high"], 0.08, "Fig.11")

    # structural claims
    mig_ind = [by[("ms2m_individual", r)]["migration_time_mean"]
               for r in C.PAPER_RATES]
    claims.append({
        "claim": "individual migration time grows steeply toward mu",
        "ours": [round(m, 1) for m in mig_ind],
        "pass": mig_ind[0] < mig_ind[1] < mig_ind[2]
                and mig_ind[2] > 2.0 * mig_ind[0],
        "paper_source": "Fig.6",
    })
    claims.append({
        "claim": "cutoff reduces migration time at high rate",
        "ours": round(by[("ms2m_cutoff", 16.0)]["migration_time_mean"], 1),
        "vs": round(by[("ms2m_individual", 16.0)]["migration_time_mean"], 1),
        "pass": by[("ms2m_cutoff", 16.0)]["migration_time_mean"]
                < 0.7 * by[("ms2m_individual", 16.0)]["migration_time_mean"],
        "paper_source": "Fig.7/§IV-B",
    })

    share_no = bby[("ms2m_individual", 16.0)]["phase_shares"]["message_replay"]
    share_cut = bby[("ms2m_cutoff", 16.0)]["phase_shares"]["message_replay"]
    claim("replay share @16/s, no cutoff", share_no,
          P["replay_share_high_no_cutoff"], 0.12, "Fig.12")
    claim("replay share @16/s, with cutoff", share_cut,
          P["replay_share_high_with_cutoff"], 0.15, "Fig.13")
    claims.append({
        "claim": "service restoration dominates StatefulSet breakdown",
        "ours": bby[("ms2m_statefulset", 10.0)]["phase_shares"],
        "pass": bby[("ms2m_statefulset", 10.0)]["phase_shares"]
                ["service_restoration"] >= max(
                    v for k, v in bby[("ms2m_statefulset", 10.0)]
                    ["phase_shares"].items() if k != "service_restoration"),
        "paper_source": "Fig.14",
    })

    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            for c in claims:
                f.write(json.dumps(c) + "\n")
    return claims


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=C.REPEATS)
    ap.add_argument("--out", default="results/claims.json")
    args = ap.parse_args(argv)
    claims = run_claims(args.repeats, args.out)
    npass = sum(1 for c in claims if c["pass"])
    for c in claims:
        mark = "PASS" if c["pass"] else "FAIL"
        print(f"[{mark}] {c['claim']}: ours={c['ours']} "
              f"paper={c.get('paper', '-')} ({c['paper_source']})")
    print(f"{npass}/{len(claims)} claims reproduced")
    return 0 if npass == len(claims) else 1


if __name__ == "__main__":
    raise SystemExit(main())
