"""Iterative delta checkpointing benchmark: bytes-per-round, the
rounds-vs-downtime tradeoff, and the delta-codec raw-vs-wire comparison.

Three sections:

  * ``run_delta_bytes``   — a real JAX consumer's checkpoint pushed full,
    then delta after k more decodes: the delta must write strictly fewer
    bytes than the full image (content-addressed chunk diffing).
  * ``run_precopy_sweep`` — ms2m_statefulset / ms2m_precopy downtime and
    bounded-replay size as a function of the max pre-copy round budget,
    under two timing profiles: the paper-calibrated control plane (fixed
    costs dominate) and a byte-dominated WAN profile (slow registry link,
    where pre-copy shines).
  * ``run_codec_comparison`` — ms2m_precopy with each delta codec
    (``none`` / ``xor_rle`` / ``int8``) on two workloads: the sparse-dirty
    blob consumer (xor+RLE territory) and a real *trainer* (params + AdamW
    state, every chunk dirty every round — the int8 error-feedback
    regime).  Reports raw vs wire bytes, total and delta-rounds-only, with
    every path verified bit-exact against the reference fold.

  PYTHONPATH=src python -m benchmarks.delta_precopy
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Dict, List, Optional

import numpy as np

from repro.checkpoint import Registry
from repro.cluster.cluster import TimingConstants
from repro.core import MigrationPolicy, run_migration_experiment
from repro.core.workload import HashConsumer

# WAN-ish profile: fast control plane, slow registry link — transfer time
# is dominated by bytes, the regime iterative pre-copy is built for.
WAN_TIMINGS = TimingConstants(
    checkpoint_s=1.0, image_build_s=2.0, delta_build_s=0.5,
    push_base_s=0.5, pull_base_s=0.5, restore_s=2.0,
    registry_bw_Bps=10e6)


class BigStateConsumer(HashConsumer):
    """Hash fold plus a multi-chunk mostly-static state blob (~8 MiB):
    the image profile where delta rounds dirty only a sliver."""

    def __init__(self):
        super().__init__()
        self.blob = np.zeros(1 << 21, dtype=np.float32)

    def process(self, msg):
        super().process(msg)
        # each message dirties one 4KiB-ish stripe of the blob
        i = (msg.msg_id * 1024) % (len(self.blob) - 1024)
        self.blob[i: i + 1024] += 1.0

    def state_tree(self):
        tree = super().state_tree()
        # snapshot semantics: the checkpoint must not alias live state
        # (the source keeps serving while the image is built and pushed)
        tree["blob"] = self.blob.copy()
        return tree

    def load_state(self, tree):
        super().load_state(tree)
        self.blob = np.array(tree["blob"], dtype=np.float32)

    def state_equal(self, other, exact: bool = True):
        return (super().state_equal(other, exact)
                and np.array_equal(self.blob, other.blob))


def run_delta_bytes(out_path: Optional[str] = None,
                    n_msgs: int = 64) -> Dict:
    """Full push vs delta push of a mutated JAX consumer state."""
    from repro.broker.broker import Message
    from repro.core import make_jax_worker_factory

    make_worker, _cfg = make_jax_worker_factory(max_seq=256)
    worker = make_worker()
    msgs = [Message(i, {"token": (i * 37) % 512}, 0.0)
            for i in range(2 * n_msgs)]
    for m in msgs[:n_msgs]:
        worker.process(m)

    with tempfile.TemporaryDirectory() as root:
        reg = Registry(root, chunk_bytes=64 * 1024)
        # the realistic image: static weight layers + the serving cache
        # (cf. registry docstring: a re-push re-uploads only cache chunks)
        full = reg.push_image({"state": worker.state_tree(),
                               "weights": worker.params})
        for m in msgs[n_msgs:]:
            worker.process(m)
        delta = reg.push_delta({"state": worker.state_tree(),
                                "weights": worker.params}, full.image_id)
        trees, _ = reg.pull_image(delta.image_id)
        restored = make_worker()
        restored.load_state(trees["state"])
        row = {
            "full_total_bytes": full.total_bytes,
            "full_written_bytes": full.written_bytes,
            "delta_written_bytes": delta.written_bytes,
            "delta_bytes": delta.delta_bytes,
            "delta_fraction": round(delta.delta_bytes
                                    / max(1, full.total_bytes), 4),
            "delta_strictly_smaller":
                delta.written_bytes < full.written_bytes,
            "restored_state_equal": bool(restored.state_equal(worker)),
        }
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(row, f, indent=2)
    return row


def run_precopy_sweep(repeats: int = 3,
                      rates=(6.0, 14.0),
                      round_budgets=(0, 1, 2, 4),
                      out_path: Optional[str] = None) -> List[Dict]:
    """Rounds-vs-downtime: ms2m_statefulset with the pre-copy opt-in at
    increasing round budgets (0 == the paper's single-checkpoint scheme)."""
    rows: List[Dict] = []
    profiles = {"paper": TimingConstants(), "wan": WAN_TIMINGS}
    for profile, timings in profiles.items():
        for rate in rates:
            for budget in round_budgets:
                downs, replays, bytes_last = [], [], []
                for rep in range(repeats):
                    with tempfile.TemporaryDirectory() as root:
                        r = run_migration_experiment(
                            "ms2m_statefulset", rate, registry_root=root,
                            seed=rep, timings=dataclasses.replace(
                                timings, processing_ms=50.0),
                            worker_factory=BigStateConsumer,
                            chunk_bytes=64 * 1024,
                            policy=MigrationPolicy(
                                precopy=budget > 0,
                                precopy_max_rounds=budget),
                        )
                    assert r.verified, (profile, rate, budget)
                    downs.append(r.downtime)
                    replays.append(r.report.replayed_messages)
                    bytes_last.append(
                        r.report.precopy_round_bytes[-1]
                        if r.report.precopy_round_bytes else
                        r.report.image_written_bytes)
                rows.append({
                    "profile": profile,
                    "rate": rate,
                    "max_rounds": budget,
                    "downtime_mean": round(float(np.mean(downs)), 3),
                    "replayed_mean": round(float(np.mean(replays)), 1),
                    "final_round_bytes_mean":
                        round(float(np.mean(bytes_last)), 1),
                })
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


def make_trainer_factory(seq_len: int = 32, global_batch: int = 2):
    """A small real trainer (params + AdamW state ~2.3 MB f32): the
    pre-copy workload where *every* chunk is dirty every round."""
    from repro import configs
    from repro.core.trainer_worker import TrainerWorker
    from repro.data import DataConfig
    from repro.optim import adamw
    from repro.train import step as steplib

    cfg = configs.get_smoke("smollm_360m")
    tcfg = steplib.TrainStepConfig(
        remat="none", lr_peak=1e-3, warmup_steps=5, total_steps=10_000,
        opt=adamw.AdamWConfig(weight_decay=0.01))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      global_batch=global_batch)
    return lambda: TrainerWorker(cfg, tcfg, dcfg)


def run_codec_comparison(codecs=("none", "xor_rle", "int8"),
                         include_trainer: bool = True,
                         out_path: Optional[str] = None) -> List[Dict]:
    """ms2m_precopy raw-vs-wire bytes per delta codec and workload.

    The blob workload dirties a thin stripe per message (near-static
    chunks: the xor_rle regime); the trainer workload updates every
    parameter and optimizer slot each step (dense float deltas: the int8
    error-feedback regime, closed by a lossless exact-flush round so the
    restored state stays bit-exact under replay).
    """
    workloads = [
        ("blob", BigStateConsumer, 12.0,
         dict(precopy_max_rounds=4), dict(t_migrate=10.0)),
    ]
    if include_trainer:
        # convergence break disabled: a trainer's dirty set never shrinks
        # (dense updates), the round budget is the knob
        workloads.append(
            ("trainer", make_trainer_factory(), 4.0,
             dict(precopy_max_rounds=8, precopy_converge_ratio=100.0),
             dict(t_migrate=5.0)))
    rows: List[Dict] = []
    for name, factory, rate, pol_kw, exp_kw in workloads:
        for codec in codecs:
            with tempfile.TemporaryDirectory() as root:
                r = run_migration_experiment(
                    "ms2m_precopy", rate, registry_root=root, seed=7,
                    timings=WAN_TIMINGS, worker_factory=factory,
                    chunk_bytes=64 * 1024,
                    policy=MigrationPolicy(compression=codec, **pol_kw),
                    **exp_kw)
            row = r.row()
            delta_raw = sum(row["precopy_round_bytes"][1:])
            delta_wire = sum(row["precopy_round_wire_bytes"][1:])
            rows.append({
                "workload": name,
                "codec": codec,
                "state_verified": row["state_verified"],
                "downtime": row["downtime"],
                "precopy_rounds": row["precopy_rounds"],
                "raw_bytes": row["image_raw_bytes"],
                "wire_bytes": row["image_wire_bytes"],
                "wire_reduction": row["wire_reduction"],
                "delta_raw_bytes": delta_raw,
                "delta_wire_bytes": delta_wire,
                "delta_wire_reduction": round(
                    delta_raw / max(1, delta_wire), 3),
            })
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


def main():
    row = run_delta_bytes(out_path="results/delta_bytes.json")
    print(f"delta push: full={row['full_written_bytes']}B "
          f"delta={row['delta_written_bytes']}B "
          f"({row['delta_fraction']*100:.1f}% of image) "
          f"smaller={row['delta_strictly_smaller']} "
          f"restored_ok={row['restored_state_equal']}")
    for r in run_precopy_sweep(out_path="results/delta_precopy.json"):
        print(f"[{r['profile']}] rate={r['rate']:g} rounds<={r['max_rounds']}"
              f" downtime={r['downtime_mean']}s replayed={r['replayed_mean']}"
              f" final_round_bytes={r['final_round_bytes_mean']}")
    for r in run_codec_comparison(out_path="results/delta_codecs.json"):
        print(f"[{r['workload']}/{r['codec']}] raw={r['raw_bytes']}B "
              f"wire={r['wire_bytes']}B x{r['wire_reduction']} "
              f"(delta rounds x{r['delta_wire_reduction']}) "
              f"verified={r['state_verified']}")


if __name__ == "__main__":
    main()
