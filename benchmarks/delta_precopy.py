"""Iterative delta checkpointing benchmark: bytes-per-round and the
rounds-vs-downtime tradeoff.

Two sections:

  * ``run_delta_bytes``   — a real JAX consumer's checkpoint pushed full,
    then delta after k more decodes: the delta must write strictly fewer
    bytes than the full image (content-addressed chunk diffing).
  * ``run_precopy_sweep`` — ms2m_statefulset / ms2m_precopy downtime and
    bounded-replay size as a function of the max pre-copy round budget,
    under two timing profiles: the paper-calibrated control plane (fixed
    costs dominate) and a byte-dominated WAN profile (slow registry link,
    where pre-copy shines).

  PYTHONPATH=src python -m benchmarks.delta_precopy
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Dict, List, Optional

import numpy as np

from repro.checkpoint import Registry
from repro.cluster.cluster import TimingConstants
from repro.core import MigrationPolicy, run_migration_experiment
from repro.core.workload import HashConsumer

# WAN-ish profile: fast control plane, slow registry link — transfer time
# is dominated by bytes, the regime iterative pre-copy is built for.
WAN_TIMINGS = TimingConstants(
    checkpoint_s=1.0, image_build_s=2.0, delta_build_s=0.5,
    push_base_s=0.5, pull_base_s=0.5, restore_s=2.0,
    registry_bw_Bps=10e6)


class BigStateConsumer(HashConsumer):
    """Hash fold plus a multi-chunk mostly-static state blob (~8 MiB):
    the image profile where delta rounds dirty only a sliver."""

    def __init__(self):
        super().__init__()
        self.blob = np.zeros(1 << 21, dtype=np.float32)

    def process(self, msg):
        super().process(msg)
        # each message dirties one 4KiB-ish stripe of the blob
        i = (msg.msg_id * 1024) % (len(self.blob) - 1024)
        self.blob[i: i + 1024] += 1.0

    def state_tree(self):
        tree = super().state_tree()
        # snapshot semantics: the checkpoint must not alias live state
        # (the source keeps serving while the image is built and pushed)
        tree["blob"] = self.blob.copy()
        return tree

    def load_state(self, tree):
        super().load_state(tree)
        self.blob = np.array(tree["blob"], dtype=np.float32)

    def state_equal(self, other, exact: bool = True):
        return (super().state_equal(other, exact)
                and np.array_equal(self.blob, other.blob))


def run_delta_bytes(out_path: Optional[str] = None,
                    n_msgs: int = 64) -> Dict:
    """Full push vs delta push of a mutated JAX consumer state."""
    from repro.broker.broker import Message
    from repro.core import make_jax_worker_factory

    make_worker, _cfg = make_jax_worker_factory(max_seq=256)
    worker = make_worker()
    msgs = [Message(i, {"token": (i * 37) % 512}, 0.0)
            for i in range(2 * n_msgs)]
    for m in msgs[:n_msgs]:
        worker.process(m)

    with tempfile.TemporaryDirectory() as root:
        reg = Registry(root, chunk_bytes=64 * 1024)
        # the realistic image: static weight layers + the serving cache
        # (cf. registry docstring: a re-push re-uploads only cache chunks)
        full = reg.push_image({"state": worker.state_tree(),
                               "weights": worker.params})
        for m in msgs[n_msgs:]:
            worker.process(m)
        delta = reg.push_delta({"state": worker.state_tree(),
                                "weights": worker.params}, full.image_id)
        trees, _ = reg.pull_image(delta.image_id)
        restored = make_worker()
        restored.load_state(trees["state"])
        row = {
            "full_total_bytes": full.total_bytes,
            "full_written_bytes": full.written_bytes,
            "delta_written_bytes": delta.written_bytes,
            "delta_bytes": delta.delta_bytes,
            "delta_fraction": round(delta.delta_bytes
                                    / max(1, full.total_bytes), 4),
            "delta_strictly_smaller":
                delta.written_bytes < full.written_bytes,
            "restored_state_equal": bool(restored.state_equal(worker)),
        }
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(row, f, indent=2)
    return row


def run_precopy_sweep(repeats: int = 3,
                      rates=(6.0, 14.0),
                      round_budgets=(0, 1, 2, 4),
                      out_path: Optional[str] = None) -> List[Dict]:
    """Rounds-vs-downtime: ms2m_statefulset with the pre-copy opt-in at
    increasing round budgets (0 == the paper's single-checkpoint scheme)."""
    rows: List[Dict] = []
    profiles = {"paper": TimingConstants(), "wan": WAN_TIMINGS}
    for profile, timings in profiles.items():
        for rate in rates:
            for budget in round_budgets:
                downs, replays, bytes_last = [], [], []
                for rep in range(repeats):
                    with tempfile.TemporaryDirectory() as root:
                        r = run_migration_experiment(
                            "ms2m_statefulset", rate, registry_root=root,
                            seed=rep, timings=dataclasses.replace(
                                timings, processing_ms=50.0),
                            worker_factory=BigStateConsumer,
                            chunk_bytes=64 * 1024,
                            policy=MigrationPolicy(
                                precopy=budget > 0,
                                precopy_max_rounds=budget),
                        )
                    assert r.verified, (profile, rate, budget)
                    downs.append(r.downtime)
                    replays.append(r.report.replayed_messages)
                    bytes_last.append(
                        r.report.precopy_round_bytes[-1]
                        if r.report.precopy_round_bytes else
                        r.report.image_written_bytes)
                rows.append({
                    "profile": profile,
                    "rate": rate,
                    "max_rounds": budget,
                    "downtime_mean": round(float(np.mean(downs)), 3),
                    "replayed_mean": round(float(np.mean(replays)), 1),
                    "final_round_bytes_mean":
                        round(float(np.mean(bytes_last)), 1),
                })
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


def main():
    row = run_delta_bytes(out_path="results/delta_bytes.json")
    print(f"delta push: full={row['full_written_bytes']}B "
          f"delta={row['delta_written_bytes']}B "
          f"({row['delta_fraction']*100:.1f}% of image) "
          f"smaller={row['delta_strictly_smaller']} "
          f"restored_ok={row['restored_state_equal']}")
    for r in run_precopy_sweep(out_path="results/delta_precopy.json"):
        print(f"[{r['profile']}] rate={r['rate']:g} rounds<={r['max_rounds']}"
              f" downtime={r['downtime_mean']}s replayed={r['replayed_mean']}"
              f" final_round_bytes={r['final_round_bytes_mean']}")


if __name__ == "__main__":
    main()
