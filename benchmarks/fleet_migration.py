"""Cluster-scale migration scenarios: N-pod fleets through the
ClusterMigrationOrchestrator.

Scenarios:
  * parallel individual-pod migration at different concurrency limits
    (span shrinks with concurrency; per-pod downtime stays MS2M-short);
  * rolling StatefulSet migration (sequential identity handoff);
  * node drain (evacuate every pod off one node).

  PYTHONPATH=src python -m benchmarks.fleet_migration
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, List, Optional


def _blob_factory():
    from benchmarks.delta_precopy import BigStateConsumer
    return BigStateConsumer()


def run_fleet(repeats: int = 2, n_pods: int = 6,
              out_path: Optional[str] = None) -> List[Dict]:
    import numpy as np

    from repro.core import MigrationPolicy, run_fleet_experiment

    scenarios = [
        ("parallel/ms2m@c2", "parallel", "ms2m_individual", 2, {}),
        ("parallel/ms2m@c4", "parallel", "ms2m_individual", 4, {}),
        ("parallel/precopy@c4", "parallel", "ms2m_precopy", 4, {}),
        # the compressed checkpoint data path at fleet scale: multi-chunk
        # blob states, delta rounds quantized (lossless exact flush)
        ("parallel/precopy+int8@c4", "parallel", "ms2m_precopy", 4,
         dict(policy=MigrationPolicy(compression="int8"),
              worker_factory=_blob_factory, chunk_bytes=64 * 1024)),
        ("parallel/adaptive@c4", "parallel", "ms2m_adaptive", 4, {}),
        ("rolling/statefulset", "rolling", "ms2m_statefulset", 1, {}),
        ("drain/ms2m@c4", "drain", "ms2m_individual", 4, {}),
    ]
    rows: List[Dict] = []
    for name, mode, strategy, conc, extra in scenarios:
        reps: List[Dict] = []
        for rep in range(repeats):
            with tempfile.TemporaryDirectory() as root:
                fleet = run_fleet_experiment(
                    n_pods, strategy, 8.0, registry_root=root, mode=mode,
                    max_concurrent=conc, seed=rep, num_nodes=4, **extra)
            reps.append(fleet.row())
        rows.append({
            "scenario": name,
            "mode": mode,
            "strategy": strategy,
            "n_pods": n_pods,
            "max_concurrent": conc,
            "span_mean": round(float(np.mean([r["span"] for r in reps])), 2),
            "max_downtime_mean": round(
                float(np.mean([r["max_downtime"] for r in reps])), 3),
            "peak_concurrency": max(r["peak_concurrency"] for r in reps),
            "raw_bytes_total": int(np.mean(
                [r["raw_bytes_total"] for r in reps])),
            "wire_bytes_total": int(np.mean(
                [r["wire_bytes_total"] for r in reps])),
            "wire_reduction": round(float(np.mean(
                [r["wire_reduction"] for r in reps])), 3),
            "all_verified": all(r["all_verified"] for r in reps),
        })
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


def main():
    for r in run_fleet(out_path="results/fleet_migration.json"):
        print(f"{r['scenario']}: {r['n_pods']} pods span={r['span_mean']}s "
              f"peak_conc={r['peak_concurrency']} "
              f"max_downtime={r['max_downtime_mean']}s "
              f"wire_reduction=x{r['wire_reduction']} "
              f"verified={r['all_verified']}")


if __name__ == "__main__":
    main()
