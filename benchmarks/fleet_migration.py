"""Cluster-scale migration scenarios: N-pod fleets through the
ClusterMigrationOrchestrator.

Scenarios (``run_fleet`` -> results/fleet_migration.json):
  * parallel individual-pod migration at different concurrency limits
    (span shrinks with concurrency; per-pod downtime stays MS2M-short);
  * rolling StatefulSet migration (sequential identity handoff);
  * node drain (evacuate every pod off one node).

Topology scenarios (``run_topology`` -> results/fleet_topology.json),
running over *contended* network topologies instead of the seed's
uncontended flat registry link:

  * concurrency sweep — N pre-copy migrations over one shared rack link:
    beyond link saturation the dirty set outruns the fair-shared
    bandwidth, pre-copy rounds stop converging, total wire bytes grow
    with concurrency and fleet span bends *upward* — the
    concurrency/span tradeoff the orchestrator exists to manage;
  * edge WAN — migrations onto an edge site behind a thin, high-latency
    WAN uplink: iterative pre-copy plus the int8 delta codec turns wire
    reduction into real downtime wins.

  PYTHONPATH=src python -m benchmarks.fleet_migration
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, List, Optional


def _blob_factory():
    from benchmarks.delta_precopy import BigStateConsumer
    return BigStateConsumer()


_CHURN_CLS = None

CHURN_BLOB = 1 << 19    # float32 elements = 2 MiB
CHURN_STRIPE = 1024     # float32 elements = 4 KiB per message


def churn_blob_factory():
    """Hash fold plus a pod-distinct 2 MiB random blob; every message
    dirties a 4 KiB stripe at a pseudo-random offset, so the dirty-byte
    rate tracks the message rate and content-addressed dedup cannot
    collapse different pods' images (each blob is seeded by the pod's
    first token).  This is the workload that makes a shared link *feel*
    fleet concurrency."""
    global _CHURN_CLS
    if _CHURN_CLS is None:
        import numpy as np
        from repro.core.workload import HashConsumer

        class ChurnBlobConsumer(HashConsumer):
            def __init__(self):
                super().__init__()
                self._seeded = False
                self.blob = np.zeros(CHURN_BLOB, dtype=np.float32)

            def process(self, msg):
                if not self._seeded:
                    # pod-distinct content, reproducible by the reference
                    # fold (same first message -> same seed)
                    self._seeded = True
                    self.blob = np.random.default_rng(
                        msg.payload["token"]).random(
                            len(self.blob)).astype(np.float32)
                tok = msg.payload["token"]
                i = ((msg.msg_id * 2654435761 + tok * 97)
                     % (len(self.blob) - CHURN_STRIPE))
                self.blob[i:i + CHURN_STRIPE] += 1.0 + (tok % 97) / 97.0
                super().process(msg)

            def state_tree(self):
                tree = super().state_tree()
                tree["blob"] = self.blob.copy()  # snapshot, no aliasing
                return tree

            def state_nbytes(self):
                # copy-free size probe (placement/adaptive telemetry):
                # blob + the four fold scalars
                return int(self.blob.nbytes) + 32

            def load_state(self, tree):
                super().load_state(tree)
                self.blob = np.array(tree["blob"], dtype=np.float32)
                self._seeded = True  # a restored blob must never reseed

            def state_equal(self, other, exact=True):
                return (super().state_equal(other, exact)
                        and np.array_equal(self.blob, other.blob))

        _CHURN_CLS = ChurnBlobConsumer
    return _CHURN_CLS()


def run_fleet(repeats: int = 2, n_pods: int = 6,
              out_path: Optional[str] = None) -> List[Dict]:
    import numpy as np

    from repro.core import MigrationPolicy, run_fleet_experiment

    scenarios = [
        ("parallel/ms2m@c2", "parallel", "ms2m_individual", 2, {}),
        ("parallel/ms2m@c4", "parallel", "ms2m_individual", 4, {}),
        ("parallel/precopy@c4", "parallel", "ms2m_precopy", 4, {}),
        # the compressed checkpoint data path at fleet scale: multi-chunk
        # blob states, delta rounds quantized (lossless exact flush)
        ("parallel/precopy+int8@c4", "parallel", "ms2m_precopy", 4,
         dict(policy=MigrationPolicy(compression="int8"),
              worker_factory=_blob_factory, chunk_bytes=64 * 1024)),
        ("parallel/adaptive@c4", "parallel", "ms2m_adaptive", 4, {}),
        ("rolling/statefulset", "rolling", "ms2m_statefulset", 1, {}),
        ("drain/ms2m@c4", "drain", "ms2m_individual", 4, {}),
    ]
    rows: List[Dict] = []
    for name, mode, strategy, conc, extra in scenarios:
        reps: List[Dict] = []
        for rep in range(repeats):
            with tempfile.TemporaryDirectory() as root:
                fleet = run_fleet_experiment(
                    n_pods, strategy, 8.0, registry_root=root, mode=mode,
                    max_concurrent=conc, seed=rep, num_nodes=4, **extra)
            reps.append(fleet.row())
        rows.append({
            "scenario": name,
            "mode": mode,
            "strategy": strategy,
            "n_pods": n_pods,
            "max_concurrent": conc,
            "span_mean": round(float(np.mean([r["span"] for r in reps])), 2),
            "max_downtime_mean": round(
                float(np.mean([r["max_downtime"] for r in reps])), 3),
            "peak_concurrency": max(r["peak_concurrency"] for r in reps),
            "raw_bytes_total": int(np.mean(
                [r["raw_bytes_total"] for r in reps])),
            "wire_bytes_total": int(np.mean(
                [r["wire_bytes_total"] for r in reps])),
            "wire_reduction": round(float(np.mean(
                [r["wire_reduction"] for r in reps])), 3),
            "all_verified": all(r["all_verified"] for r in reps),
        })
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


# ---------------------------------------------------------------------------
# Contended-topology scenarios
# ---------------------------------------------------------------------------

def _shared_rack(node_names, registry_bw_Bps):
    """One zone, one *shared* fair-share link to the registry — the
    minimal topology where fleet concurrency has a price."""
    from repro.cluster.network import LinkSpec, NetworkTopology

    return NetworkTopology(
        "shared_rack", {n: "rack" for n in node_names}, "rack",
        {"intra": LinkSpec(registry_bw_Bps, latency_s=0.01)})


def _contended_timings(registry_bw_Bps):
    """Fast control plane, byte-dominated transfers: the regime where the
    network model matters (cf. delta_precopy.WAN_TIMINGS)."""
    from repro.cluster.cluster import TimingConstants

    return TimingConstants(
        checkpoint_s=1.0, image_build_s=1.0, delta_build_s=0.5,
        push_base_s=0.3, pull_base_s=0.3, restore_s=1.0,
        pod_create_s=0.5, pod_delete_s=0.5, sts_identity_release_s=0.5,
        registry_bw_Bps=registry_bw_Bps)


def run_topology(repeats: int = 2, quick: bool = False,
                 out_path: Optional[str] = None) -> List[Dict]:
    """Two contended-network scenario families (one JSON, flat rows):

    * ``sweep@cK`` — 6 pre-copy migrations of churn-blob pods over one
      shared 1 MB/s rack link at ``max_concurrent=K``.  Below saturation,
      concurrency pipelines fixed costs and the span drops; beyond it the
      fair-shared link stretches every pre-copy round, the dirty set stops
      converging, total wire bytes grow with K and the span bends upward.
    * ``edge_wan/<scheme>`` — migrations onto an edge site behind a thin
      (0.5 MB/s, 300 ms) WAN uplink: stop-and-copy vs stop-then-replay vs
      iterative pre-copy vs pre-copy + int8 delta codec.  The codec's wire
      reduction is real downtime reduction on a link this thin.
    """
    import numpy as np

    from repro.core import MigrationPolicy, run_fleet_experiment

    rows: List[Dict] = []

    def wan_bytes(row) -> int:
        return sum(link["total_bytes"]
                   for link in row["network"].get("links", [])
                   if link["name"].startswith("wan"))

    def aggregate(scenario, topology, strategy, conc, reps):
        rows.append({
            "scenario": scenario,
            "topology": topology,
            "strategy": strategy,
            "max_concurrent": conc,
            "n_pods": reps[0]["n_migrated"],
            # run_fleet_experiment asserts failures==0, so this is a
            # tripwire for future harness paths, not a live statistic
            "n_failed": max(r["n_failed"] for r in reps),
            "span_mean": round(float(np.mean([r["span"] for r in reps])), 2),
            "max_downtime_mean": round(
                float(np.mean([r["max_downtime"] for r in reps])), 3),
            "wire_bytes_total": int(np.mean(
                [r["wire_bytes_total"] for r in reps])),
            "wan_bytes_total": int(np.mean([wan_bytes(r) for r in reps])),
            "all_verified": all(r["all_verified"] for r in reps),
            "network": reps[-1]["network"],  # per-link detail, last repeat
        })

    # -- concurrency sweep on one shared rack link ---------------------------
    sweep_conc = (1, 4) if quick else (1, 2, 4, 6)
    n_pods = 4 if quick else 6
    sweep_policy = MigrationPolicy(precopy_max_rounds=8,
                                   precopy_converge_ratio=2.0,
                                   precopy_min_dirty=4)
    for conc in sweep_conc:
        reps = []
        for rep in range(repeats):
            with tempfile.TemporaryDirectory() as root:
                fleet = run_fleet_experiment(
                    n_pods, "ms2m_precopy", 10.0, registry_root=root,
                    mode="parallel", max_concurrent=conc, seed=rep,
                    num_nodes=4, timings=_contended_timings(1e6),
                    worker_factory=churn_blob_factory,
                    chunk_bytes=16 * 1024, topology=_shared_rack,
                    policy=sweep_policy)
            reps.append(fleet.row())
        aggregate(f"sweep@c{conc}", "shared_rack", "ms2m_precopy", conc,
                  reps)

    # -- edge WAN: wire reduction -> downtime reduction ----------------------
    edge_schemes = [
        ("stop_and_copy", "stop_and_copy", MigrationPolicy()),
        ("stop_then_replay", "ms2m_statefulset", MigrationPolicy()),
        ("precopy", "ms2m_statefulset",
         MigrationPolicy(precopy=True, precopy_max_rounds=4)),
        ("precopy+int8", "ms2m_statefulset",
         MigrationPolicy(precopy=True, precopy_max_rounds=4,
                         compression="int8")),
    ]
    if quick:
        edge_schemes = [edge_schemes[1], edge_schemes[3]]
    for label, strategy, policy in edge_schemes:
        reps = []
        for rep in range(repeats):
            with tempfile.TemporaryDirectory() as root:
                fleet = run_fleet_experiment(
                    4, strategy, 8.0, registry_root=root,
                    mode="parallel", max_concurrent=4, seed=rep,
                    num_nodes=4, timings=_contended_timings(10e6),
                    worker_factory=churn_blob_factory,
                    chunk_bytes=16 * 1024, topology="edge_wan",
                    policy=policy)
            reps.append(fleet.row())
        aggregate(f"edge_wan/{label}", "edge_wan", strategy, 4, reps)

    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


def main():
    for r in run_topology(out_path="results/fleet_topology.json"):
        print(f"{r['scenario']}: span={r['span_mean']}s "
              f"max_downtime={r['max_downtime_mean']}s "
              f"wire={r['wire_bytes_total']}B wan={r['wan_bytes_total']}B "
              f"verified={r['all_verified']}")
    for r in run_fleet(out_path="results/fleet_migration.json"):
        print(f"{r['scenario']}: {r['n_pods']} pods span={r['span_mean']}s "
              f"peak_conc={r['peak_concurrency']} "
              f"max_downtime={r['max_downtime_mean']}s "
              f"wire_reduction=x{r['wire_reduction']} "
              f"verified={r['all_verified']}")


if __name__ == "__main__":
    main()
