"""Serving-migration benchmark: tail latency under migration, scheme x
topology.

An open-loop Poisson request stream (requests keep arriving no matter how
slow the service is — queueing delay lands in the latency tail instead of
being hidden by backpressure) drives a slot-based serving worker while one
migration runs.  Three schemes:

  * ``serving_handoff``   — the dual-serving KV-cache handoff (ours):
                            per-slot-aligned delta pre-copy, both replicas
                            decode through the window, per-slot in-flight
                            handoff at a ~1.4 s cutover;
  * ``ms2m_statefulset``  — stop-then-replay: the paper's sticky-identity
                            scheme; the source stops for the whole
                            restore+replay window, queueing ~λ·T_down
                            requests;
  * ``stop_and_copy``     — the cold baseline; downtime spans the whole
                            checkpoint/push/pull/restore pipeline.

over two topologies (``flat``, ``edge_wan``), p50/p99/p999 pooled across
repeat seeds.  Every run is state-verified (bit-exact reference fold) and
exactly-once audited (zero lost, zero duplicated completions — replayed
finishes are deduped by the completion ledger and reported separately).
One extra row injects a mid-handoff target-node fault with retry enabled:
the handoff must roll back to the still-serving source, recover on a
later attempt, and keep the exactly-once guarantee throughout.

  PYTHONPATH=src python -m benchmarks.serving_handoff          # full
  PYTHONPATH=src python -m benchmarks.serving_handoff --quick  # CI smoke

Output: results/serving_handoff.json — per (scheme, topology) one row
with the latency summary, downtime, and the audit columns, plus the
fault-injection row and a ``p99_win`` verdict per topology.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
from typing import Dict, List, Optional

from benchmarks.stats import latency_summary

SCHEMES = ("serving_handoff", "ms2m_statefulset", "stop_and_copy")
TOPOLOGIES = ("flat", "edge_wan")
RATE = 8.0


def _run_cell(scheme: str, topology: str, seeds, **kw) -> Dict:
    """Pooled-latency row for one (scheme, topology) cell."""
    from repro.serving.handoff import run_serving_experiment

    latencies: List[float] = []
    downtimes: List[float] = []
    published = delivered = duplicates = lost = 0
    exactly_once = state_verified = True
    for seed in seeds:
        with tempfile.TemporaryDirectory() as root:
            r = run_serving_experiment(
                scheme, RATE, registry_root=root, seed=seed,
                topology=topology, **kw)
        latencies.extend(r.latencies)
        downtimes.append(r.downtime)
        published += r.published
        delivered += r.delivered
        duplicates += r.duplicates
        lost += r.lost
        exactly_once = exactly_once and r.exactly_once
        state_verified = state_verified and bool(r.state_verified)
    return {
        "scheme": scheme,
        "topology": topology,
        "rate": RATE,
        "seeds": list(seeds),
        "latency": latency_summary(latencies),
        "downtime_mean": round(sum(downtimes) / len(downtimes), 3),
        "published": published,
        "delivered": delivered,
        "duplicates": duplicates,
        "lost": lost,
        "exactly_once": exactly_once,
        "state_verified": state_verified,
    }


def _run_fault_row(quick: bool) -> Dict:
    """serving_handoff under an injected mid-handoff fault: the target
    node flaps the moment the dual-serving window opens (both replicas
    decoding), the attempt rolls back to the still-serving source, and a
    retry completes the handoff — with the exactly-once audit still
    green."""
    from repro.cluster.faults import parse_fault
    from repro.core.policy import MigrationPolicy
    from repro.serving.handoff import run_serving_experiment

    with tempfile.TemporaryDirectory() as root:
        r = run_serving_experiment(
            "serving_handoff", RATE, registry_root=root, seed=0,
            faults=[parse_fault(
                "node_flap@dual_serving_begin,node=node1,duration=5")],
            policy=MigrationPolicy(max_attempts=3, retry_backoff_s=1.0),
            allow_failure=True,
            settle_time=3.0 if quick else 5.0)
    return {
        "scheme": "serving_handoff",
        "topology": "flat",
        "fault": "node_flap@dual_serving_begin",
        "rate": RATE,
        "failed": r.failed,
        "attempts": (r.report.attempts if r.report is not None
                     else (r.failure or {}).get("attempts")),
        "recovered": not r.failed,
        "latency": latency_summary(r.latencies),
        "downtime": round(r.downtime, 3),
        "published": r.published,
        "delivered": r.delivered,
        "duplicates": r.duplicates,
        "lost": r.lost,
        "exactly_once": r.exactly_once,
        "state_verified": r.state_verified,
    }


def run_serving_bench(quick: bool = False,
                      out_path: Optional[str] = None) -> List[Dict]:
    seeds = range(1) if quick else range(3)
    kw = dict(settle_time=3.0) if quick else {}
    rows: List[Dict] = []
    for topology in TOPOLOGIES:
        for scheme in SCHEMES:
            rows.append(_run_cell(scheme, topology, seeds, **kw))
        # the headline verdict: dual-serving handoff beats stop-then-replay
        # on tail latency on this topology
        p99 = {r["scheme"]: r["latency"]["p99"] for r in rows
               if r["topology"] == topology}
        rows.append({
            "scheme": "VERDICT",
            "topology": topology,
            "p99_handoff": p99["serving_handoff"],
            "p99_stop_then_replay": p99["ms2m_statefulset"],
            "p99_cold": p99["stop_and_copy"],
            "p99_win": p99["serving_handoff"] < p99["ms2m_statefulset"],
        })
    rows.append(_run_fault_row(quick))
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    for r in run_serving_bench(quick=args.quick,
                               out_path="results/serving_handoff.json"):
        if r["scheme"] == "VERDICT":
            print(f"[{r['topology']}] p99: handoff={r['p99_handoff']}s "
                  f"stop_then_replay={r['p99_stop_then_replay']}s "
                  f"cold={r['p99_cold']}s win={r['p99_win']}")
            continue
        lat = r["latency"]
        tag = f" fault={r['fault']}" if "fault" in r else ""
        print(f"{r['scheme']}@{r['topology']}{tag}: "
              f"p50={lat['p50']} p99={lat['p99']} p999={lat['p999']} "
              f"exactly_once={r['exactly_once']} "
              f"state_verified={r['state_verified']} "
              f"duplicates={r['duplicates']} lost={r['lost']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
