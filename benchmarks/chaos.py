"""Chaos benchmark: seeded fault schedules vs migration scheme.

Sweeps randomized :class:`repro.cluster.faults.FaultSchedule`s (node
crashes and flaps of the target node, registry-link degradation, registry
outages, broker stalls) against fleet migrations under three schemes with
retry enabled, and checks the crash-consistency invariant on every run:

  * every completed migration is ``state_verified`` (bit-exact against an
    independent reference fold — no message loss or duplication), and
  * every exhausted-retries failure was rolled back with its source pod
    still serving and drain-consistent (``source_verified``).

The scheme comparison answers the exposure question: iterative pre-copy
keeps downtime short but its longer transfer window is exposed to churn
for longer, so under fault pressure it retries more than the
stop-then-replay scheme whose window is short — ``exposure_s`` (mean
migration span) vs ``attempts``/``recovered`` makes the tradeoff visible.

Determinism: for every (scheme, level) cell one seed is run twice and the
two ``FleetReport.row()`` dicts must match bit-for-bit
(``deterministic`` in the output row).

  PYTHONPATH=src python -m benchmarks.chaos         # full sweep
  ...run.py --quick runs the trimmed profile (still >= 100 schedules)

Output: results/chaos.json — one row per (scheme, fault level) with the
per-seed outcome list and the aggregate columns above.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, List, Optional

# faults per schedule by pressure level
FAULT_LEVELS = {"calm": 1, "stormy": 3}

SCHEMES = ("ms2m_individual", "ms2m_precopy", "ms2m_statefulset")


def _chaos_schedule(seed: int, n_faults: int, n_pods: int, num_nodes: int):
    """Target-side-only schedule: faults hit the reserved target node, the
    registry, its link and the queues — never a source node directly, so
    the rollback guarantee (source serving again) is always testable."""
    from repro.cluster.faults import FaultSchedule

    target = f"node{num_nodes - 1}"
    return FaultSchedule.random(
        seed, n_faults=n_faults, t_window=(11.0, 70.0),
        nodes=(target,),
        queues=tuple(f"orders-{i}" for i in range(n_pods)))


def _run_one(scheme: str, seed: int, n_faults: int, *,
             n_pods: int = 2, num_nodes: int = 4) -> Dict:
    from repro.core import MigrationPolicy, run_fleet_experiment

    schedule = _chaos_schedule(seed, n_faults, n_pods, num_nodes)
    mode = "rolling" if scheme == "ms2m_statefulset" else "parallel"
    with tempfile.TemporaryDirectory() as root:
        fleet = run_fleet_experiment(
            n_pods, scheme, 8.0, registry_root=root, mode=mode,
            max_concurrent=2, seed=seed, num_nodes=num_nodes,
            faults=schedule, allow_failures=True,
            policy=MigrationPolicy(max_attempts=3, retry_backoff_s=1.0))
    row = fleet.row()
    ok = all(r.state_verified for r in fleet.reports)
    for f in fleet.failures:
        ok = ok and bool(f.get("rolled_back") and f.get("source_serving")
                         and f.get("source_verified"))
    return {"seed": seed, "row": row, "invariant_ok": bool(ok),
            "schedule": schedule.rows()}


def run_chaos(quick: bool = False,
              out_path: Optional[str] = None) -> List[Dict]:
    import numpy as np

    from benchmarks.stats import summarize_spans

    seeds_per_cell = 17 if quick else 25
    rows: List[Dict] = []
    total = invariant_fails = 0
    for scheme in SCHEMES:
        for level, n_faults in FAULT_LEVELS.items():
            outcomes = []
            for k in range(seeds_per_cell):
                seed = 10_000 * n_faults + k
                outcomes.append(_run_one(scheme, seed, n_faults))
            total += len(outcomes)
            invariant_fails += sum(1 for o in outcomes
                                   if not o["invariant_ok"])
            # same-seed reproducibility: the first seed, run again, must
            # produce a bit-identical fleet row
            rerun = _run_one(scheme, outcomes[0]["seed"], n_faults)
            deterministic = (json.dumps(rerun["row"], sort_keys=True)
                             == json.dumps(outcomes[0]["row"],
                                           sort_keys=True))
            rs = [o["row"] for o in outcomes]
            rows.append({
                "scheme": scheme,
                "fault_level": level,
                "faults_per_run": n_faults,
                "runs": len(outcomes),
                "n_migrated": sum(r["n_migrated"] for r in rs),
                "n_failed": sum(r["n_failed"] for r in rs),
                "attempts": sum(r["attempts"] for r in rs),
                "recovered": sum(r["recovered"] for r in rs),
                "exposure_s": round(float(np.mean(
                    [r["span"] for r in rs])), 2),
                # distribution shape across the seed sweep, not just the
                # mean (deterministic interpolation: benchmarks.stats)
                **{f"exposure_{k}": v for k, v in summarize_spans(
                    [r["span"] for r in rs]).items()},
                "max_downtime_mean": round(float(np.mean(
                    [r["max_downtime"] for r in rs])), 3),
                "invariant_ok": all(o["invariant_ok"] for o in outcomes),
                "deterministic": deterministic,
                "seeds": [o["seed"] for o in outcomes],
            })
    summary = {
        "scheme": "ALL",
        "fault_level": "summary",
        "runs": total,
        "invariant_ok": invariant_fails == 0,
        "deterministic": all(r["deterministic"] for r in rows),
    }
    rows.append(summary)
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=2)
    return rows


def main():
    for r in run_chaos(out_path="results/chaos.json"):
        if r["fault_level"] == "summary":
            print(f"TOTAL: {r['runs']} schedules "
                  f"invariant_ok={r['invariant_ok']} "
                  f"deterministic={r['deterministic']}")
            continue
        print(f"{r['scheme']}@{r['fault_level']}: "
              f"{r['n_migrated']} ok / {r['n_failed']} failed, "
              f"attempts={r['attempts']} recovered={r['recovered']} "
              f"exposure={r['exposure_s']}s "
              f"invariant_ok={r['invariant_ok']} "
              f"deterministic={r['deterministic']}")


if __name__ == "__main__":
    main()
