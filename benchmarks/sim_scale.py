"""Fleet-scale sim engine benchmark: epoch-batched vs per-message.

Measures the discrete-event kernel itself, not migration policy: how fast
the simulator pushes steady-state message traffic through consumer pods.

  steady_1k   1k pods, constant-gap traffic, no migrations.  The fluid
              engine advances each pod analytically per epoch; the
              per-message baseline (``REPRO_SIM_FLUID=0`` semantics, here
              ``Cluster(fluid=False)``) pays one heap event per arrival
              and per completion.  ``speedup`` is the headline ratio and
              the CI regression gate.
  poisson_1k  same fleet with per-message Poisson draws + token RNG — the
              honest variant: the two interleaved RNG draws per message
              are irreducible (bit-identity pins the stream order), so
              the speedup here bounds what real harnesses see.
  smoke_10k   10k pods / >= 1M messages, fluid only, service logs off —
              the scale acceptance gate (budget: 120 s wall).
  chaos_seed  one seeded fault-schedule fleet run (crashes, flaps, stalls,
              registry outages) timed wall-clock with the chaos suite's
              crash-consistency invariant checked.
  census      opt-in event-census counters (``Sim(census=True)``) for the
              steady fluid run — where the remaining heap events go.

Determinism is asserted here too: the steady fluid fleet is run twice and
the ``fleet_state()`` arrays must match exactly.

  PYTHONPATH=src python -m benchmarks.sim_scale            # full profile
  PYTHONPATH=src python -m benchmarks.sim_scale --quick    # CI smoke
  ... --check-baseline   # fail if speedup < 0.8x committed baseline

Output: results/BENCH_sim.json (schema: docs/scaling.md).  The committed
reference lives at benchmarks/baselines/BENCH_sim.json; the gate compares
speedup ratios, not absolute events/sec, so it is machine-independent.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from typing import Dict, Optional

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "baselines",
                             "BENCH_sim.json")
# fail --check-baseline when speedup drops below this fraction of the
# committed reference ratio (>20% regression)
BASELINE_TOLERANCE = 0.8
SMOKE_BUDGET_S = 120.0


def _steady_fleet(n_pods: int, rate: float, duration: float, *,
                  fluid: bool, poisson: bool = False, census: bool = False,
                  keep_log: bool = True, processing_ms: float = 5.0,
                  warm: float = 2.0, seed: int = 0) -> Dict:
    """Run ``n_pods`` consumers on steady traffic for ``duration`` sim
    seconds (after a ``warm`` boot window) and report wall-clock cost.

    Constant-gap draws isolate kernel cost; ``poisson=True`` switches to
    the harnesses' open-loop Poisson + token-RNG draws (two RNG calls per
    message, stream order pinned by bit-identity with the seed)."""
    import numpy as np

    from repro.cluster.cluster import Cluster
    from repro.core.workload import HashConsumer, open_loop_gaps

    with tempfile.TemporaryDirectory() as root:
        cluster = Cluster(root, num_nodes=max(2, min(16, n_pods // 64 + 2)),
                          fluid=fluid, census=census)
        sim, api, broker = cluster.sim, cluster.api, cluster.broker
        num_nodes = len(api.nodes)
        pods = []

        for i in range(n_pods):
            queue = broker.declare_queue(f"q-{i}")
            if poisson:
                rng = np.random.default_rng(seed * 1009 + i)
                gaps = open_loop_gaps(rng, rate)

                def draw(rng=rng, gaps=gaps):
                    return next(gaps), {"token": int(rng.integers(0, 2048))}
            else:
                gap = 1.0 / rate
                payload = {"token": i & 2047}  # read-only; shared per pod

                def draw(gap=gap, payload=payload):
                    return gap, payload
            queue.attach_source(draw)

            def boot(i=i, queue=queue):
                pod = yield from api.create_pod(
                    f"bench-{i}", f"node{i % num_nodes}", HashConsumer(),
                    queue, processing_ms=processing_ms)
                pod.keep_service_log = keep_log
                pod.start()
                pods.append(pod)

            sim.process(boot(), name=f"boot-{i}")

        sim.run(until=warm)
        state0 = api.fleet_state()
        n0 = int(state0["n_processed"].sum())
        # the timed window includes the terminal fleet_state(): in fluid
        # mode that folds every open epoch plan, so deferred per-message
        # work is paid inside the measurement, not smuggled past it
        t0 = time.perf_counter()
        sim.run(until=warm + duration)
        state = api.fleet_state()
        wall = time.perf_counter() - t0
        msgs = int(state["n_processed"].sum()) - n0
        stats = sim.stats()
        return {
            "n_pods": n_pods,
            "rate_per_pod": rate,
            "sim_seconds": duration,
            "messages": msgs,
            "wall_s": round(wall, 4),
            "msgs_per_wall_s": round(msgs / wall, 1) if wall > 0 else None,
            "heap_events": stats["events_total"],
            "census": stats["events"] if census else None,
            "fingerprint": {
                "digest_sum": int(np.uint64(0) + state["last_msg_id"].sum()),
                "n_processed": int(state["n_processed"].sum()),
            },
        }


def _smoke_10k(n_pods: int, rate: float, duration: float,
               min_msgs: int = 1_000_000, seed: int = 0) -> Dict:
    """Scale smoke: fluid engine, logs off — must fit SMOKE_BUDGET_S."""
    t0 = time.perf_counter()
    res = _steady_fleet(n_pods, rate, duration, fluid=True, keep_log=False,
                        seed=seed)
    wall_total = time.perf_counter() - t0
    res["wall_total_s"] = round(wall_total, 2)  # includes boot + teardown
    res["budget_s"] = SMOKE_BUDGET_S
    res["min_msgs"] = min_msgs
    res["ok"] = bool(wall_total < SMOKE_BUDGET_S
                     and res["messages"] >= min_msgs)
    return res


def _chaos_seed(n_pods: int, *, seed: int = 3, num_nodes: int = 8) -> Dict:
    """One seeded fault-schedule fleet migration run, timed wall-clock,
    with the chaos suite's rollback/verification invariant checked."""
    from benchmarks.chaos import _chaos_schedule

    from repro.core import MigrationPolicy, run_fleet_experiment

    schedule = _chaos_schedule(seed, 3, n_pods, num_nodes)
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as root:
        fleet = run_fleet_experiment(
            n_pods, "ms2m_individual", 4.0, registry_root=root,
            mode="parallel", max_concurrent=8, seed=seed,
            num_nodes=num_nodes, faults=schedule, allow_failures=True,
            policy=MigrationPolicy(max_attempts=3, retry_backoff_s=1.0))
    wall = time.perf_counter() - t0
    ok = all(r.state_verified for r in fleet.reports)
    for f in fleet.failures:
        ok = ok and bool(f.get("rolled_back") and f.get("source_serving")
                         and f.get("source_verified"))
    return {"n_pods": n_pods, "seed": seed, "wall_s": round(wall, 2),
            "n_migrated": fleet.n_migrated, "n_failed": fleet.n_failed,
            "invariant_ok": bool(ok)}


def run_sim_scale(quick: bool = False,
                  out_path: Optional[str] = None) -> Dict:
    if quick:
        steady = dict(n_pods=1000, rate=8.0, duration=10.0)
        poisson = dict(n_pods=256, rate=8.0, duration=6.0)
        smoke = dict(n_pods=2000, rate=2.0, duration=30.0,
                     min_msgs=100_000)
        chaos_pods = 64
    else:
        steady = dict(n_pods=1000, rate=8.0, duration=30.0)
        poisson = dict(n_pods=512, rate=8.0, duration=15.0)
        smoke = dict(n_pods=10_000, rate=2.0, duration=52.0)
        # migration cost grows superlinearly with fleet size (every open
        # migration syncs against all active sources): 256 pods keeps the
        # full profile under ~2 min for this stage
        chaos_pods = 256

    out: Dict = {"quick": quick}

    # service logs off: the kernel benchmark measures the engine, not the
    # application-level audit trail (both modes honor keep_service_log)
    fluid = _steady_fleet(**steady, fluid=True, census=True, keep_log=False)
    fluid2 = _steady_fleet(**steady, fluid=True, keep_log=False)
    assert fluid["fingerprint"] == fluid2["fingerprint"], \
        "steady fluid fleet not deterministic across runs"
    base = _steady_fleet(**steady, fluid=False, keep_log=False)
    assert fluid["fingerprint"] == base["fingerprint"], \
        "fluid vs per-message fleet state diverged"
    speedup = fluid["msgs_per_wall_s"] / base["msgs_per_wall_s"]
    out["steady_1k"] = {"fluid": fluid, "baseline": base,
                        "speedup": round(speedup, 2)}
    out["census"] = fluid["census"]

    pf = _steady_fleet(**poisson, fluid=True, poisson=True, keep_log=False)
    pb = _steady_fleet(**poisson, fluid=False, poisson=True, keep_log=False)
    assert pf["fingerprint"] == pb["fingerprint"], \
        "fluid vs per-message diverged under Poisson traffic"
    out["poisson"] = {
        "fluid": pf, "baseline": pb,
        "speedup": round(pf["msgs_per_wall_s"] / pb["msgs_per_wall_s"], 2)}

    out["smoke_10k"] = _smoke_10k(**smoke)
    out["chaos_seed"] = _chaos_seed(chaos_pods)

    path = out_path or os.path.join("results", "BENCH_sim.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(out, fh, indent=2)
    return out


def check_baseline(out: Dict, baseline_path: str = BASELINE_PATH) -> bool:
    """Machine-independent regression gate: the fluid/per-message speedup
    ratio must stay within BASELINE_TOLERANCE of the committed one."""
    if not os.path.exists(baseline_path):
        print(f"sim_scale: no baseline at {baseline_path}; gate skipped")
        return True
    with open(baseline_path) as fh:
        ref = json.load(fh)
    ok = True
    for key in ("steady_1k", "poisson"):
        ref_speedup = ref.get(key, {}).get("speedup")
        cur_speedup = out.get(key, {}).get("speedup")
        if not ref_speedup or not cur_speedup:
            continue
        floor = BASELINE_TOLERANCE * ref_speedup
        line = (f"sim_scale[{key}]: speedup {cur_speedup:.1f}x "
                f"(baseline {ref_speedup:.1f}x, floor {floor:.1f}x)")
        if cur_speedup < floor:
            print(line + " REGRESSION", file=sys.stderr)
            ok = False
        else:
            print(line + " ok")
    if out.get("smoke_10k") and not out["smoke_10k"]["ok"]:
        print(f"sim_scale[smoke]: {out['smoke_10k']}", file=sys.stderr)
        ok = False
    if out.get("chaos_seed") and not out["chaos_seed"]["invariant_ok"]:
        print(f"sim_scale[chaos]: invariant failed {out['chaos_seed']}",
              file=sys.stderr)
        ok = False
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check-baseline", action="store_true",
                    help="fail if speedup regresses >20%% vs the "
                         "committed baseline")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    out = run_sim_scale(quick=args.quick, out_path=args.out)
    s = out["steady_1k"]
    print(f"steady_1k: fluid {s['fluid']['msgs_per_wall_s']:.0f} msg/s, "
          f"baseline {s['baseline']['msgs_per_wall_s']:.0f} msg/s, "
          f"speedup {s['speedup']:.1f}x")
    print(f"poisson:   speedup {out['poisson']['speedup']:.1f}x")
    sm = out["smoke_10k"]
    print(f"smoke:     {sm['n_pods']} pods, {sm['messages']} msgs in "
          f"{sm['wall_total_s']:.1f}s (ok={sm['ok']})")
    ch = out["chaos_seed"]
    print(f"chaos:     {ch['n_pods']} pods seed {ch['seed']} in "
          f"{ch['wall_s']:.1f}s (invariant_ok={ch['invariant_ok']})")
    if args.check_baseline and not check_baseline(out):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
