"""Roofline tables: dry-run model terms and measured codec throughput.

Two entry points:

  * the dry-run table (default) reads results/dryrun.json (written by
    repro.launch.dryrun) and prints the three terms per
    (arch x shape x mesh), the dominant bottleneck, the
    MODEL_FLOPS/HLO_FLOPS usefulness ratio, and a one-line "what would
    move the dominant term" suggestion;
  * ``--codec`` measures the checkpoint data path itself — fingerprint
    and fingerprint+encode bytes/s per chunk size, legacy two-pass flow
    vs the fused kernel path — and writes results/codec_roofline.json
    (schema in docs/kernels.md).  ``TimingConstants.from_roofline``
    consumes the calibration block.  ``--devices N`` applies the
    ``xla_force_host_platform_device_count`` idiom (must happen before
    the first jax import, hence the lazy imports below) so multi-device
    CPU numbers are honest about the host they ran on.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

SUGGESTIONS = {
    ("compute",): "increase per-chip batch or fuse small ops (MXU underfed)",
    ("memory",): "bf16 intermediates + flash tiling cut bytes; check remat "
                 "recompute and f32 attention buffers",
    ("collective",): "reshard: move FSDP all-gathers to bf16, overlap with "
                     "compute, or shard activations instead of replicating",
}


def load(path: str) -> List[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            rows.append(json.loads(line))
    return rows


def fmt_row(r: dict) -> str:
    if r["status"] != "OK":
        reason = r.get("reason", r.get("error", ""))[:60]
        return (f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} "
                f"{r['status']:5s} {reason}")
    rl = r["roofline"]
    dom = rl["dominant"]
    frac = rl["useful_flops_ratio"]
    return (f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} OK    "
            f"c={rl['compute_s']*1e3:9.1f}ms m={rl['memory_s']*1e3:9.1f}ms "
            f"x={rl['collective_s']*1e3:9.1f}ms dom={dom:10s} "
            f"useful={frac:5.2f}")


# ---------------------------------------------------------------------------
# measured codec roofline (the checkpoint data path itself)
# ---------------------------------------------------------------------------

CODEC_CHUNK_SIZES = (4 * 1024, 64 * 1024, 1024 * 1024)


def configure_host_devices(n: int) -> None:
    """Pre-jax-import platform config (SNIPPETS.md idiom): virtual CPU
    devices only exist if the flag lands before jax initializes."""
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    if n > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())


def _geomean(xs: List[float]) -> float:
    import numpy as np

    return float(np.exp(np.mean(np.log(np.asarray(xs)))))


def run_codec_roofline(chunk_sizes=CODEC_CHUNK_SIZES, leaf_mib: int = 16,
                       repeats: int = 3, quick: bool = False,
                       out_path: str = "results/codec_roofline.json"
                       ) -> dict:
    """Measure fingerprint / fingerprint+encode throughput per chunk size.

    One striped-dirty f32 leaf; per chunk size, best-of-``repeats`` wall
    time (after a warmup that absorbs jit compilation) for:

      * ``fingerprint`` — the device fingerprint pass alone;
      * ``encode_<codec>`` — the host codec encoders alone (what
        ``TimingConstants.codec_Bps`` charges);
      * ``fp+encode_<codec>`` twice — the legacy ``two_pass`` flow
        (fingerprint pass, then serialize + host-encode every chunk) vs
        the ``fused`` single-pass kernel path the registry now uses.

    Returns (and writes) the result dict; the ``calibration`` block holds
    geomean throughputs shaped for ``TimingConstants.from_roofline``.
    """
    import numpy as np

    import jax

    from repro.checkpoint.codecs import FusedLeafEncoding, get_codec
    from repro.kernels import ops

    if quick:
        leaf_mib, repeats = 4, 1
    rng = np.random.default_rng(0)
    n = leaf_mib * (1 << 20) // 4
    cur = rng.standard_normal(n).astype(np.float32)
    parent = cur.copy()
    idx = rng.integers(0, n, size=n // 64)
    parent[idx] += rng.standard_normal(idx.size).astype(np.float32)
    praw = parent.tobytes()
    nbytes = cur.nbytes
    dt = np.dtype(np.float32)

    def bench(fn) -> float:
        fn()  # warmup: jit compile + first-touch
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    rows: List[dict] = []

    def add(op: str, path: str, cb: int, elapsed: float):
        rows.append({"op": op, "path": path, "chunk_bytes": cb,
                     "elapsed_s": round(elapsed, 6),
                     "bytes_per_s": round(nbytes / elapsed, 1)})

    for cb in chunk_sizes:
        n_chunks = -(-nbytes // cb)

        def fp_pass():
            np.asarray(ops.chunk_fingerprint(cur, cb))

        add("fingerprint", "device", cb, bench(fp_pass))
        for name in ("xor_rle", "int8"):
            codec = get_codec(name)
            raw = cur.tobytes()

            def encode_only():
                for c in range(n_chunks):
                    codec.encode(raw[c * cb: (c + 1) * cb],
                                 praw[c * cb: (c + 1) * cb], dt)

            def two_pass():
                np.asarray(ops.chunk_fingerprint(cur, cb))
                data = cur.tobytes()
                for c in range(n_chunks):
                    codec.encode(data[c * cb: (c + 1) * cb],
                                 praw[c * cb: (c + 1) * cb], dt)

            def fused():
                fenc = FusedLeafEncoding(cur, praw, name, dt, cb)
                for c in range(n_chunks):
                    fenc.blob(c)

            add(f"encode_{name}", "host", cb, bench(encode_only))
            add(f"fp+encode_{name}", "two_pass", cb, bench(two_pass))
            add(f"fp+encode_{name}", "fused", cb, bench(fused))

    result = {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "leaf_bytes": nbytes,
        "repeats": repeats,
        "chunk_sizes": list(chunk_sizes),
        "rows": rows,
        "calibration": {
            "codec_Bps": _geomean([r["bytes_per_s"] for r in rows
                                   if r["op"].startswith("encode_")]),
            "fingerprint_Bps": _geomean([r["bytes_per_s"] for r in rows
                                         if r["op"] == "fingerprint"]),
            # the cost-model defaults these would replace (see
            # TimingConstants.from_roofline: replacing them is opt-in —
            # regression timelines stay pinned to the defaults)
            "defaults": {"codec_Bps": 1.2e9, "fingerprint_Bps": 24e9},
        },
    }
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--input", default="results/dryrun.json")
    ap.add_argument("--mesh", default="all")
    ap.add_argument("--codec", action="store_true",
                    help="measure the codec roofline instead of printing "
                         "the dry-run table")
    ap.add_argument("--devices", type=int, default=1,
                    help="virtual CPU device count for --codec (set via "
                         "xla_force_host_platform_device_count before "
                         "jax loads)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="results/codec_roofline.json")
    args = ap.parse_args(argv)
    if args.codec:
        configure_host_devices(args.devices)
        res = run_codec_roofline(quick=args.quick, out_path=args.out)
        print(f"{'op':22s} {'path':9s} {'chunk':>9s} {'MB/s':>10s}")
        for r in res["rows"]:
            print(f"{r['op']:22s} {r['path']:9s} {r['chunk_bytes']:9d} "
                  f"{r['bytes_per_s'] / 1e6:10.1f}")
        cal = res["calibration"]
        print(f"\ncalibration: codec_Bps={cal['codec_Bps']:.3g} "
              f"fingerprint_Bps={cal['fingerprint_Bps']:.3g} "
              f"(defaults {cal['defaults']['codec_Bps']:.3g}/"
              f"{cal['defaults']['fingerprint_Bps']:.3g}) "
              f"-> {args.out}")
        return 0
    rows = load(args.input)
    if args.mesh != "all":
        rows = [r for r in rows if r.get("mesh") == args.mesh]
    print(f"{'arch':26s} {'shape':12s} {'mesh':8s} stat  terms (per-chip)")
    for r in rows:
        print(fmt_row(r))
    ok = [r for r in rows if r["status"] == "OK"]
    if ok:
        doms: Dict[str, int] = {}
        for r in ok:
            doms[r["roofline"]["dominant"]] = doms.get(
                r["roofline"]["dominant"], 0) + 1
        print(f"\ndominant-term histogram: {doms}")
        worst = sorted(ok, key=lambda r: r["roofline"]["useful_flops_ratio"])[:5]
        print("lowest useful-flops ratio (hillclimb candidates):")
        for r in worst:
            print(f"  {r['arch']} x {r['shape']} x {r['mesh']}: "
                  f"{r['roofline']['useful_flops_ratio']:.3f} "
                  f"(dominant={r['roofline']['dominant']})")
        for dom in ("compute", "memory", "collective"):
            if any(r["roofline"]["dominant"] == dom for r in ok):
                print(f"to reduce '{dom}': {SUGGESTIONS[(dom,)]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
