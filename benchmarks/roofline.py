"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun.json (written by repro.launch.dryrun), prints the
three terms per (arch x shape x mesh), the dominant bottleneck, the
MODEL_FLOPS/HLO_FLOPS usefulness ratio, and a one-line "what would move
the dominant term" suggestion.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

SUGGESTIONS = {
    ("compute",): "increase per-chip batch or fuse small ops (MXU underfed)",
    ("memory",): "bf16 intermediates + flash tiling cut bytes; check remat "
                 "recompute and f32 attention buffers",
    ("collective",): "reshard: move FSDP all-gathers to bf16, overlap with "
                     "compute, or shard activations instead of replicating",
}


def load(path: str) -> List[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            rows.append(json.loads(line))
    return rows


def fmt_row(r: dict) -> str:
    if r["status"] != "OK":
        reason = r.get("reason", r.get("error", ""))[:60]
        return (f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} "
                f"{r['status']:5s} {reason}")
    rl = r["roofline"]
    dom = rl["dominant"]
    frac = rl["useful_flops_ratio"]
    return (f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} OK    "
            f"c={rl['compute_s']*1e3:9.1f}ms m={rl['memory_s']*1e3:9.1f}ms "
            f"x={rl['collective_s']*1e3:9.1f}ms dom={dom:10s} "
            f"useful={frac:5.2f}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--input", default="results/dryrun.json")
    ap.add_argument("--mesh", default="all")
    args = ap.parse_args(argv)
    rows = load(args.input)
    if args.mesh != "all":
        rows = [r for r in rows if r.get("mesh") == args.mesh]
    print(f"{'arch':26s} {'shape':12s} {'mesh':8s} stat  terms (per-chip)")
    for r in rows:
        print(fmt_row(r))
    ok = [r for r in rows if r["status"] == "OK"]
    if ok:
        doms: Dict[str, int] = {}
        for r in ok:
            doms[r["roofline"]["dominant"]] = doms.get(
                r["roofline"]["dominant"], 0) + 1
        print(f"\ndominant-term histogram: {doms}")
        worst = sorted(ok, key=lambda r: r["roofline"]["useful_flops_ratio"])[:5]
        print("lowest useful-flops ratio (hillclimb candidates):")
        for r in worst:
            print(f"  {r['arch']} x {r['shape']} x {r['mesh']}: "
                  f"{r['roofline']['useful_flops_ratio']:.3f} "
                  f"(dominant={r['roofline']['dominant']})")
        for dom in ("compute", "memory", "collective"):
            if any(r["roofline"]["dominant"] == dom for r in ok):
                print(f"to reduce '{dom}': {SUGGESTIONS[(dom,)]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
