"""§Perf hillclimbing harness: hypothesis -> change -> re-lower -> record.

Each iteration re-runs a dry-run cell with a config/step variant and records
the three roofline terms before/after into results/perf_iterations.json.

The three chosen cells (from the baseline table):
  A. granite_moe_1b_a400m x train_4k   — worst useful ratio (0.07), most
     collective-bound (101.6 s/step of ICI time: global-sort dispatch).
  B. llama4_maverick_400b_a17b x train_4k — the flagship MoE; collective-
     bound (77.9 s) with f32 FSDP gathers + global routing.
  C. codeqwen1_5_7b x decode_32k — serving decode, the substrate MS2M
     migrates; memory-bound on KV-cache traffic.

Run:  python -m benchmarks.perf_iterations --cell A --variant <name>
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
from typing import Callable, Dict

CELLS = {
    "A": ("granite_moe_1b_a400m", "train_4k"),
    "B": ("llama4_maverick_400b_a17b", "train_4k"),
    "C": ("codeqwen1_5_7b", "decode_32k"),
}


def _variants():
    from repro.optim import adamw
    from repro.train import step as steplib

    def moe_global(cfg, tcfg):
        return dataclasses.replace(cfg, moe_routing="global"), tcfg

    def moe_local(cfg, tcfg):
        return dataclasses.replace(cfg, moe_routing="local"), tcfg

    def moe_local_repl(cfg, tcfg):
        return dataclasses.replace(cfg, moe_routing="local",
                                   expert_sharding="replicated"), tcfg

    def bf16_params(cfg, tcfg):
        return cfg, dataclasses.replace(tcfg, param_dtype="bfloat16")

    def moe_local_bf16(cfg, tcfg):
        cfg, tcfg = moe_local(cfg, tcfg)
        return bf16_params(cfg, tcfg)

    def moe_local_repl_bf16(cfg, tcfg):
        cfg, tcfg = moe_local_repl(cfg, tcfg)
        return bf16_params(cfg, tcfg)

    def decode_flash(cfg, tcfg):
        return dataclasses.replace(cfg, decode_heads_replicated=True), tcfg

    def decode_flash_int8(cfg, tcfg):
        return dataclasses.replace(cfg, decode_heads_replicated=True,
                                   kv_cache_dtype="int8"), tcfg

    def kv_int8(cfg, tcfg):
        return dataclasses.replace(cfg, kv_cache_dtype="int8"), tcfg

    return {
        "baseline": lambda cfg, tcfg: (cfg, tcfg),
        "moe_global": moe_global,
        "moe_local": moe_local,
        "moe_local_repl": moe_local_repl,
        "bf16_params": bf16_params,
        "moe_local_bf16": moe_local_bf16,
        "moe_local_repl_bf16": moe_local_repl_bf16,
        "kv_int8": kv_int8,
        "decode_flash": decode_flash,
        "decode_flash_int8": decode_flash_int8,
    }


def run_variant(cell: str, variant: str, out_path: str,
                multi_pod: bool = False):
    from repro import configs
    from repro.launch import dryrun
    from repro.models.config import SHAPES
    from repro.train import step as steplib

    arch, shape = CELLS[cell]
    cfg = configs.get_config(arch)
    tcfg = steplib.TrainStepConfig(opt=dryrun.opt_config_for(cfg))
    cfg, tcfg = _variants()[variant](cfg, tcfg)

    # monkey-patch the registry so run_cell sees the variant config
    import repro.configs as C
    orig = C.get_config
    C.get_config = lambda name: cfg if name == arch else orig(name)
    try:
        row = dryrun.run_cell(arch, shape, multi_pod=multi_pod, tcfg=tcfg)
    finally:
        C.get_config = orig
    row["cell"] = cell
    row["variant"] = variant
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "a") as f:
        f.write(json.dumps(row) + "\n")
    r = row.get("roofline", {})
    print(f"[perf] cell {cell} ({arch} x {shape}) variant={variant}: "
          f"compute={r.get('compute_s', 0)*1e3:.1f}ms "
          f"mem={r.get('memory_s', 0)*1e3:.1f}ms "
          f"coll={r.get('collective_s', 0)*1e3:.1f}ms "
          f"dominant={r.get('dominant')} useful={r.get('useful_flops_ratio', 0):.3f}")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--variant", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--out", default="results/perf_iterations.json")
    args = ap.parse_args(argv)
    run_variant(args.cell, args.variant, args.out, multi_pod=args.multi)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
