"""Paper Figs. 5-8: migration time + downtime vs message rate, per strategy.

Each (strategy, rate) cell runs REPEATS times with different seeds (the
paper runs each test case 10 times); we report mean/min/max.  Results are
deterministic per seed (virtual clock), with real registry bytes and real
(hash-fold or JAX) state verified after every run.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import tempfile

from benchmarks import constants as C
from repro.core import run_migration_experiment

STRATEGIES = ("stop_and_copy", "ms2m_individual", "ms2m_cutoff",
              "ms2m_statefulset")


def run_sweep(strategies=STRATEGIES, rates=C.SWEEP_RATES, repeats=3,
              out_path=None, use_jax_consumer=False, batched_replay=None,
              replay_speedup=None, t_replay_max=C.T_REPLAY_MAX, policy=None):
    # legacy knobs default to None ("unset") so an explicit policy= is not
    # silently overridden by their old False/1.0 defaults
    worker_factory = None
    if use_jax_consumer:
        from repro.core import make_jax_worker_factory
        worker_factory, _ = make_jax_worker_factory()
    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        for strat in strategies:
            for rate in rates:
                migs, downs, ok = [], [], True
                phases_acc = {}
                for rep in range(repeats):
                    r = run_migration_experiment(
                        strat, rate,
                        registry_root=os.path.join(tmp, f"{strat}-{rate}-{rep}"),
                        processing_ms=C.PROCESSING_MS,
                        t_replay_max=t_replay_max,
                        seed=rep,
                        worker_factory=worker_factory,
                        policy=policy,
                        batched_replay=batched_replay,
                        replay_speedup=replay_speedup,
                    )
                    migs.append(r.migration_time)
                    downs.append(r.downtime)
                    ok = ok and r.verified
                    for k, v in r.report.phases.items():
                        phases_acc[k] = phases_acc.get(k, 0.0) + v / repeats
                row = {
                    "strategy": strat,
                    "rate": rate,
                    "migration_time_mean": round(statistics.mean(migs), 3),
                    "migration_time_min": round(min(migs), 3),
                    "migration_time_max": round(max(migs), 3),
                    "downtime_mean": round(statistics.mean(downs), 3),
                    "downtime_min": round(min(downs), 3),
                    "downtime_max": round(max(downs), 3),
                    "phases_mean": {k: round(v, 3) for k, v in phases_acc.items()},
                    "all_verified": ok,
                }
                rows.append(row)
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            for row in rows:
                f.write(json.dumps(row) + "\n")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=C.REPEATS)
    ap.add_argument("--strategy", default="all",
                    help="'all' = the paper's four; any registry name "
                         "(e.g. ms2m_precopy, ms2m_adaptive) also works")
    ap.add_argument("--rates", default=",".join(str(r) for r in C.SWEEP_RATES))
    ap.add_argument("--jax-consumer", action="store_true")
    ap.add_argument("--out", default="results/migration_sweep.json")
    args = ap.parse_args(argv)
    strategies = STRATEGIES if args.strategy == "all" else (args.strategy,)
    rates = tuple(float(r) for r in args.rates.split(","))
    rows = run_sweep(strategies, rates, args.repeats, args.out,
                     use_jax_consumer=args.jax_consumer)
    print(f"{'strategy':18s} {'rate':>5s} {'migration(s)':>14s} {'downtime(s)':>12s} ok")
    for r in rows:
        print(f"{r['strategy']:18s} {r['rate']:5.1f} "
              f"{r['migration_time_mean']:14.2f} {r['downtime_mean']:12.2f} "
              f"{r['all_verified']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
