"""pjit train/serve step builders + sharding derivation.

Everything the dry-run lowers comes from here: ``build_train_step`` /
``build_decode_step`` / ``build_prefill_step`` return pure functions; the
``*_shardings`` helpers derive NamedShardings for every carried pytree from
the logical-axis trees (with shape-aware divisibility fallback), and
``input_specs`` builds the ShapeDtypeStruct stand-ins for every model input
— weak-type-correct, shardable, zero allocation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from repro.models.common import split_params
from repro.models.config import ModelConfig, ShapeSpec
from repro.optim import adamw
from repro.sharding.rules import AxisRules, DEFAULT_RULES, logical_to_spec


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    remat: str = "dots"  # none | dots | full
    microbatches: int = 1
    unroll: bool = False  # inline layer groups (dry-run cost calibration)
    param_dtype: str = "float32"  # bfloat16 halves FSDP all-gather bytes
    opt: adamw.AdamWConfig = dataclasses.field(default_factory=adamw.AdamWConfig)
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000


def arch_rules(cfg: ModelConfig) -> AxisRules:
    rules = DEFAULT_RULES
    if cfg.attn_sharding == "seq":
        rules = rules.overriding(seq="model", act_heads=None, act_qout=None)
    if cfg.num_experts and cfg.expert_sharding == "replicated":
        # small-MoE regime: EP dispatch is inherently ICI-bound, so expert
        # weights replicate over `model` (still FSDP-sharded over `data`)
        rules = rules.overriding(experts=None)
    return rules


def decode_rules(cfg: ModelConfig) -> AxisRules:
    """Decode-time activation rules: q is [B,1,H,hd] (tiny) while the KV
    cache's seq axis is model-sharded — sharding q heads over `model` too
    forces XLA to all-gather the cache every layer (~20x decode bytes).
    Replicating decode-time heads keeps attention a local partial-softmax +
    psum (flash-decode).  §Perf iteration C1."""
    return arch_rules(cfg).overriding(act_heads=None, act_qout=None)


# ---------------------------------------------------------------------------
# sharding derivation
# ---------------------------------------------------------------------------

def _axes_leaf(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def _shardings_from(mesh, axes_tree, shapes_tree, rules):
    return jax.tree.map(
        lambda ax, shp: NamedSharding(
            mesh, logical_to_spec(ax, mesh, rules, dims=shp.shape)),
        axes_tree, shapes_tree, is_leaf=_axes_leaf)


def param_shapes_and_axes(cfg: ModelConfig, param_dtype: str = "float32"):
    leaves = jax.eval_shape(
        lambda k: T.init_lm(k, cfg), jax.random.PRNGKey(0))
    values, axes = split_params(leaves)
    if param_dtype != "float32":
        pdt = jnp.dtype(param_dtype)
        values = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, pdt)
            if s.dtype == jnp.float32 else s, values)
    return values, axes


def train_state_shardings(cfg: ModelConfig, mesh: Mesh,
                          opt_cfg: adamw.AdamWConfig,
                          rules: Optional[AxisRules] = None,
                          param_dtype: str = "float32"):
    """-> (param_shapes, param_shardings, opt_shapes, opt_shardings)."""
    rules = rules or arch_rules(cfg)
    p_shapes, p_axes = param_shapes_and_axes(cfg, param_dtype)
    p_shard = _shardings_from(mesh, p_axes, p_shapes, rules)
    o_shapes = jax.eval_shape(lambda p: adamw.adamw_init(p, opt_cfg), p_shapes)

    def _mu_axes(ax, shp):
        """Moment axes mirror the parameter's; factored moments drop dims."""
        if opt_cfg.factored and adamw._factorable(shp.shape):
            v = {"row": ax[:-1], "col": ax[:-2] + ax[-1:]}
        else:
            v = ax
        return {"m": ax, "v": v}

    mu_axes = jax.tree.map(_mu_axes, p_axes, p_shapes, is_leaf=_axes_leaf)
    o_axes = {"count": (), "mu": mu_axes}
    o_shard = jax.tree.map(
        lambda ax, shp: NamedSharding(
            mesh, logical_to_spec(ax, mesh, rules, dims=shp.shape)),
        o_axes, o_shapes, is_leaf=_axes_leaf)
    return p_shapes, p_shard, o_shapes, o_shard


def batch_logical_axes(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, tuple]:
    axes = {"tokens": ("batch", None), "labels": ("batch", None)}
    if cfg.frontend == "audio_frames":
        axes["frames"] = ("batch", None, "act_embed")
    if cfg.frontend == "image_patches":
        axes["patch_embeds"] = ("batch", None, "act_embed")
        axes["positions"] = (None, "batch", None)  # [3,B,S] m-rope ids
    return axes


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    B, S = shape.batch, shape.seq
    f32 = jnp.float32
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:  # decode: one new token, cache of length S
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "positions": jax.ShapeDtypeStruct((B, 1), i32),
        }
    if cfg.frontend == "audio_frames" and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), f32)
    if cfg.frontend == "image_patches" and shape.kind != "decode":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), f32)
        if cfg.rope_kind == "mrope":
            specs["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
    return specs


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    rules: Optional[AxisRules] = None):
    rules = rules or arch_rules(cfg)
    axes = batch_logical_axes(cfg, shape)
    specs = input_specs(cfg, shape)
    out = {}
    for k, spec in specs.items():
        ax = axes.get(k)
        if k == "positions":  # [B,1] decode vs [3,B,S] m-rope prefill
            ax = ((None, "batch", None) if len(spec.shape) == 3
                  else ("batch", None))
        if ax is None:
            ax = ("batch",) + (None,) * (len(spec.shape) - 1)
        out[k] = NamedSharding(mesh, logical_to_spec(
            ax, mesh, rules, dims=spec.shape))
    return out


def cache_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    rules: Optional[AxisRules] = None):
    rules = rules or arch_rules(cfg)
    shapes = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.batch, shape.seq))
    axes = T.cache_logical_axes(cfg)
    shard = jax.tree.map(
        lambda ax, shp: NamedSharding(
            mesh, logical_to_spec(ax, mesh, rules, dims=shp.shape)),
        axes, shapes, is_leaf=_axes_leaf)
    return shapes, shard


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, tcfg: TrainStepConfig):
    """-> train_step(params, opt_state, batch, step) -> (params', opt', metrics)."""
    from repro.optim.schedule import cosine_schedule

    def loss_fn(params, batch):
        return T.lm_loss(params, batch, cfg, remat=tcfg.remat,
                         unroll=tcfg.unroll)

    def train_step(params, opt_state, batch, step):
        if tcfg.microbatches > 1:
            # gradient accumulation over microbatches (scan keeps one
            # microbatch's activations live at a time)
            def micro(c, mb):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc, n = c
                acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, n + 1), (l, m)

            mbs = jax.tree.map(
                lambda x: x.reshape((tcfg.microbatches,
                                     x.shape[0] // tcfg.microbatches)
                                    + x.shape[1:]),
                batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, _), (losses, metrics) = jax.lax.scan(
                micro, (zero, 0), mbs)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        lr = cosine_schedule(step, peak=tcfg.lr_peak,
                             warmup_steps=tcfg.warmup_steps,
                             total_steps=tcfg.total_steps)
        params, opt_state, opt_metrics = adamw.adamw_update(
            params, grads, opt_state, tcfg.opt, lr=lr)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        metrics["lr"] = lr
        return params, opt_state, metrics

    return train_step


def build_decode_step(cfg: ModelConfig, unroll: bool = False):
    def decode_step(params, cache, tokens, positions):
        return T.lm_decode_step(params, tokens, positions, cfg, cache,
                                unroll=unroll)

    return decode_step


def build_prefill_step(cfg: ModelConfig, unroll: bool = False):
    def prefill_step(params, cache, batch):
        return T.lm_prefill(params, batch, cfg, cache, unroll=unroll)

    return prefill_step
