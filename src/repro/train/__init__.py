from repro.train.step import (  # noqa: F401
    TrainStepConfig,
    build_train_step,
    build_decode_step,
    build_prefill_step,
    input_specs,
    train_state_shardings,
    cache_shardings,
    batch_shardings,
)
