"""Deterministic discrete-event kernel with generator processes.

Processes are Python generators that ``yield`` either a float delay or a
``Condition``; the kernel advances a virtual clock.  All service times are
charged to the virtual clock (so benchmarks are deterministic and fast)
while *real* JAX compute runs inside the handlers (so migrated state is
real, bit-exactly checkable, and measured step times can calibrate the
clock constants).

Two analysis modes (see docs/determinism.md):

  * ``Sim(sanitize=True)`` / ``REPRO_SIM_SANITIZE=1`` — the runtime
    sanitizer: conditions, link flows and waiting processes carry
    creation-site provenance, and leak/race invariants (callback-list
    growth, conflicting double-triggers, dangling waiters at quiescence)
    raise :class:`repro.analysis.sanitizer.SanitizerViolation`;
  * ``Sim(tiebreak_seed=N)`` / ``REPRO_SIM_TIEBREAK=N`` — seeded schedule
    perturbation: the pop order of *equal-timestamp* heap events is
    permuted by a deterministic bijective hash of (event counter, seed).
    Virtual time is untouched; only tie order changes.  Any observable
    divergence under perturbation is a latent scheduling race
    (``tools/sim_perturb.py`` sweeps this).
"""
from __future__ import annotations

import heapq
import itertools
import os
from typing import Any, Callable, Generator, List, Optional

from repro.analysis.sanitizer import (SanitizerViolation, SimSanitizer,
                                      capture_site)

_SANITIZE_ENV = "REPRO_SIM_SANITIZE"
_TIEBREAK_ENV = "REPRO_SIM_TIEBREAK"
_FLUID_ENV = "REPRO_SIM_FLUID"
_CENSUS_ENV = "REPRO_SIM_CENSUS"
_M64 = (1 << 64) - 1

# event-census categories (docs/scaling.md): attribution buckets for
# popped heap events, derived from process names / scheduling sites
CENSUS_CATEGORIES = ("message", "heartbeat", "link", "fault", "other")


def _census_category(name: str) -> str:
    """Classify a process by name prefix for the opt-in event census."""
    if name.startswith(("pod:", "producer", "source:")):
        return "message"
    if name.startswith("heartbeat"):
        return "heartbeat"
    if name.startswith("fault"):
        return "fault"
    return "other"


def _mix64(counter: int, seed: int) -> int:
    """splitmix64 finalizer over (counter, seed): a bijection of the
    counter for any fixed seed, so equal-timestamp events get a
    deterministic, collision-free permuted pop order."""
    z = (counter + (seed + 1) * 0x9E3779B97F4A7C15) & _M64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
    return z ^ (z >> 31)


class Condition:
    """A waitable event; processes yield it to block until triggered."""

    def __init__(self, sim: "Sim", name: str = ""):
        self.sim = sim
        self.name = name
        self.triggered = False
        self.value: Any = None
        self._waiters: List["_Proc"] = []
        self._callbacks: List[Callable] = []
        if sim.sanitizer is not None:
            sim.sanitizer.track_condition(self)

    def on_trigger(self, fn: Callable):
        if self.triggered:
            fn(self.value)
        else:
            self._callbacks.append(fn)
            if self.sim.sanitizer is not None:
                self.sim.sanitizer.on_register_callback(self)

    def detach(self, fn: Callable):
        """Remove a callback registered with ``on_trigger`` (no-op when
        it already fired or was never registered)."""
        try:
            self._callbacks.remove(fn)
        except ValueError:
            pass

    def trigger(self, value: Any = None):
        if self.triggered:
            if self.sim.sanitizer is not None:
                self.sim.sanitizer.on_retrigger(self, value)
            return
        self.triggered = True
        self.value = value
        for proc in self._waiters:
            self.sim._ready(proc, value)
        self._waiters.clear()
        for fn in self._callbacks:
            fn(value)
        self._callbacks.clear()


class _Proc:
    def __init__(self, gen: Generator, name: str):
        self.gen = gen
        self.name = name
        self.cat = _census_category(name)
        self.done = Condition.__new__(Condition)  # filled by Sim.process


class Interrupt(Exception):
    pass


class Sim:
    def __init__(self, sanitize: Optional[bool] = None,
                 tiebreak_seed: Optional[int] = None,
                 fluid: Optional[bool] = None,
                 census: Optional[bool] = None):
        self.now = 0.0
        self._heap: list = []
        self._counter = itertools.count()
        # env fallbacks let harnesses flip the modes on Sims they never
        # construct directly (Cluster builds its own)
        if sanitize is None:
            sanitize = os.environ.get(_SANITIZE_ENV, "") not in ("", "0")
        self.sanitizer: Optional[SimSanitizer] = (
            SimSanitizer() if sanitize else None)
        if tiebreak_seed is None:
            env = os.environ.get(_TIEBREAK_ENV, "")
            tiebreak_seed = int(env) if env else None
        self.tiebreak_seed = tiebreak_seed
        # epoch-batched (fluid) message dynamics: ON by default, with
        # REPRO_SIM_FLUID=0 selecting the legacy per-message-event flow
        # (docs/scaling.md).  The flag only gates an optimization — the
        # observable timeline is bit-identical either way.
        if fluid is None:
            fluid = os.environ.get(_FLUID_ENV, "") != "0"
        self.fluid_enabled = bool(fluid)
        # opt-in event census: count popped heap events per category so
        # perf regressions are attributable (BENCH_sim.json)
        if census is None:
            census = os.environ.get(_CENSUS_ENV, "") not in ("", "0")
        self._census: Optional[dict] = (
            {c: 0 for c in CENSUS_CATEGORIES} if census else None)

    # -- scheduling ----------------------------------------------------------
    def _push(self, t: float, fn: Callable, arg: Any = None,
              cat: str = "other"):
        c = next(self._counter)
        if self.tiebreak_seed is not None:
            c = _mix64(c, self.tiebreak_seed)
        heapq.heappush(self._heap, (t, c, fn, arg, cat))

    def _ready(self, proc: _Proc, value: Any = None):
        if self.sanitizer is not None:
            self.sanitizer.on_ready(proc)
        self._push(self.now, lambda v: self._step(proc, v), value, proc.cat)

    def condition(self, name: str = "") -> Condition:
        return Condition(self, name)

    def any_of(self, *conds: Condition, name: str = "any") -> Condition:
        """Condition triggering when the first of ``conds`` triggers.

        The losers are detached when the winner fires: callers that
        repeatedly build ``any_of`` over long-lived conditions (the fleet
        driver's wakeup loop) must not grow the losers' callback lists
        unboundedly."""
        out = Condition(self, name)
        armed: List[Condition] = []

        def fire(value: Any = None):
            for c in armed:
                if not c.triggered:
                    c.detach(fire)
            armed.clear()
            out.trigger(value)

        for c in conds:
            if c.triggered:
                fire(c.value)
                break
            armed.append(c)
            c.on_trigger(fire)
        return out

    def process(self, gen: Generator, name: str = "") -> Condition:
        """Start a generator process; returns its completion Condition."""
        proc = _Proc(gen, name)
        done = Condition(self, f"done:{name}")
        proc.done = done
        self._push(self.now, lambda v: self._step(proc, v), None, proc.cat)
        return done

    def call_at(self, t: float, fn: Callable, category: str = "other"):
        self._push(max(t, self.now), lambda _: fn(), None, category)

    def call_after(self, delay: float, fn: Callable,
                   category: str = "other"):
        self.call_at(self.now + delay, fn, category=category)

    # -- process stepping ------------------------------------------------------
    def _step(self, proc: _Proc, send_value: Any):
        try:
            yielded = proc.gen.send(send_value)
        except StopIteration as stop:
            proc.done.trigger(stop.value)
            return
        if isinstance(yielded, Condition):
            if yielded.triggered:
                self._ready(proc, yielded.value)
            else:
                yielded._waiters.append(proc)
                if self.sanitizer is not None:
                    self.sanitizer.on_wait(proc, yielded)
        elif isinstance(yielded, (int, float)):
            self._push(self.now + float(yielded),
                       lambda v: self._step(proc, v), None, proc.cat)
        else:
            raise TypeError(f"process {proc.name} yielded {type(yielded)}")

    # -- fair-share flows ------------------------------------------------------
    def link(self, capacity_Bps: float, latency_s: float = 0.0,
             name: str = "link", shared: bool = True) -> "Link":
        return Link(self, capacity_Bps, latency_s=latency_s, name=name,
                    shared=shared)

    # -- run -------------------------------------------------------------------
    def run(self, until: Optional[float] = None,
            stop_when: Optional[Condition] = None):
        census = self._census
        while self._heap:
            if stop_when is not None and stop_when.triggered:
                return
            head = self._heap[0]
            t = head[0]
            if until is not None and t > until:
                self.now = until
                return
            heapq.heappop(self._heap)
            self.now = t
            if census is not None:
                census[head[4]] += 1
            head[2](head[3])
        if until is not None:
            self.now = max(self.now, until)

    def stats(self) -> dict:
        """Kernel introspection: clock, heap size and (when the census is
        on — ``Sim(census=True)`` / ``REPRO_SIM_CENSUS=1``) popped-event
        counts per category.  With the census off ``events`` is ``None``
        so callers can tell "not measured" from "zero events"."""
        events = dict(self._census) if self._census is not None else None
        return {"now": self.now,
                "heap_len": len(self._heap),
                "census_enabled": self._census is not None,
                "events": events,
                "events_total": (sum(events.values())
                                 if events is not None else None)}

    # -- quiescence audit ------------------------------------------------------
    def assert_quiescent(self, **allow) -> None:
        """With ``sanitize`` on, raise :class:`SanitizerViolation` if any
        process is parked on a condition that can never trigger or any
        link flow is still in flight now that the heap has drained.
        ``allow`` forwards to :meth:`SimSanitizer.dangling`
        (``allow_suffixes`` / ``allow_names`` tune the idle-pattern
        allowlist).  No-op when the sanitizer is off."""
        if self.sanitizer is None:
            return
        leaks = self.sanitizer.dangling(**allow)
        if leaks:
            raise SanitizerViolation(
                "dangling",
                "leaks at quiescence:\n  " + "\n  ".join(leaks))


class TransferAborted(RuntimeError):
    """An in-flight Link transfer was withdrawn (e.g. an endpoint died)."""


class _Flow:
    __slots__ = ("nbytes", "remaining", "done", "created")

    def __init__(self, sim: Sim, nbytes: float):
        self.nbytes = nbytes
        self.remaining = nbytes
        self.done = Condition(sim, "flow")
        self.created = (capture_site() if sim.sanitizer is not None
                        else None)


class Link:
    """A capacity-limited network link with max-min fair bandwidth sharing.

    Concurrent ``transfer(nbytes)`` flows split the capacity equally;
    remaining bytes and per-flow rate are recomputed on every flow arrival
    and departure (progressive filling).  The schedule is deterministic and
    heap-driven — each recompute arms exactly one next-completion event,
    superseded by a generation counter when the flow set changes — so a
    link never polls.  Work conservation: when a short flow finishes, the
    survivors immediately speed up.

    ``shared=False`` is the dedicated-capacity (legacy) mode: every
    transfer is charged ``nbytes / capacity`` independently, with no
    contention — the ``flat`` topology preset uses it to reproduce the
    uncontended single-registry-link model bit-for-bit.
    """

    _EPS_BYTES = 1e-6  # float-settlement slack when finishing a flow

    def __init__(self, sim: Sim, capacity_Bps: float, latency_s: float = 0.0,
                 name: str = "link", shared: bool = True):
        if capacity_Bps <= 0:
            raise ValueError(f"link {name!r} needs capacity_Bps > 0")
        self.sim = sim
        self.capacity_Bps = float(capacity_Bps)
        self.latency_s = float(latency_s)
        self.name = name
        self.shared = shared
        self.total_bytes = 0.0      # lifetime bytes accepted onto the link
        self.peak_flows = 0
        self.aborted_flows = 0
        self._flows: List[_Flow] = []
        self._last = sim.now
        self._gen = 0
        if sim.sanitizer is not None:
            sim.sanitizer.track_link(self)

    @property
    def n_flows(self) -> int:
        return len(self._flows)

    @property
    def queued_bytes(self) -> float:
        """Bytes still in flight across all active flows (load signal)."""
        self._settle()
        return sum(f.remaining for f in self._flows)

    def rate_per_flow(self) -> float:
        return (self.capacity_Bps / len(self._flows) if self._flows
                else self.capacity_Bps)

    def set_capacity(self, capacity_Bps: float) -> None:
        """Change the link's capacity mid-run (fault injection: a degraded
        or repaired link).  Shared links settle in-flight flows at the old
        rate first, then re-plan the next completion at the new rate, so
        the change takes effect for every active flow at the instant it is
        applied.  Dedicated (unshared) links price each transfer when it
        starts, so a capacity change there affects new transfers only."""
        if capacity_Bps <= 0:
            raise ValueError(f"link {self.name!r} needs capacity_Bps > 0")
        if self.shared:
            self._settle()
            self.capacity_Bps = float(capacity_Bps)
            self._reschedule()
        else:
            self.capacity_Bps = float(capacity_Bps)

    # -- progressive filling ---------------------------------------------------
    def _settle(self) -> None:
        """Credit progress at the rate that held since the last event."""
        now = self.sim.now
        dt = now - self._last
        self._last = now
        if dt <= 0.0 or not self._flows:
            return
        rate = self.capacity_Bps / len(self._flows)
        for f in self._flows:
            f.remaining -= rate * dt

    def _finish_completed(self) -> None:
        still: List[_Flow] = []
        for f in self._flows:
            if f.remaining <= self._EPS_BYTES:
                f.done.trigger()
            else:
                still.append(f)
        self._flows = still

    def _reschedule(self) -> None:
        self._gen += 1
        if not self._flows:
            return
        while True:
            rate = self.capacity_Bps / len(self._flows)
            residual = min(f.remaining for f in self._flows)
            t = self.sim.now + residual / rate
            if t > self.sim.now:
                break
            # the residual is below the clock's float resolution at this
            # timestamp (now + dt == now): arming a tick could never make
            # progress (_settle sees dt == 0), so credit the sub-resolution
            # window synchronously — every flow advances by the residual —
            # and finish what that settles
            for f in self._flows:
                f.remaining -= residual
            self._finish_completed()
            if not self._flows:
                return
        gen = self._gen
        self.sim.call_at(t, lambda: self._on_tick(gen), category="link")

    def _on_tick(self, gen: int) -> None:
        if gen != self._gen:  # superseded by an arrival/departure
            return
        self._settle()
        self._finish_completed()
        self._reschedule()

    # -- the flow API ----------------------------------------------------------
    def transfer(self, nbytes: float, abort: Optional[Condition] = None
                 ) -> Generator:
        """Generator process: move ``nbytes`` across the link, fair-sharing
        with every concurrent flow.  Charges the per-transfer latency
        first.  If ``abort`` (a Condition) triggers mid-flight, the flow is
        withdrawn — survivors speed up — and ``TransferAborted`` raises
        into the calling process.  Returns the elapsed transfer seconds
        (excluding latency)."""
        if abort is not None and abort.triggered:
            raise TransferAborted(f"{self.name}: aborted before start")
        if self.latency_s > 0.0:
            yield self.latency_s
        if nbytes <= 0:
            return 0.0
        self.total_bytes += nbytes
        t0 = self.sim.now
        if not self.shared:  # dedicated capacity: no contention
            duration = nbytes / self.capacity_Bps
            if abort is None:
                yield duration
            else:
                timer = Condition(self.sim, f"{self.name}:xfer")
                self.sim.call_after(duration, timer.trigger, category="link")
                yield self.sim.any_of(timer, abort)
                if not timer.triggered:
                    undelivered = nbytes * (1.0 - (self.sim.now - t0)
                                            / duration)
                    self.total_bytes -= max(0.0, undelivered)
                    self.aborted_flows += 1
                    raise TransferAborted(
                        f"{self.name}: dedicated transfer aborted with "
                        f"{undelivered:.0f}/{nbytes:.0f} bytes left")
            return self.sim.now - t0
        self._settle()
        flow = _Flow(self.sim, float(nbytes))
        self._flows.append(flow)
        self.peak_flows = max(self.peak_flows, len(self._flows))
        self._reschedule()
        if abort is None:
            yield flow.done
        else:
            yield self.sim.any_of(flow.done, abort)
            if not flow.done.triggered:
                self._settle()
                if flow in self._flows:
                    self._flows.remove(flow)
                # total_bytes reports DELIVERED traffic: give back what the
                # withdrawn flow never moved
                self.total_bytes -= max(0.0, flow.remaining)
                self.aborted_flows += 1
                self._reschedule()
                raise TransferAborted(
                    f"{self.name}: transfer aborted with "
                    f"{flow.remaining:.0f}/{nbytes:.0f} bytes left")
        return self.sim.now - t0

    def stats(self) -> dict:
        return {"name": self.name,
                "capacity_Bps": self.capacity_Bps,
                "latency_s": self.latency_s,
                "shared": self.shared,
                "total_bytes": int(self.total_bytes),
                "peak_flows": self.peak_flows,
                "aborted_flows": self.aborted_flows}
