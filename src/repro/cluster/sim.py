"""Deterministic discrete-event kernel with generator processes.

Processes are Python generators that ``yield`` either a float delay or a
``Condition``; the kernel advances a virtual clock.  All service times are
charged to the virtual clock (so benchmarks are deterministic and fast)
while *real* JAX compute runs inside the handlers (so migrated state is
real, bit-exactly checkable, and measured step times can calibrate the
clock constants).
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Generator, List, Optional


class Condition:
    """A waitable event; processes yield it to block until triggered."""

    def __init__(self, sim: "Sim", name: str = ""):
        self.sim = sim
        self.name = name
        self.triggered = False
        self.value: Any = None
        self._waiters: List["_Proc"] = []
        self._callbacks: List[Callable] = []

    def on_trigger(self, fn: Callable):
        if self.triggered:
            fn(self.value)
        else:
            self._callbacks.append(fn)

    def trigger(self, value: Any = None):
        if self.triggered:
            return
        self.triggered = True
        self.value = value
        for proc in self._waiters:
            self.sim._ready(proc, value)
        self._waiters.clear()
        for fn in self._callbacks:
            fn(value)
        self._callbacks.clear()


class _Proc:
    def __init__(self, gen: Generator, name: str):
        self.gen = gen
        self.name = name
        self.done = Condition.__new__(Condition)  # filled by Sim.process


class Interrupt(Exception):
    pass


class Sim:
    def __init__(self):
        self.now = 0.0
        self._heap: list = []
        self._counter = itertools.count()

    # -- scheduling ----------------------------------------------------------
    def _push(self, t: float, fn: Callable, arg: Any = None):
        heapq.heappush(self._heap, (t, next(self._counter), fn, arg))

    def _ready(self, proc: _Proc, value: Any = None):
        self._push(self.now, lambda v: self._step(proc, v), value)

    def condition(self, name: str = "") -> Condition:
        return Condition(self, name)

    def any_of(self, *conds: Condition, name: str = "any") -> Condition:
        """Condition triggering when the first of ``conds`` triggers."""
        out = Condition(self, name)
        for c in conds:
            c.on_trigger(out.trigger)
        return out

    def process(self, gen: Generator, name: str = "") -> Condition:
        """Start a generator process; returns its completion Condition."""
        proc = _Proc(gen, name)
        done = Condition(self, f"done:{name}")
        proc.done = done
        self._push(self.now, lambda v: self._step(proc, v), None)
        return done

    def call_at(self, t: float, fn: Callable):
        self._push(max(t, self.now), lambda _: fn(), None)

    def call_after(self, delay: float, fn: Callable):
        self.call_at(self.now + delay, fn)

    # -- process stepping ------------------------------------------------------
    def _step(self, proc: _Proc, send_value: Any):
        try:
            yielded = proc.gen.send(send_value)
        except StopIteration as stop:
            proc.done.trigger(stop.value)
            return
        if isinstance(yielded, Condition):
            if yielded.triggered:
                self._ready(proc, yielded.value)
            else:
                yielded._waiters.append(proc)
        elif isinstance(yielded, (int, float)):
            self._push(self.now + float(yielded), lambda v: self._step(proc, v), None)
        else:
            raise TypeError(f"process {proc.name} yielded {type(yielded)}")

    # -- run -------------------------------------------------------------------
    def run(self, until: Optional[float] = None,
            stop_when: Optional[Condition] = None):
        while self._heap:
            if stop_when is not None and stop_when.triggered:
                return
            t, _, fn, arg = self._heap[0]
            if until is not None and t > until:
                self.now = until
                return
            heapq.heappop(self._heap)
            self.now = t
            fn(arg)
        if until is not None:
            self.now = max(self.now, until)
