"""Predictive rebalancing controller: migrate *before* the failure.

Every migration subsystem so far is reactive — an operator (or a test)
decides when to drain a node, and the fault injector decides when to kill
one.  This module closes the loop: a :class:`RebalanceController` runs as
a sim process, watches three cheap cluster-health signals each control
tick, and proactively drains the pods most at risk *ahead* of the
predicted failure or hotspot:

  * **heartbeat jitter** — a node whose heartbeat generation counter
    advanced since the last tick flapped (died and revived under the
    deadline-driven monitor in ``cluster.start_heartbeats``); flapping
    nodes are marked *suspect* for a window, on the operational prior
    that a node that just flapped is likely to flap again;
  * **link saturation** — a node whose registry link would need more
    than ``link_hot_drain_s`` seconds to drain its in-flight bytes
    (``Link.queued_bytes / capacity_Bps``) is a congestion hotspot;
  * **queue growth** — per-pod backlog slope over a short history ring
    of ``APIServer.fleet_state()`` snapshots (one vectorized scan per
    tick — no per-message observers, so the fluid execution regime is
    untouched).

Each flagged pod gets a cost/benefit score (pure functions, unit-testable
without a cluster):

  benefit  messages at risk if the pod's node fails now: current backlog
           plus arrivals over the catch-up exposure window, with the
           drain time from ``cutoff.expected_catchup_time`` (infinite at
           saturation — exactly the paper's high-λ failure mode — capped
           at ``horizon_s``);
  cost     estimated wire bytes times zone distance, reusing the two
           distance legs of the topology-aware placement score
           (registry→target plus source→target);
  score    risk-weighted benefit per byte moved.

Moves above ``min_score`` execute through the existing
``ClusterMigrationOrchestrator`` — per-spec rollback, retry and
placement included — and every decision is emitted as a structured
``MigrationEvent`` (also fanned out through ``api.notify_migration`` so
fault-phase triggers and probes can observe the controller).

The controller is **disabled by default** everywhere: nothing constructs
one unless a harness or CLI flag asks for it, so every existing
experiment timeline is bit-identical.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Generator, List, Optional, Set

from repro.cluster.cluster import APIServer, Node, Pod
from repro.cluster.sim import Condition

# ---------------------------------------------------------------------------
# Pure decision math (the unit-testable core)
# ---------------------------------------------------------------------------


def predicted_messages_at_risk(lam: float, mu: float, backlog: float,
                               horizon_s: float) -> float:
    """Messages stranded if the pod's node failed right now: the backlog
    already queued plus the arrivals that land during the catch-up
    exposure window.  The window is ``expected_catchup_time`` (drain time
    of the backlog at μ-λ), capped at ``horizon_s``; at or beyond
    saturation the drain never converges, so the full horizon is exposed
    — saturated pods rank highest, which is exactly the regime the paper
    reports original MS2M degrading in."""
    from repro.core.cutoff import expected_catchup_time

    catchup = expected_catchup_time(lam, mu, backlog)
    exposure = horizon_s if math.isinf(catchup) else min(catchup, horizon_s)
    return backlog + max(lam, 0.0) * exposure


def move_cost_bytes(state_bytes: float, registry_dist: int,
                    source_dist: int) -> float:
    """Wire-byte cost of relocating a pod: state size scaled by the same
    two zone-distance legs the topology-aware placement score charges
    (registry→target pull plus source→target affinity), plus the baseline
    intra-zone transfer itself (the ``1 +``)."""
    return max(1.0, float(state_bytes) * (1.0 + registry_dist + source_dist))


def move_score(risk: float, messages_at_risk: float,
               cost_bytes: float) -> float:
    """Risk-weighted messages-at-risk averted per byte moved — the
    controller's ranking key and its admission threshold
    (``RebalanceConfig.min_score``)."""
    return risk * messages_at_risk / max(cost_bytes, 1.0)


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RebalanceConfig:
    """Knobs of the predictive rebalancer (see docs/rebalancing.md)."""

    tick_s: float = 1.0            # control-loop period (virtual s)
    horizon_s: float = 30.0        # exposure cap for messages-at-risk
    suspect_s: float = 90.0        # how long a flapped node stays suspect
    cooldown_s: float = 30.0       # per-queue quiet period after a move
    max_moves_per_tick: int = 2    # new migrations admitted per tick
    max_inflight: int = 4          # total migrations in flight at once
    growth_window_ticks: int = 5   # history ring for the backlog slope
    growth_min_rate: float = 0.5   # sustained backlog growth (msgs/s) flag
    link_hot_drain_s: float = 5.0  # registry-link drain seconds flag
    lam_halflife_s: float = 10.0   # EWMA half-life of the per-pod λ̂
    flap_risk: float = 1.0         # risk weight: node flapped recently
    link_risk: float = 0.5         # risk weight: registry link saturated
    growth_risk: float = 0.3       # risk weight: backlog growing
    min_risk: float = 0.25         # ignore pods below this combined risk
    min_score: float = 1e-9        # messages-at-risk per byte admission bar
    strategy: str = "ms2m_individual"  # migration strategy for drains


# ---------------------------------------------------------------------------
# The controller
# ---------------------------------------------------------------------------


class RebalanceController:
    """Continuous rebalancing loop over one cluster.

    ``start()`` launches the tick process; ``stop()`` halts admissions;
    ``quiesce()`` (a generator — run it as a process or ``yield from``
    it) additionally waits for every in-flight fleet to land, so a
    harness can settle the cluster before verification.

    Wire ``on_node_dead`` into ``api.start_heartbeats`` (possibly chained
    with the workload's own callback) so confirmed deaths reach the
    controller at detection time rather than at the next tick.
    """

    def __init__(self, api: APIServer, orchestrator,
                 config: Optional[RebalanceConfig] = None):
        from repro.core.orchestrator import ClusterMigrationOrchestrator
        assert isinstance(orchestrator, ClusterMigrationOrchestrator)
        self.api = api
        self.sim = api.sim
        self.orch = orchestrator
        self.config = config or RebalanceConfig()
        self.events: List[Any] = []          # MigrationEvent trace
        self.moves: List[Any] = []           # landed FleetReports
        self.n_ticks = 0
        self.n_moves_launched = 0
        self._stopped = False
        self._proc: Optional[Condition] = None
        # signal state
        self._node_gen: Dict[str, int] = {}
        self._suspect_until: Dict[str, float] = {}
        self._dead: Set[str] = set()
        self._lam: Dict[str, float] = {}             # per-queue λ̂ (EWMA)
        self._prev: Dict[str, tuple] = {}            # queue -> (t, published)
        self._depth_hist: Dict[str, List[tuple]] = {}  # queue -> [(t, depth)]
        self._cooldown_until: Dict[str, float] = {}  # queue -> t
        self._moving: Set[str] = set()               # queues in flight
        self._fleets: List[tuple] = []               # (cond, [queues])

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> Condition:
        if self._proc is None:
            self._proc = self.sim.process(self._loop(),
                                          name="rebalance-controller")
        return self._proc

    def stop(self) -> None:
        self._stopped = True

    def quiesce(self) -> Generator:
        """Stop admissions and wait for every in-flight fleet to land."""
        self.stop()
        while self._fleets:
            cond, _ = self._fleets[0]
            yield cond
            self._harvest()

    # -- signal intake ------------------------------------------------------
    def on_node_dead(self, name: str) -> None:
        """Heartbeat-monitor callback: a node's death was confirmed."""
        self._dead.add(name)
        self._emit("rebalance_node_dead", node=name)

    # -- event plumbing -----------------------------------------------------
    def _emit(self, kind: str, **data: Any) -> None:
        from repro.core.policy import MigrationEvent

        now = self.sim.now
        self.events.append(MigrationEvent(t=now, kind=kind, data=dict(data)))
        self.api.notify_migration(kind, now, dict(data))

    def event_rows(self) -> List[Dict[str, Any]]:
        return [e.row() for e in self.events]

    @property
    def moved_wire_bytes(self) -> int:
        return sum(f.wire_bytes_total for f in self.moves)

    @property
    def n_moved(self) -> int:
        return sum(f.n_migrated for f in self.moves)

    @property
    def n_failed_moves(self) -> int:
        return sum(f.n_failed for f in self.moves)

    # -- main loop ----------------------------------------------------------
    def _loop(self) -> Generator:
        while not self._stopped:
            yield self.config.tick_s
            if self._stopped:
                return
            self.n_ticks += 1
            self._tick()

    def _harvest(self) -> None:
        """Collect landed fleets: record reports, release queues into
        cooldown, surface failures as events."""
        still = []
        for cond, queues in self._fleets:
            if not cond.triggered:
                still.append((cond, queues))
                continue
            fleet = cond.value
            self.moves.append(fleet)
            until = self.sim.now + self.config.cooldown_s
            for q in queues:
                self._moving.discard(q)
                self._cooldown_until[q] = until
            self._emit("rebalance_fleet_done",
                       n_migrated=fleet.n_migrated, n_failed=fleet.n_failed,
                       wire_bytes=fleet.wire_bytes_total,
                       queues=list(queues))
            for entry in fleet.failures:
                self._emit("rebalance_move_failed", queue=entry["queue"],
                           error=entry["error"])
        self._fleets = still

    def _scan_nodes(self) -> None:
        """Flap detection: a heartbeat-generation bump since the last tick
        means the node died and revived under the monitor — mark it
        suspect for ``suspect_s``."""
        now = self.sim.now
        for name, node in self.api.nodes.items():
            gen = node._hb_gen
            prev = self._node_gen.get(name)
            if prev is not None and gen > prev:
                self._suspect_until[name] = now + self.config.suspect_s
                self._dead.discard(name)
                self._emit("rebalance_suspect", node=name,
                           until=round(self._suspect_until[name], 6))
            self._node_gen[name] = gen

    def _suspect(self, name: str) -> bool:
        return self._suspect_until.get(name, -math.inf) > self.sim.now

    def _link_drain_s(self, node_name: str) -> float:
        link = self.api.topology.registry_link(node_name)
        return link.queued_bytes / link.capacity_Bps

    def _tick(self) -> None:
        cfg = self.config
        now = self.sim.now
        self._harvest()
        self._scan_nodes()

        state = self.api.fleet_state()  # one vectorized scan per tick
        depths = state["queue_depth"]
        pubs = state["total_published"]

        # per-queue λ̂: published-count deltas per tick, EWMA-smoothed
        # (windowed recent rate — not the lifetime average; satellite #1's
        # bug class must not be rebuilt here)
        candidates: List[tuple] = []
        for i, queue in enumerate(state["queue"]):
            prev = self._prev.get(queue)
            self._prev[queue] = (now, int(pubs[i]))
            hist = self._depth_hist.setdefault(queue, [])
            hist.append((now, int(depths[i])))
            if len(hist) > cfg.growth_window_ticks:
                del hist[0]
            if prev is not None and now > prev[0]:
                inst = (int(pubs[i]) - prev[1]) / (now - prev[0])
                lam = self._lam.get(queue)
                if lam is None:
                    self._lam[queue] = inst
                else:
                    alpha = 1.0 - 0.5 ** ((now - prev[0])
                                          / cfg.lam_halflife_s)
                    self._lam[queue] = lam + alpha * (inst - lam)

        # risk assessment + scoring, one pass over the pods
        inflight = len(self._moving)
        topo = self.api.topology
        drain_cache: Dict[str, float] = {}
        for i, pod_name in enumerate(state["pods"]):
            pod = self.api.pods.get(pod_name)
            if pod is None or pod.deleted or not pod.serving:
                continue
            if not pod.node.alive:
                continue  # nothing can move off a dead node; wait for revive
            if pod.queue._primary_ref is not None:
                continue  # migration-internal target draining a mirror
            if pod.queue._mirror_sinks:
                continue  # source already mid-migration (someone's fleet)
            queue = state["queue"][i]
            if queue in self._moving:
                continue
            if self._cooldown_until.get(queue, -math.inf) > now:
                continue

            risk = 0.0
            reasons = []
            if self._suspect(pod.node.name):
                risk += cfg.flap_risk
                reasons.append("node_flap")
            node_drain = drain_cache.get(pod.node.name)
            if node_drain is None:
                node_drain = self._link_drain_s(pod.node.name)
                drain_cache[pod.node.name] = node_drain
            if node_drain > cfg.link_hot_drain_s:
                risk += cfg.link_risk
                reasons.append("link_saturated")
            # growth needs a full ring: a part-filled history (first ticks
            # after boot, or right after a move reset) is startup noise
            hist = self._depth_hist.get(queue, [])
            if (len(hist) >= cfg.growth_window_ticks
                    and hist[-1][0] > hist[0][0]):
                growth = ((hist[-1][1] - hist[0][1])
                          / (hist[-1][0] - hist[0][0]))
                if growth > cfg.growth_min_rate:
                    risk += cfg.growth_risk
                    reasons.append("queue_growth")
            risk = min(1.0, risk)
            if risk < cfg.min_risk:
                continue

            lam = self._lam.get(queue, 0.0)
            mu = 1000.0 / pod.processing_ms
            mar = predicted_messages_at_risk(lam, mu, float(depths[i]),
                                             cfg.horizon_s)
            target = self._pick_target(pod)
            if target is None:
                continue  # nowhere trustworthy to go
            from repro.core.strategy import worker_state_nbytes
            state_bytes = max(1, worker_state_nbytes(pod.worker))
            tgt_zone = topo.zone(target)
            cost = move_cost_bytes(
                state_bytes,
                topo.zone_distance(topo.registry_zone, tgt_zone),
                topo.zone_distance(topo.zone(pod.node.name), tgt_zone))
            score = move_score(risk, mar, cost)
            if score < cfg.min_score:
                self._emit("rebalance_skip", queue=queue, pod=pod_name,
                           score=score, risk=risk, reasons=reasons)
                continue
            candidates.append((score, queue, pod, target, risk, mar,
                               cost, reasons))

        if not candidates:
            return
        # deterministic admission: best score first, queue name tiebreak
        candidates.sort(key=lambda c: (-c[0], c[1]))
        budget = min(cfg.max_moves_per_tick,
                     max(0, cfg.max_inflight - inflight))
        if budget <= 0:
            return
        self._launch(candidates[:budget])

    def _pick_target(self, pod: Pod) -> Optional[str]:
        """Placement over the *trusted* nodes: alive, not the source, not
        suspect, not confirmed dead.  Reuses the orchestrator's placement
        policy so controller moves and operator drains score targets
        identically."""
        nodes = [n for n in self.api.nodes.values()
                 if n.alive and n.name != pod.node.name
                 and n.name not in self._dead
                 and not self._suspect(n.name)]
        if not nodes:
            return None
        return self.orch.placement(pod, nodes)

    def _launch(self, chosen: List[tuple]) -> None:
        from repro.core.orchestrator import PodMigrationSpec

        specs = []
        queues = []
        for score, queue, pod, target, risk, mar, cost, reasons in chosen:
            identity = self.orch.identity_of(pod)
            specs.append(PodMigrationSpec(
                pod=pod, queue=queue, target_node=target,
                strategy=("ms2m_statefulset" if identity
                          else self.config.strategy),
                identity=identity))
            queues.append(queue)
            self._moving.add(queue)
            self.n_moves_launched += 1
            self._emit("rebalance_move", queue=queue, pod=pod.name,
                       source=pod.node.name, target=target,
                       score=score, risk=risk,
                       messages_at_risk=round(mar, 3),
                       cost_bytes=round(cost, 1), reasons=reasons)
        cond = self.orch.migrate_fleet(
            specs, max_concurrent=self.config.max_moves_per_tick)
        self._fleets.append((cond, queues))


# ---------------------------------------------------------------------------
# Scenario harness: controller-on vs reactive baseline, same seed
# ---------------------------------------------------------------------------

def nimble_timings(**overrides) -> Any:
    """Infra timings for rebalancing scenarios: a fast CRIU/registry path
    (container-native checkpointing on warm caches) where one pod move
    lands in a few virtual seconds — the regime where acting on a flap
    *before* the next one is physically possible.  The paper-fitted
    defaults (~49 s per stop-and-copy) would make every proactive story
    a foregone loss; benchmarks state which timing set they use."""
    from repro.cluster.cluster import TimingConstants

    base = dict(checkpoint_s=1.0, image_build_s=1.0, delta_build_s=0.4,
                push_base_s=0.8, pull_base_s=0.7, restore_s=1.5,
                pod_create_s=0.5, pod_delete_s=0.3,
                sts_identity_release_s=1.0, route_switch_s=0.2,
                cutover_coord_s=0.1)
    base.update(overrides)
    return TimingConstants(**base)


@dataclasses.dataclass
class RebalanceResult:
    """One scenario run (a single (schedule, faults, controller?) cell)."""

    schedule: str
    controller: bool
    seed: int
    n_pods: int
    num_nodes: int
    t_end: float
    # exposure metrics (sampled every sample_dt of virtual time)
    unserved_queue_seconds: float = 0.0   # queue-seconds with no live consumer
    backlog_integral_msg_s: float = 0.0   # ∫ total backlog dt (msgs-at-risk)
    peak_backlog: int = 0
    # throughput/verification
    published_total: int = 0
    processed_total: int = 0
    verified: List[bool] = dataclasses.field(default_factory=list)
    # controller activity
    n_moves: int = 0
    n_failed_moves: int = 0
    moved_wire_bytes: int = 0
    n_detections: int = 0
    events: List[Dict[str, Any]] = dataclasses.field(default_factory=list)

    @property
    def all_verified(self) -> bool:
        return bool(self.verified) and all(self.verified)

    def row(self) -> Dict[str, Any]:
        return {
            "schedule": self.schedule, "controller": self.controller,
            "seed": self.seed, "n_pods": self.n_pods,
            "num_nodes": self.num_nodes, "t_end": self.t_end,
            "unserved_queue_seconds": round(self.unserved_queue_seconds, 6),
            "backlog_integral_msg_s": round(self.backlog_integral_msg_s, 6),
            "peak_backlog": int(self.peak_backlog),
            "published_total": self.published_total,
            "processed_total": self.processed_total,
            "all_verified": self.all_verified,
            "n_moves": self.n_moves,
            "n_failed_moves": self.n_failed_moves,
            "moved_wire_bytes": int(self.moved_wire_bytes),
            "n_detections": self.n_detections,
        }


def run_rebalance_scenario(
    *,
    registry_root: str,
    n_pods: int = 6,
    num_nodes: int = 4,
    message_rate: float = 6.0,
    schedule: str = "steady",
    schedule_kwargs: Optional[Dict[str, Any]] = None,
    faults: Any = None,
    seed: int = 0,
    t_end: float = 150.0,
    controller: Optional[RebalanceConfig] = None,
    worker_factory: Optional[Callable[[], Any]] = None,
    processing_ms: float = 50.0,
    timings: Any = None,
    topology: Any = None,
    placement: Any = None,
    policy: Any = None,
    sanitize: Optional[bool] = None,
    tiebreak_seed: Optional[int] = None,
    fluid: Optional[bool] = None,
    sample_dt: float = 2.0,
    drain_timeout_s: float = 240.0,
    verify: bool = True,
) -> RebalanceResult:
    """Drive one rebalancing scenario and measure service exposure.

    N queues x N seeded producers (``schedule`` selects the arrival
    modulation — see ``core.workload.make_arrival_gaps``) x N consumer
    pods spread over every node; ``faults`` injects the failure story.
    With ``controller=None`` the cluster is purely reactive (pods stall
    through partitions and catch up after — the baseline); with a
    ``RebalanceConfig`` the predictive controller runs and may drain pods
    ahead of predicted failures.  Identical seeds produce identical
    arrival sequences in both cells, so the exposure deltas are the
    controller's doing alone.

    Ends with source halt, full drain, and per-queue verification against
    an independent reference fold of each queue's published log."""
    import numpy as np
    from repro.cluster.cluster import Cluster
    from repro.core.orchestrator import ClusterMigrationOrchestrator
    from repro.core.policy import MigrationPolicy
    from repro.core.workload import (HashConsumer, make_arrival_gaps,
                                     reference_fold)

    timings = timings if timings is not None else nimble_timings()
    timings = dataclasses.replace(timings, processing_ms=processing_ms)
    cluster = Cluster(registry_root, timings=timings, num_nodes=num_nodes,
                      topology=topology, faults=faults, sanitize=sanitize,
                      tiebreak_seed=tiebreak_seed, fluid=fluid)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    make_worker = worker_factory or (lambda: HashConsumer())

    published: List[List[int]] = [[] for _ in range(n_pods)]
    stop_producing = {"flag": False}
    qnames = [f"orders-{i}" for i in range(n_pods)]

    for i in range(n_pods):
        queue = broker.declare_queue(qnames[i])

        def make_draw(i=i):
            rng = np.random.default_rng(seed * 1009 + i)
            gaps = make_arrival_gaps(schedule, rng, message_rate,
                                     **(schedule_kwargs or {}))

            def draw():
                if stop_producing["flag"]:
                    return None
                gap = next(gaps)
                return gap, {"token": int(rng.integers(0, 2048))}

            return draw

        def on_publish(msg, i=i):
            published[i].append(msg.payload["token"])

        queue.attach_source(make_draw(), on_publish=on_publish)

        def boot(i=i):
            pod = yield from api.create_pod(
                f"consumer-{i}", f"node{i % num_nodes}", make_worker(),
                broker.queues[qnames[i]])
            pod.start()

        sim.process(boot(), name=f"boot-{i}")

    orch = ClusterMigrationOrchestrator(
        api, make_worker,
        policy=policy or MigrationPolicy(max_attempts=3,
                                         retry_backoff_s=1.0),
        placement=placement)

    ctrl: Optional[RebalanceController] = None
    if controller is not None:
        ctrl = RebalanceController(api, orch, controller)
        ctrl.start()

    detections: List[tuple] = []

    def on_dead(name: str) -> None:
        detections.append((sim.now, name))
        if ctrl is not None:
            ctrl.on_node_dead(name)

    api.start_heartbeats(on_dead)

    result = RebalanceResult(schedule=schedule,
                             controller=controller is not None,
                             seed=seed, n_pods=n_pods, num_nodes=num_nodes,
                             t_end=t_end)
    sampling = {"on": True}

    def queue_depths() -> int:
        total = 0
        now = sim.now
        for q in qnames:
            mq = broker.queues[q]
            mq.sync(now)
            total += mq.depth()
        return total

    def sampler() -> Generator:
        while sampling["on"]:
            yield sample_dt
            if not sampling["on"]:
                return
            state = api.fleet_state()
            live: Dict[str, bool] = {}
            for j, q in enumerate(state["queue"]):
                pod = api.pods.get(state["pods"][j])
                ok = bool(pod is not None and not pod.deleted
                          and pod.node.alive and pod.serving)
                live[q] = live.get(q, False) or ok
            unserved = sum(1 for q in qnames if not live.get(q, False))
            depth = queue_depths()
            result.unserved_queue_seconds += unserved * sample_dt
            result.backlog_integral_msg_s += depth * sample_dt
            result.peak_backlog = max(result.peak_backlog, depth)

    sim.process(sampler(), name="rebalance-sampler")
    sim.run(until=t_end)

    # settle: no new admissions, land in-flight moves, stop traffic, drain
    if ctrl is not None:
        done = sim.process(ctrl.quiesce(), name="rebalance-quiesce")
        sim.run(stop_when=done)
    sampling["on"] = False
    stop_producing["flag"] = True
    for q in qnames:
        broker.queues[q].halt_source()
    deadline = sim.now + drain_timeout_s
    while sim.now < deadline:
        sim.run(until=sim.now + 2.0)
        if queue_depths() == 0:
            break
    for q in qnames:
        broker.queues[q].sync(sim.now)

    # -- final consumer per queue + verification -----------------------------
    consumers: Dict[str, Pod] = {}
    for pod in api.pods.values():
        if not pod.deleted and pod.queue.name in set(qnames):
            prev = consumers.get(pod.queue.name)
            if prev is None or (pod.serving and not prev.serving):
                consumers[pod.queue.name] = pod

    result.published_total = sum(len(p) for p in published)
    for i, q in enumerate(qnames):
        pod = consumers.get(q)
        if pod is None or not pod.node.alive:
            result.verified.append(False)
            continue
        result.processed_total += getattr(pod.worker, "n_processed", 0)
        if verify:
            ref = reference_fold(make_worker, published[i],
                                 pod.worker.last_msg_id)
            result.verified.append(bool(ref.state_equal(pod.worker)))
        else:
            result.verified.append(True)

    result.n_detections = len(detections)
    if ctrl is not None:
        result.n_moves = ctrl.n_moved
        result.n_failed_moves = ctrl.n_failed_moves
        result.moved_wire_bytes = ctrl.moved_wire_bytes
        result.events = ctrl.event_rows()
    return result
