"""Deterministic fault injection for the virtual-time cluster.

Real clusters lose nodes, links, registries and broker channels *mid-
migration*; the paper's pipeline (and the seed sim) assumed the
migration itself succeeds.  This module makes the hard scenarios
reproducible: a :class:`FaultSchedule` is a list of :class:`Fault`
entries fired either at exact sim times or at strategy-phase triggers
("during pre-copy round 2"), armed as sim processes by a
:class:`FaultInjector` (``Cluster(faults=...)`` wires one up; the CLI's
``--fault`` flag parses the same specs).

Fault kinds:

  * ``node_crash``      — hard kill: pods on the node die (``kill_node``);
    with ``duration`` the (empty) node revives afterwards;
  * ``node_flap``       — soft partition: the node drops off the network
    for ``duration`` seconds (pods stall in place, in-flight transfers
    abort) then revives and its pods resume (``partition_node`` /
    ``revive_node``);
  * ``link_degrade``    — the node's registry link runs at ``factor`` x
    capacity for ``duration`` seconds (shared links re-plan in-flight
    flows at the new rate);
  * ``registry_outage`` — every push/pull/prefetch fails fast and
    in-flight registry flows abort for ``duration`` seconds;
  * ``broker_stall``    — a queue (or every queue) stops delivering for
    ``duration`` seconds; publishes still land, so the stall delays but
    never loses messages.

Scheduling:

  * ``at=<t>``     — fire at absolute sim time ``t``;
  * ``phase=<p>``  — fire when a migration emits a matching trace event:
    ``"checkpoint"`` (or any phase name) matches that phase's boundary
    event, ``"precopy_round:2"`` matches pre-copy round 2's completion,
    any other event kind (``"cutoff_fired"``, ...) matches by kind.
    ``after`` delays the firing past the trigger.  Phase triggers fire
    once, on the first match.

``FaultSchedule.random(seed, ...)`` generates a seeded-random schedule —
the same seed always yields the same schedule, so chaos runs are
bit-reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

FAULT_KINDS = ("node_crash", "node_flap", "link_degrade",
               "registry_outage", "broker_stall")

_NODE_KINDS = ("node_crash", "node_flap", "link_degrade")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected failure: what, where, and when (time or phase)."""

    kind: str
    at: Optional[float] = None       # absolute sim time
    phase: Optional[str] = None      # strategy-phase trigger (see module doc)
    node: Optional[str] = None       # node_crash / node_flap / link_degrade
    queue: Optional[str] = None      # broker_stall (None = every queue)
    duration: float = 0.0            # flap/outage/stall/degrade window
    factor: float = 0.25             # link_degrade capacity multiplier
    after: float = 0.0               # extra delay past a phase trigger

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {list(FAULT_KINDS)}")
        if (self.at is None) == (self.phase is None):
            raise ValueError(
                f"fault {self.kind!r} needs exactly one of at= / phase=")
        if self.kind in _NODE_KINDS and self.node is None:
            raise ValueError(f"fault {self.kind!r} needs node=")
        if self.kind in ("node_flap", "link_degrade", "registry_outage",
                         "broker_stall") and self.duration <= 0:
            raise ValueError(f"fault {self.kind!r} needs duration > 0")
        if self.kind == "link_degrade" and not 0 < self.factor < 1:
            raise ValueError("link_degrade needs 0 < factor < 1")
        if self.phase is not None and self.phase.startswith("precopy_round:"):
            want = self.phase.partition(":")[2]
            try:
                int(want)
            except ValueError:
                raise ValueError(
                    f"fault phase {self.phase!r}: the round after "
                    "'precopy_round:' must be an integer") from None

    def row(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"kind": self.kind}
        for f in ("at", "phase", "node", "queue"):
            v = getattr(self, f)
            if v is not None:
                out[f] = v
        if self.duration:
            out["duration"] = self.duration
        if self.kind == "link_degrade":
            out["factor"] = self.factor
        if self.after:
            out["after"] = self.after
        return out


def parse_fault(spec: str) -> Fault:
    """Parse a CLI fault spec: ``kind@trigger[,key=value,...]``.

    The trigger is an absolute sim time when it parses as a float, else a
    phase spec.  Examples::

        node_flap@12,node=node1,duration=5
        node_crash@8.5,node=node2
        registry_outage@phase:precopy_round:1,duration=8
        link_degrade@20,node=node1,duration=10,factor=0.1
        broker_stall@15,queue=orders,duration=4
    """
    head, *pairs = spec.split(",")
    if "@" not in head:
        raise ValueError(f"fault spec {spec!r}: expected kind@trigger")
    kind, trigger = head.split("@", 1)
    kw: Dict[str, Any] = {}
    if trigger.startswith("phase:"):
        kw["phase"] = trigger[len("phase:"):]
    else:
        try:
            kw["at"] = float(trigger)
        except ValueError:
            kw["phase"] = trigger
    for pair in pairs:
        if "=" not in pair:
            raise ValueError(f"fault spec {spec!r}: bad pair {pair!r}")
        k, v = pair.split("=", 1)
        k = k.strip()
        if k in ("duration", "factor", "after", "at"):
            kw[k] = float(v)
        elif k in ("node", "queue", "phase"):
            kw[k] = v.strip()
        else:
            raise ValueError(f"fault spec {spec!r}: unknown key {k!r}")
    return Fault(kind=kind.strip(), **kw)


class FaultSchedule:
    """An ordered, immutable collection of faults (sorted by fire time;
    phase-triggered faults keep their declaration order at the end)."""

    def __init__(self, faults: Iterable[Fault] = ()):
        timed = [f for f in faults if f.at is not None]
        phased = [f for f in faults if f.at is None]
        self.faults: Tuple[Fault, ...] = tuple(
            sorted(timed, key=lambda f: f.at) + phased)

    def __iter__(self):
        return iter(self.faults)

    def __len__(self) -> int:
        return len(self.faults)

    def rows(self) -> List[Dict[str, Any]]:
        return [f.row() for f in self.faults]

    @classmethod
    def random(cls, seed: int, *,
               n_faults: int = 3,
               t_window: Tuple[float, float] = (5.0, 60.0),
               nodes: Sequence[str] = (),
               queues: Sequence[str] = (),
               kinds: Sequence[str] = FAULT_KINDS,
               flap_s: Tuple[float, float] = (1.0, 8.0),
               outage_s: Tuple[float, float] = (1.0, 8.0),
               stall_s: Tuple[float, float] = (1.0, 6.0),
               degrade_factor: Tuple[float, float] = (0.05, 0.5),
               degrade_s: Tuple[float, float] = (2.0, 12.0)
               ) -> "FaultSchedule":
        """Seeded-random schedule: same seed => same schedule => (given a
        deterministic workload) the same sim, bit for bit.

        Node-targeted kinds draw from ``nodes`` (pass only target-side
        nodes to keep migration *sources* safe); ``broker_stall`` draws
        from ``queues``.  Kinds that have no candidates are skipped.
        """
        import numpy as np

        rng = np.random.default_rng(seed)
        usable = [k for k in kinds
                  if not (k in _NODE_KINDS and not nodes)
                  and not (k == "broker_stall" and not queues)]
        if not usable:
            return cls(())
        faults: List[Fault] = []
        for _ in range(n_faults):
            kind = usable[int(rng.integers(0, len(usable)))]
            at = float(rng.uniform(*t_window))
            kw: Dict[str, Any] = {"kind": kind, "at": round(at, 3)}
            if kind in _NODE_KINDS:
                kw["node"] = nodes[int(rng.integers(0, len(nodes)))]
            if kind == "node_flap":
                kw["duration"] = round(float(rng.uniform(*flap_s)), 3)
            elif kind == "registry_outage":
                kw["duration"] = round(float(rng.uniform(*outage_s)), 3)
            elif kind == "broker_stall":
                kw["queue"] = queues[int(rng.integers(0, len(queues)))]
                kw["duration"] = round(float(rng.uniform(*stall_s)), 3)
            elif kind == "link_degrade":
                kw["factor"] = round(float(rng.uniform(*degrade_factor)), 3)
                kw["duration"] = round(float(rng.uniform(*degrade_s)), 3)
            faults.append(Fault(**kw))
        return cls(faults)


def make_schedule(faults: Any) -> FaultSchedule:
    """Resolve a faults argument: a ready FaultSchedule, a single Fault or
    spec string, or a list mixing Faults and spec strings."""
    if isinstance(faults, FaultSchedule):
        return faults
    if isinstance(faults, Fault):
        return FaultSchedule([faults])
    if isinstance(faults, str):
        return FaultSchedule([parse_fault(faults)])
    return FaultSchedule([f if isinstance(f, Fault) else parse_fault(f)
                          for f in faults])


class FaultInjector:
    """Arms a FaultSchedule against one APIServer: timed faults become
    ``sim.call_at`` firings, phase faults subscribe to the migration
    event stream.  ``log`` records every action taken, in firing order."""

    def __init__(self, api, schedule: FaultSchedule):
        self.api = api
        self.sim = api.sim
        self.schedule = schedule
        self.log: List[Dict[str, Any]] = []
        self._armed = False
        # overlapping-window bookkeeping: the registry comes back / a queue
        # unstalls / a link regains full capacity only when the LAST
        # overlapping window ends
        self._outage_depth = 0
        self._stall_depth: Dict[str, int] = {}
        self._degraded: Dict[str, List] = {}  # link name -> [base_Bps, depth]
        # nodes a permanent (duration-less) node_crash killed: a revive
        # scheduled by an earlier flap/timed crash must not resurrect them
        self._crashed: set = set()

    # -- arming ---------------------------------------------------------------
    def arm(self) -> "FaultInjector":
        if self._armed:
            return self
        self._armed = True
        phased = []
        for fault in self.schedule:
            if fault.at is not None:
                self.sim.call_at(fault.at,
                                 (lambda f=fault: self._fire(f)))
            else:
                phased.append({"fault": fault, "fired": False})
        if phased:
            def on_event(kind: str, t: float, data: dict):
                for entry in phased:
                    f = entry["fault"]
                    if entry["fired"] or not _phase_match(f.phase, kind,
                                                          data):
                        continue
                    entry["fired"] = True
                    if f.after > 0:
                        self.sim.call_after(f.after,
                                            (lambda f=f: self._fire(f)))
                    else:
                        self._fire(f)

            self.api.add_migration_listener(on_event)
        return self

    # -- firing ---------------------------------------------------------------
    def _note(self, fault: Fault, action: str, **kw):
        self.log.append({"t": round(self.sim.now, 6), "action": action,
                         **fault.row(), **kw})

    def _fire(self, fault: Fault) -> None:
        api = self.api
        if fault.kind == "node_crash":
            node = api.nodes.get(fault.node)
            if node is None or (not node.alive and not node.pods):
                # unknown node, or already hard-dead; a PARTITIONED node
                # (down but pods intact) is still crashable — the kill
                # must land so a pending flap revive cannot resurrect a
                # node this fault declared dead.  A permanent crash on an
                # already-dead node still declares permanence: any revive
                # a TIMED crash scheduled earlier must not undo it
                if fault.duration <= 0 and node is not None:
                    self._crashed.add(fault.node)
                self._note(fault, "skipped")
                return
            api.kill_node(fault.node)
            self._note(fault, "fired")
            if fault.duration > 0:
                self.sim.call_after(fault.duration,
                                    lambda: self._revive(fault))
            else:
                self._crashed.add(fault.node)
        elif fault.kind == "node_flap":
            node = api.nodes.get(fault.node)
            if node is None or not node.alive:
                self._note(fault, "skipped")
                return
            api.partition_node(fault.node)
            self._note(fault, "fired")
            self.sim.call_after(fault.duration, lambda: self._revive(fault))
        elif fault.kind == "link_degrade":
            if fault.node not in api.nodes:
                # an unknown node would silently resolve to the registry's
                # own intra-zone link (zone() falls back to registry_zone)
                # and degrade the wrong link — skip, like the node kinds
                self._note(fault, "skipped")
                return
            link = api.topology.registry_link(fault.node)
            entry = self._degraded.setdefault(link.name,
                                              [link.capacity_Bps, 0])
            entry[1] += 1  # overlapping degrades compose multiplicatively
            link.set_capacity(link.capacity_Bps * fault.factor)
            self._note(fault, "fired", capacity_Bps=link.capacity_Bps)
            self.sim.call_after(fault.duration,
                                lambda: self._restore_link(fault, link))
        elif fault.kind == "registry_outage":
            self._outage_depth += 1
            if self._outage_depth == 1:
                api.set_registry_up(False)
            self._note(fault, "fired")
            self.sim.call_after(fault.duration,
                                lambda: self._end_outage(fault))
        elif fault.kind == "broker_stall":
            queues = ([fault.queue] if fault.queue is not None
                      else sorted(api.broker.queues))
            for q in queues:
                self._stall_depth[q] = self._stall_depth.get(q, 0) + 1
                mq = api.broker.queues.get(q)
                if mq is not None:
                    mq.stall()
            self._note(fault, "fired", queues=queues)
            self.sim.call_after(fault.duration,
                                lambda: self._unstall(fault, queues))

    def _revive(self, fault: Fault) -> None:
        if fault.node in self._crashed:
            self._note(fault, "revive_superseded_by_crash")
            return
        node = self.api.nodes.get(fault.node)
        if node is not None and not node.alive:
            self.api.revive_node(fault.node)
            self._note(fault, "revived")

    def _restore_link(self, fault: Fault, link) -> None:
        entry = self._degraded[link.name]
        entry[1] -= 1
        if entry[1] == 0:
            # last overlapping window over: restore the pre-degrade
            # capacity bit-exactly (no float round-trip through factors)
            link.set_capacity(entry[0])
            del self._degraded[link.name]
        else:
            link.set_capacity(link.capacity_Bps / fault.factor)
        self._note(fault, "restored", capacity_Bps=link.capacity_Bps)

    def _end_outage(self, fault: Fault) -> None:
        self._outage_depth -= 1
        if self._outage_depth == 0:
            self.api.set_registry_up(True)
        self._note(fault, "ended")

    def _unstall(self, fault: Fault, queues: List[str]) -> None:
        for q in queues:
            self._stall_depth[q] -= 1
            if self._stall_depth[q] == 0:
                mq = self.api.broker.queues.get(q)
                if mq is not None:
                    mq.unstall()
        self._note(fault, "ended", queues=queues)


def _phase_match(spec: str, kind: str, data: dict) -> bool:
    """Does an emitted migration event match a phase trigger spec?

    ``"precopy_round:N"`` matches pre-copy round N's completion event;
    a bare phase name (``"checkpoint"``, ``"cutover"``, ...) matches that
    phase's boundary event; anything else matches by event kind
    (``"cutoff_fired"``, ``"migration_end"``, ...).
    """
    if spec.startswith("precopy_round"):
        if kind != "precopy_round":
            return False
        _, _, want = spec.partition(":")
        return not want or data.get("round") == int(want)
    if kind == "phase":
        return data.get("phase") == spec
    return kind == spec
