"""Zone-aware cluster network topology with contended, fair-shared links.

The paper's evaluation (and the seed sim) models one uncontended registry
link: every transfer was charged ``bytes / registry_bw_Bps`` in isolation,
so N concurrent migrations each moved at full bandwidth.  This module
replaces that with an explicit topology:

  * every node belongs to a **zone**; the registry has its own attachment
    zone (``registry_zone``);
  * traffic between two zones rides one shared :class:`~repro.cluster.sim.Link`
    per zone pair, classified as ``intra`` (same zone), ``cross``
    (different zones, same site) or ``wan`` (zone pairs listed in
    ``wan_pairs``), each with its own capacity, per-transfer latency and
    sharing mode;
  * concurrent transfers on a shared link split bandwidth max-min style
    (progressive filling — see ``sim.Link``), so fleet migrations finally
    pay for their concurrency.

Presets (``make_topology``):

  * ``flat``     — one zone, one dedicated-capacity link: **bit-identical**
    to the seed's single-registry-link constants (the backward-compat
    default);
  * ``two_zone`` — two equal zones, registry in zone-a; cross-zone traffic
    shares a 4x thinner link;
  * ``edge_wan`` — a core site (with the registry) and an edge site behind
    a 20x thinner, high-latency WAN uplink.

A ``NetworkTopology`` binds to exactly one ``Sim`` (links hold sim state);
build a fresh instance — or pass the preset name / a factory — per
experiment.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, FrozenSet, Iterable, List, Optional

from repro.cluster.sim import Link, Sim

LINK_CLASSES = ("intra", "cross", "wan")
_CLASS_RANK = {"intra": 0, "cross": 1, "wan": 2}


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Capacity + per-transfer latency + sharing mode of one link class."""

    capacity_Bps: float
    latency_s: float = 0.0
    shared: bool = True


class NetworkTopology:
    """Nodes -> zones, a registry attachment zone, and one lazily-built
    shared ``Link`` per zone pair."""

    def __init__(self, name: str, zone_of: Dict[str, str],
                 registry_zone: str, link_specs: Dict[str, LinkSpec],
                 wan_pairs: Iterable[Iterable[str]] = ()):
        if "intra" not in link_specs:
            raise ValueError("link_specs needs at least an 'intra' entry")
        unknown = set(link_specs) - set(LINK_CLASSES)
        if unknown:
            raise ValueError(f"unknown link class(es): {sorted(unknown)}")
        self.name = name
        self.zone_of = dict(zone_of)
        self.registry_zone = registry_zone
        self.link_specs = dict(link_specs)
        self.link_specs.setdefault("cross", self.link_specs["intra"])
        self.link_specs.setdefault("wan", self.link_specs["cross"])
        self.wan_pairs: set = {frozenset(p) for p in wan_pairs}
        self._sim: Optional[Sim] = None
        self._links: Dict[FrozenSet[str], Link] = {}

    # -- binding ---------------------------------------------------------------
    def bind(self, sim: Sim) -> "NetworkTopology":
        """Attach to a Sim.  One topology serves one cluster: links carry
        sim state, so rebinding to a different sim is an error."""
        if self._sim is not None and self._sim is not sim:
            raise RuntimeError(
                f"topology {self.name!r} is already bound to another Sim; "
                "build a fresh NetworkTopology per cluster/experiment")
        self._sim = sim
        return self

    def is_multizone(self) -> bool:
        """More than one distinct zone exists (nodes or registry)."""
        return len(set(self.zone_of.values()) | {self.registry_zone}) > 1

    def ensure_node(self, node: str, zone: Optional[str] = None) -> None:
        """Register a node in ``zone``.

        Already-registered nodes are left alone unless ``zone``
        contradicts the registration (that is a wiring bug, not a
        default).  For an unknown node, ``zone=None`` is only acceptable
        while the topology is single-zone (there is exactly one answer);
        on a multi-zone topology it would silently file the node next to
        the registry — ``zone_distance == 0`` — and every placement
        scorer (and the rebalance controller) would systematically
        prefer it, so an explicit zone is required there."""
        have = self.zone_of.get(node)
        if have is not None:
            if zone is not None and zone != have:
                raise ValueError(
                    f"node {node!r} is already in zone {have!r}; "
                    f"cannot re-register it in {zone!r}")
            return
        if zone is None:
            if self.is_multizone():
                raise ValueError(
                    f"node {node!r} needs an explicit zone on multi-zone "
                    f"topology {self.name!r} (zones: "
                    f"{sorted(set(self.zone_of.values()) | {self.registry_zone})}); "
                    "defaulting to the registry zone would give it "
                    "zone_distance == 0 and bias every placement score "
                    "toward it")
            zone = self.registry_zone
        self.zone_of[node] = zone

    # -- classification --------------------------------------------------------
    def zone(self, node: Optional[str]) -> str:
        if node is None:
            return self.registry_zone
        return self.zone_of.get(node, self.registry_zone)

    def link_class(self, zone_a: str, zone_b: str) -> str:
        if zone_a == zone_b:
            return "intra"
        if frozenset((zone_a, zone_b)) in self.wan_pairs:
            return "wan"
        return "cross"

    def zone_distance(self, zone_a: str, zone_b: str) -> int:
        """Rank of the link class between two zones: intra=0 cross=1 wan=2
        (the placement score's distance term)."""
        return _CLASS_RANK[self.link_class(zone_a, zone_b)]

    # -- links -----------------------------------------------------------------
    def link_between(self, zone_a: str, zone_b: str) -> Link:
        if self._sim is None:
            raise RuntimeError(
                f"topology {self.name!r} is not bound to a Sim yet")
        key = frozenset((zone_a, zone_b))
        link = self._links.get(key)
        if link is None:
            cls = self.link_class(zone_a, zone_b)
            spec = self.link_specs[cls]
            link = Link(self._sim, spec.capacity_Bps,
                        latency_s=spec.latency_s, shared=spec.shared,
                        name=f"{cls}:{'|'.join(sorted(key))}")
            self._links[key] = link
        return link

    def registry_link(self, node: Optional[str]) -> Link:
        """The link a node's registry traffic (push/pull/prefetch) rides."""
        return self.link_between(self.zone(node), self.registry_zone)

    def registry_capacity_Bps(self, node: Optional[str] = None) -> float:
        return self.link_specs[
            self.link_class(self.zone(node), self.registry_zone)].capacity_Bps

    def links(self) -> List[Link]:
        return [self._links[k] for k in sorted(self._links,
                                               key=lambda k: sorted(k))]

    def stats(self) -> Dict[str, Any]:
        """Telemetry for reports/benchmarks: per-link byte/flow counters."""
        return {"topology": self.name,
                "zones": sorted(set(self.zone_of.values())
                                | {self.registry_zone}),
                "links": [link.stats() for link in self.links()]}


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

def _split_zones(node_names: Iterable[str], first: str, second: str
                 ) -> Dict[str, str]:
    """First half of the nodes in ``first`` (at least one), rest in
    ``second`` — the deterministic preset layout."""
    names = list(node_names)
    cut = max(1, len(names) // 2)
    return {n: (first if i < cut else second) for i, n in enumerate(names)}


def flat_topology(node_names: Iterable[str] = (),
                  registry_bw_Bps: float = 200e6) -> NetworkTopology:
    """One zone, one dedicated-capacity registry link: bit-identical to
    the seed's uncontended ``bytes / registry_bw_Bps`` model."""
    return NetworkTopology(
        "flat", {n: "flat" for n in node_names}, "flat",
        {"intra": LinkSpec(registry_bw_Bps, latency_s=0.0, shared=False)})


def two_zone_topology(node_names: Iterable[str] = (),
                      registry_bw_Bps: float = 200e6,
                      cross_ratio: float = 0.25,
                      intra_latency_s: float = 0.02,
                      cross_latency_s: float = 0.1) -> NetworkTopology:
    """Two equal zones (registry in zone-a); each zone's fabric and the
    cross-zone trunk are shared, the trunk 4x thinner."""
    return NetworkTopology(
        "two_zone", _split_zones(node_names, "zone-a", "zone-b"), "zone-a",
        {"intra": LinkSpec(registry_bw_Bps, latency_s=intra_latency_s),
         "cross": LinkSpec(registry_bw_Bps * cross_ratio,
                           latency_s=cross_latency_s)})


def edge_wan_topology(node_names: Iterable[str] = (),
                      registry_bw_Bps: float = 200e6,
                      wan_ratio: float = 0.05,
                      intra_latency_s: float = 0.01,
                      wan_latency_s: float = 0.3) -> NetworkTopology:
    """A core site (first half of the nodes, with the registry) and an
    edge site behind a 20x thinner, high-latency shared WAN uplink."""
    return NetworkTopology(
        "edge_wan", _split_zones(node_names, "core", "edge"), "core",
        {"intra": LinkSpec(registry_bw_Bps, latency_s=intra_latency_s),
         "wan": LinkSpec(registry_bw_Bps * wan_ratio,
                         latency_s=wan_latency_s)},
        wan_pairs=[("core", "edge")])


TOPOLOGY_PRESETS: Dict[str, Callable[..., NetworkTopology]] = {
    "flat": flat_topology,
    "two_zone": two_zone_topology,
    "edge_wan": edge_wan_topology,
}


def available_topologies() -> List[str]:
    return sorted(TOPOLOGY_PRESETS)


def topology_entries() -> List[Dict[str, str]]:
    """One row per preset: name + docstring summary (CLI --list-topologies
    and the docs table read this)."""
    rows = []
    for name in available_topologies():
        doc = (TOPOLOGY_PRESETS[name].__doc__ or "").strip()
        rows.append({"name": name,
                     "summary": " ".join(line.strip()
                                         for line in doc.splitlines())})
    return rows


def make_topology(topology: Any, node_names: Iterable[str],
                  registry_bw_Bps: float) -> NetworkTopology:
    """Resolve a topology argument: None -> flat (legacy behaviour), a
    preset name, a ready ``NetworkTopology``, or a factory called as
    ``factory(node_names, registry_bw_Bps)``."""
    if topology is None:
        topology = "flat"
    if isinstance(topology, NetworkTopology):
        return topology
    if isinstance(topology, str):
        try:
            factory = TOPOLOGY_PRESETS[topology]
        except KeyError:
            raise ValueError(
                f"unknown topology preset {topology!r}; "
                f"available: {available_topologies()}") from None
        return factory(node_names, registry_bw_Bps=registry_bw_Bps)
    if callable(topology):
        return topology(node_names, registry_bw_Bps)
    raise TypeError(f"cannot build a topology from {topology!r}")
