"""Virtual-time Kubernetes-like cluster runtime.

Maps the paper's actors onto simulation objects:
  * ``Node`` — worker machine hosting Pods (kill-able: failure injection);
  * ``Pod``  — one consumer worker + its run-loop process;
  * ``APIServer`` — the control-plane facade the Migration Manager talks
    to: pod lifecycle, FCC checkpointing, image build/push/pull/restore.
    All infra operations are generator sub-processes charging calibrated
    virtual-time constants plus *real* registry byte counts / bandwidth;
  * ``StatefulSetController`` — sticky identity: a named replica's new Pod
    cannot be created until the old one is fully deleted (identity release),
    which is exactly why MS2M-for-StatefulSet must stop-then-replay.
  * heartbeat failure detector + reconciliation (checkpoint/restart FT path).

Calibration: constants default to values fitted to the paper's measured
sub-process distribution (Figs 5-14); benchmarks/constants.py documents the
derivation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Generator, List, Optional

from repro.analysis.sanitizer import capture_site
from repro.broker.broker import Broker, MessageQueue
from repro.checkpoint.registry import Registry
from repro.cluster.network import NetworkTopology, flat_topology, make_topology
from repro.cluster.sim import Condition, Sim, TransferAborted


@dataclasses.dataclass
class TimingConstants:
    """Virtual-time costs of infra sub-processes (seconds).

    Fitted so stop-and-copy totals ~49s (paper Fig. 5) with the paper's
    sub-process proportions; transfer terms add real_bytes / bandwidth.
    """

    checkpoint_s: float = 8.0          # FCC/CRIU dump of the pod
    image_build_s: float = 11.0        # buildah OCI image assembly
    delta_build_s: float = 2.5         # incremental layer assembly (pre-copy)
    push_base_s: float = 6.0           # registry round-trips
    pull_base_s: float = 5.0
    registry_bw_Bps: float = 200e6     # artifact registry bandwidth
    # checkpoint data-path compute: wire time is charged on *encoded*
    # bytes, so the codec's own cost must be charged too (raw bytes fed
    # through a delta-codec encoder), as must the device-side fingerprint
    # pass (a streaming reduction, so near memory bandwidth)
    codec_Bps: float = 1.2e9
    fingerprint_Bps: float = 24e9
    restore_s: float = 13.0            # CRIU restore into a fresh container
    pod_create_s: float = 3.0          # scheduling + sandbox start
    pod_delete_s: float = 2.0          # SIGTERM + teardown
    sts_identity_release_s: float = 8.0  # StatefulSet graceful identity release
    route_switch_s: float = 0.9        # consumer rebind / traffic redirect
    cutover_coord_s: float = 0.5       # pause coordination during cutover
    processing_ms: float = 50.0        # per-message service time (paper: 50ms)
    heartbeat_interval_s: float = 2.0
    heartbeat_timeout_s: float = 6.0

    @classmethod
    def from_roofline(cls, roofline, **overrides) -> "TimingConstants":
        """Constants with the data-path throughputs recalibrated from a
        measured codec roofline (``benchmarks/roofline.py --codec`` ->
        ``results/codec_roofline.json``, or its loaded dict).

        The class defaults above stay the paper-fitted constants — every
        regression timeline is pinned to them bit-for-bit — so measured
        throughput is strictly opt-in via this constructor.
        """
        import json

        if isinstance(roofline, str):
            with open(roofline) as f:
                roofline = json.load(f)
        cal = roofline.get("calibration", roofline)
        kw = {}
        if cal.get("codec_Bps"):
            kw["codec_Bps"] = float(cal["codec_Bps"])
        if cal.get("fingerprint_Bps"):
            kw["fingerprint_Bps"] = float(cal["fingerprint_Bps"])
        kw.update(overrides)
        return cls(**kw)


class Node:
    def __init__(self, name: str, sim: Optional[Sim] = None):
        self.name = name
        self.alive = True
        self.pods: Dict[str, "Pod"] = {}
        self.last_heartbeat = 0.0
        # local image-layer cache (chunk keys): prefetched/pulled chunks are
        # free on later pulls — how pre-copy makes the final restore cheap
        self.image_chunks: set = set()
        # triggered when the node dies: in-flight link transfers touching
        # this node wait on it and abort; replaced fresh on revive
        self.down: Optional[Condition] = (Condition(sim, f"{name}:down")
                                          if sim is not None else None)


class Pod:
    """A consumer worker plus its service loop."""

    def __init__(self, name: str, node: Node, worker, queue: MessageQueue,
                 sim: Sim, timings: TimingConstants,
                 processing_ms: Optional[float] = None):
        self.name = name
        self.node = node
        self.worker = worker
        self.queue = queue
        self.sim = sim
        self.timings = timings
        self.processing_ms = (timings.processing_ms
                              if processing_ms is None else processing_ms)
        self.serving = False
        self.deleted = False
        self.paused = False
        self.service_log: List[tuple] = []  # (virtual_time, msg_id)
        # single-slot hook (owned by the workload) + removable listeners
        # (owned by migrations, which must deregister on completion)
        self.on_processed: Optional[Callable] = None
        self.on_processed_listeners: List[Callable] = []
        self.in_flight = None  # message popped but not yet folded/requeued
        self._loop_started = False
        self._wake: Optional[Condition] = None

    @property
    def busy(self) -> bool:
        """True while a popped message is mid-service (in flight)."""
        return self.in_flight is not None

    def add_on_processed(self, fn: Callable):
        self.on_processed_listeners.append(fn)
        if self.sim.sanitizer is not None:
            self.sim.sanitizer.check_listener_growth(
                f"pod {self.name!r} on_processed list",
                len(self.on_processed_listeners))

    def remove_on_processed(self, fn: Callable):
        if fn in self.on_processed_listeners:
            self.on_processed_listeners.remove(fn)

    def _notify_processed(self, msg):
        if self.on_processed:
            self.on_processed(self, msg)
        for fn in list(self.on_processed_listeners):
            fn(self, msg)

    # -- service loop ---------------------------------------------------------
    def start(self):
        self.serving = True
        if not self._loop_started:
            self._loop_started = True
            self.sim.process(self._run(), name=f"pod:{self.name}")

    def pause(self):
        if self.sim.sanitizer is not None:
            self.sim.sanitizer.on_pause(self)
        self.paused = True
        self.serving = False

    def resume(self):
        self.paused = False
        self.serving = True
        self.wake()  # release a condition-stalled loop

    def stop(self):
        self.deleted = True
        self.serving = False
        if self.sim.sanitizer is not None:
            self.sim.sanitizer.unprotect_pod(self)
        self.wake()

    def wake(self):
        """Unblock the loop (e.g. after a queue switch)."""
        if self._wake is not None:
            cond, self._wake = self._wake, None
            cond.trigger()

    def _run(self) -> Generator:
        while not self.deleted:
            if self.paused or not self.node.alive:
                # condition-based stall, not a busy-poll: a paused pod (e.g.
                # the source of a long migration after the cutoff fired)
                # contributes ZERO sim events until resume()/stop()/node
                # recovery wakes it
                self._wake = self.sim.condition(f"{self.name}:stall")
                yield self._wake
                continue
            msg = self.queue.try_get()
            if msg is None:
                self._wake = self.sim.condition(f"{self.name}:wake")
                yield self.sim.any_of(self.queue.wait_not_empty(), self._wake)
                continue
            # at-least-once dedup guard: ids are totally ordered, so a
            # message already folded into the state is skipped for free
            skip_until = getattr(self.worker, "skip_until", -1)
            if msg.msg_id <= max(skip_until, self.worker.last_msg_id):
                continue
            self.in_flight = msg
            yield self.processing_ms / 1000.0  # service time (virtual)
            if self.deleted or self.paused or not self.node.alive:
                # interrupted mid-service (pause, delete, or the node went
                # down under us — a soft partition must not fold state
                # while "offline"): message returns to the queue; the
                # id-dedup guard above makes the eventual redelivery
                # exactly-once
                self.queue.requeue_front(msg)
                self.in_flight = None
                continue
            self.worker.process(msg)  # real JAX state update
            self.in_flight = None
            self.service_log.append((self.sim.now, msg.msg_id))
            self._notify_processed(msg)


class StatefulSetController:
    """Sticky identity bookkeeping: replica name -> live pod (at most one)."""

    def __init__(self):
        self.identities: Dict[str, Optional[str]] = {}

    def claim(self, replica: str, pod_name: str):
        if self.identities.get(replica) is not None:
            raise RuntimeError(
                f"StatefulSet identity {replica} still held by "
                f"{self.identities[replica]}")
        self.identities[replica] = pod_name

    def release(self, replica: str):
        self.identities[replica] = None


class APIServer:
    """Control-plane facade: what the Migration Manager calls."""

    def __init__(self, sim: Sim, broker: Broker, registry: Registry,
                 timings: TimingConstants,
                 topology: Optional[NetworkTopology] = None):
        self.sim = sim
        self.broker = broker
        self.registry = registry
        self.timings = timings
        # default: the flat preset — one dedicated-capacity registry link,
        # bit-identical to the seed's bytes / registry_bw_Bps model
        self.topology = (topology if topology is not None else
                         flat_topology(
                             registry_bw_Bps=timings.registry_bw_Bps))
        self.topology.bind(sim)
        self.nodes: Dict[str, Node] = {}
        self.pods: Dict[str, Pod] = {}
        self.statefulsets = StatefulSetController()
        self.events: List[tuple] = []
        # registry availability (fault injection): while False every
        # node<->registry transfer fails fast with TransferAborted
        self.registry_up = True
        # in-flight registry transfers: (node_name, abort Condition) ->
        # creation site, so node deaths and registry outages can abort
        # exactly the affected flows without leaking callbacks on
        # long-lived conditions.  A dict (insertion-ordered), not a set:
        # set iteration order follows object hashes, and the abort fan-out
        # must not depend on ids
        self._live_transfers: Dict[tuple, Any] = {}
        # migration-event listeners (fault injection phase triggers, test
        # probes): called as fn(kind, t, data) for every MigrationContext
        # emit
        self.migration_listeners: List[Callable[[str, float, dict],
                                               None]] = []

    def add_migration_listener(self, fn: Callable[[str, float, dict],
                                                  None]) -> None:
        self.migration_listeners.append(fn)
        if self.sim.sanitizer is not None:
            self.sim.sanitizer.check_listener_growth(
                "api migration_listeners", len(self.migration_listeners))

    def remove_migration_listener(self, fn: Callable) -> None:
        if fn in self.migration_listeners:
            self.migration_listeners.remove(fn)

    def _log(self, kind: str, **kw):
        self.events.append((self.sim.now, kind, kw))

    def notify_migration(self, kind: str, t: float, data: dict) -> None:
        for fn in list(self.migration_listeners):
            fn(kind, t, data)

    def _abort_transfers(self, node_name: Optional[str]) -> None:
        """Trigger the abort condition of every in-flight registry
        transfer touching ``node_name`` (None = all of them)."""
        for entry_node, cond in list(self._live_transfers):
            if node_name is None or entry_node == node_name:
                cond.trigger()

    # -- topology --------------------------------------------------------------
    def add_node(self, name: str) -> Node:
        node = Node(name, sim=self.sim)
        self.nodes[name] = node
        self.topology.ensure_node(name)
        return node

    def kill_node(self, name: str):
        """Failure injection (hard crash): every pod on the node dies
        instantly, and every in-flight link transfer touching the node
        aborts."""
        node = self.nodes[name]
        node.alive = False
        for pod in list(node.pods.values()):
            pod.stop()
            self.pods.pop(pod.name, None)
        node.pods.clear()
        if node.down is not None:
            node.down.trigger()
        self._abort_transfers(name)
        self._log("node_killed", node=name)

    def partition_node(self, name: str):
        """Failure injection (soft/transient): the node drops off the
        network — its pods stall in place (state intact, nothing folded
        while "offline"; a mid-service message is requeued) and its
        in-flight transfers abort — but unlike :meth:`kill_node` the pods
        survive and resume on :meth:`revive_node`.  Models a network
        partition / kernel hang / reboot-without-data-loss: the flapping
        half of a ``node_flap`` fault."""
        node = self.nodes[name]
        node.alive = False
        for pod in node.pods.values():
            pod.wake()  # re-enter the loop so it sees node.alive == False
        if node.down is not None:
            node.down.trigger()
        self._abort_transfers(name)
        self._log("node_partitioned", node=name)

    def revive_node(self, name: str):
        """Bring a node back (maintenance over / transient partition healed)
        and wake any pod whose service loop stalled on the dead node."""
        node = self.nodes[name]
        node.alive = True
        node.last_heartbeat = self.sim.now
        node.down = Condition(self.sim, f"{name}:down")  # re-arm the abort
        for pod in list(node.pods.values()):
            pod.wake()
        self._log("node_revived", node=name)

    # -- registry availability (fault injection) --------------------------------
    def set_registry_up(self, up: bool):
        """Registry outage toggle: while down, every push/pull/prefetch
        fails fast with ``TransferAborted`` and in-flight registry flows
        abort (the artifact registry is a single external dependency —
        when it is unreachable no node can move bytes)."""
        was = self.registry_up
        self.registry_up = up
        if was and not up:
            self._abort_transfers(None)
            self._log("registry_outage_begin")
        elif not was and up:
            self._log("registry_outage_end")

    # -- pod lifecycle (generator sub-processes) --------------------------------
    def create_pod(self, name: str, node_name: str, worker,
                   queue: MessageQueue, *, statefulset_identity=None,
                   processing_ms=None) -> Generator:
        t = self.timings
        yield t.pod_create_s
        node = self.nodes[node_name]
        if not node.alive:
            raise RuntimeError(f"node {node_name} is dead")
        if statefulset_identity is not None:
            self.statefulsets.claim(statefulset_identity, name)
        pod = Pod(name, node, worker, queue, self.sim, t,
                  processing_ms=processing_ms)
        node.pods[name] = pod
        self.pods[name] = pod
        self._log("pod_created", pod=name, node=node_name)
        return pod

    def delete_pod(self, name: str, *, statefulset_identity=None,
                   graceful: bool = True) -> Generator:
        t = self.timings
        pod = self.pods.get(name)
        if pod is not None:
            pod.stop()
        yield t.pod_delete_s if graceful else 0.1
        if statefulset_identity is not None:
            yield t.sts_identity_release_s
            self.statefulsets.release(statefulset_identity)
        if pod is not None:
            pod.node.pods.pop(name, None)
            self.pods.pop(name, None)
        self._log("pod_deleted", pod=name)

    # -- FCC: checkpoint / image / restore --------------------------------------
    def checkpoint_pod(self, pod: Pod) -> Generator:
        """FCC dump: snapshot the worker's state tree (real pytree)."""
        t = self.timings
        yield t.checkpoint_s
        state = pod.worker.state_tree()
        marker = pod.worker.last_msg_id
        self._log("checkpointed", pod=pod.name, last_msg_id=marker)
        return {"state": state, "last_msg_id": marker}

    def _data_path_cost_s(self, report) -> float:
        """Codec encode + device fingerprint compute for one push."""
        t = self.timings
        return (report.enc_raw_bytes / t.codec_Bps
                + report.fp_bytes / t.fingerprint_Bps)

    def _registry_transfer(self, node_name: Optional[str], nbytes: float,
                           base_s: float, extra_s: float = 0.0) -> Generator:
        """Charge one node<->registry transfer over the topology link.

        Dedicated links (the ``flat`` preset) are charged as one combined
        delay with the exact legacy ``base + bytes/bw + extra`` float
        arithmetic, so flat timelines stay bit-identical to the seed —
        including the seed's semantics that a mid-flight node death does
        NOT interrupt the delay (a dead node still fails fast before the
        transfer starts).  Shared links charge the fixed costs first, then
        join the link as a fair-share flow; if the node dies mid-flight
        the flow aborts with ``TransferAborted`` (the fleet orchestrator's
        guard isolates it)."""
        node = self.nodes.get(node_name) if node_name is not None else None
        if node is not None and not node.alive:
            raise TransferAborted(f"node {node_name} is dead")
        if not self.registry_up:
            raise TransferAborted("registry outage: transfer rejected")
        link = self.topology.registry_link(node_name)
        if not link.shared:
            dur = base_s + nbytes / link.capacity_Bps + extra_s
            if link.latency_s:
                dur += link.latency_s
            link.total_bytes += nbytes
            yield dur
            return
        yield base_s + extra_s
        # re-check after the fixed costs: the node may have died or the
        # registry gone down while they were being charged
        if node is not None and not node.alive:
            raise TransferAborted(f"node {node_name} is dead")
        if not self.registry_up:
            raise TransferAborted("registry outage: transfer rejected")
        # per-transfer abort condition, registered so node deaths and
        # registry outages can fan out to exactly the affected flows (and
        # nothing accumulates on long-lived conditions)
        abort = Condition(self.sim, "xfer-abort")
        entry = (node_name, abort)
        self._live_transfers[entry] = (
            capture_site() if self.sim.sanitizer is not None else None)
        try:
            yield from link.transfer(nbytes, abort=abort)
        finally:
            self._live_transfers.pop(entry, None)

    def build_and_push_image(self, checkpoint: dict, tag: str,
                             node_name: Optional[str] = None,
                             on_pushed: Optional[Callable[[str], None]]
                             = None) -> Generator:
        """Image Manager: OCI assembly + registry push (real bytes) over
        the pushing node's registry link.  ``on_pushed`` fires with the
        image id as soon as the registry holds it — BEFORE the transfer
        is charged, which can abort — so rollback can garbage-collect an
        image whose push died mid-wire."""
        t = self.timings
        yield t.image_build_s
        report = self.registry.push_image(
            {"state": checkpoint["state"]},
            meta={"last_msg_id": int(checkpoint["last_msg_id"]), "tag": tag},
            tag=tag,
        )
        if on_pushed is not None:
            on_pushed(report.image_id)
        yield from self._registry_transfer(
            node_name, report.written_bytes, t.push_base_s,
            extra_s=self._data_path_cost_s(report))
        self._log("image_pushed", tag=tag, image_id=report.image_id,
                  written=report.written_bytes, deduped=report.deduped_bytes)
        return report

    def push_delta_image(self, checkpoint: dict, tag: str,
                         parent_image_id: str, *,
                         compression="none", exact: bool = False,
                         node_name: Optional[str] = None,
                         on_pushed: Optional[Callable[[str], None]]
                         = None) -> Generator:
        """Pre-copy round: delta layer vs the parent image — the wire only
        carries *encoded* chunks the registry doesn't already hold.
        ``compression`` selects the per-leaf delta codec; ``exact=True``
        restricts it to lossless codecs (the pre-copy final flush).
        ``on_pushed`` fires with the image id before the (abortable)
        transfer — see ``build_and_push_image``."""
        t = self.timings
        yield t.delta_build_s
        report = self.registry.push_delta(
            {"state": checkpoint["state"]}, parent_image_id,
            meta={"last_msg_id": int(checkpoint["last_msg_id"]), "tag": tag},
            tag=tag, compression=compression, exact=exact,
        )
        if on_pushed is not None:
            on_pushed(report.image_id)
        yield from self._registry_transfer(
            node_name, report.written_bytes, t.push_base_s,
            extra_s=self._data_path_cost_s(report))
        self._log("delta_pushed", tag=tag, image_id=report.image_id,
                  parent=parent_image_id, delta=report.delta_bytes,
                  wire=report.wire_bytes, written=report.written_bytes,
                  codec=report.codec, lossy=report.lossy)
        return report

    def prefetch_image(self, node_name: str, image_id: str) -> Generator:
        """Warm a node's layer cache while the source keeps serving; the
        final restore then pulls only what prefetching missed."""
        t = self.timings
        node = self.nodes[node_name]
        chunks = self.registry.image_chunks(image_id)
        new_bytes = sum(size for key, size in chunks.items()
                        if key not in node.image_chunks)
        yield from self._registry_transfer(node_name, new_bytes,
                                           t.pull_base_s)
        # cache only after the transfer lands: a concurrent pull to the same
        # node must not ride for free on bytes still in flight
        node.image_chunks.update(chunks)
        self._log("image_prefetched", node=node_name, image_id=image_id,
                  bytes=new_bytes)
        return new_bytes

    def pull_and_restore(self, image_id: str, worker,
                         node_name: Optional[str] = None) -> Generator:
        """Target node: pull from registry, restore worker state.  With
        ``node_name``, the node's layer cache discounts already-held
        chunks (and is updated with the pulled ones)."""
        t = self.timings
        node = self.nodes[node_name] if node_name is not None else None
        trees, pulled = self.registry.pull_image(
            image_id,
            have_chunks=node.image_chunks if node is not None else None)
        yield from self._registry_transfer(node_name, pulled, t.pull_base_s)
        if node is not None:  # cache after the transfer lands (see prefetch)
            node.image_chunks.update(self.registry.image_chunks(image_id))
        yield t.restore_s
        worker.load_state(trees["state"])
        meta = self.registry.image_meta(image_id)
        self._log("restored", image_id=image_id, pulled=pulled,
                  last_msg_id=meta.get("last_msg_id"))
        return meta

    # -- failure detection / reconciliation -------------------------------------
    def start_heartbeats(self, on_node_dead: Callable[[str], None]):
        t = self.timings

        def monitor() -> Generator:
            while True:
                yield t.heartbeat_interval_s
                for node in self.nodes.values():
                    if node.alive:
                        node.last_heartbeat = self.sim.now
                    elif self.sim.now - node.last_heartbeat > t.heartbeat_timeout_s:
                        node.last_heartbeat = float("inf")  # fire once
                        on_node_dead(node.name)

        self.sim.process(monitor(), name="heartbeat-monitor")


class Cluster:
    """Convenience bundle: sim + broker + registry + api server.

    ``topology`` selects the network model: ``None`` / ``"flat"`` (the
    seed-identical uncontended registry link), another preset name
    (``"two_zone"``, ``"edge_wan"``), a ready ``NetworkTopology``, or a
    factory ``(node_names, registry_bw_Bps) -> NetworkTopology``.

    ``faults`` injects a deterministic failure schedule: a
    ``repro.cluster.faults.FaultSchedule``, a list of ``Fault``s / fault
    spec strings, or ``None`` (no faults — the default).  The schedule is
    armed immediately: timed faults become sim processes, phase-triggered
    faults subscribe to migration events."""

    def __init__(self, registry_root: str,
                 timings: Optional[TimingConstants] = None,
                 num_nodes: int = 3,
                 chunk_bytes: Optional[int] = None,
                 topology=None,
                 faults=None,
                 sanitize: Optional[bool] = None,
                 tiebreak_seed: Optional[int] = None):
        self.sim = Sim(sanitize=sanitize, tiebreak_seed=tiebreak_seed)
        self.broker = Broker(self.sim)
        self.registry = Registry(registry_root, chunk_bytes=chunk_bytes)
        self.timings = timings or TimingConstants()
        node_names = [f"node{i}" for i in range(num_nodes)]
        self.topology = make_topology(topology, node_names,
                                      self.timings.registry_bw_Bps)
        self.api = APIServer(self.sim, self.broker, self.registry,
                             self.timings, topology=self.topology)
        for name in node_names:
            self.api.add_node(name)
        self.faults = None
        if faults is not None:
            from repro.cluster.faults import FaultInjector, make_schedule
            self.faults = FaultInjector(self.api, make_schedule(faults))
            self.faults.arm()
