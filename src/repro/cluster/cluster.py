"""Virtual-time Kubernetes-like cluster runtime.

Maps the paper's actors onto simulation objects:
  * ``Node`` — worker machine hosting Pods (kill-able: failure injection);
  * ``Pod``  — one consumer worker + its run-loop process;
  * ``APIServer`` — the control-plane facade the Migration Manager talks
    to: pod lifecycle, FCC checkpointing, image build/push/pull/restore.
    All infra operations are generator sub-processes charging calibrated
    virtual-time constants plus *real* registry byte counts / bandwidth;
  * ``StatefulSetController`` — sticky identity: a named replica's new Pod
    cannot be created until the old one is fully deleted (identity release),
    which is exactly why MS2M-for-StatefulSet must stop-then-replay.
  * heartbeat failure detector + reconciliation (checkpoint/restart FT path).

Calibration: constants default to values fitted to the paper's measured
sub-process distribution (Figs 5-14); benchmarks/constants.py documents the
derivation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Generator, List, Optional

from repro.analysis.sanitizer import capture_site
from repro.broker.broker import Broker, MessageQueue
from repro.checkpoint.registry import Registry
from repro.cluster.network import NetworkTopology, flat_topology, make_topology
from repro.cluster.sim import Condition, Sim, TransferAborted


@dataclasses.dataclass
class TimingConstants:
    """Virtual-time costs of infra sub-processes (seconds).

    Fitted so stop-and-copy totals ~49s (paper Fig. 5) with the paper's
    sub-process proportions; transfer terms add real_bytes / bandwidth.
    """

    checkpoint_s: float = 8.0          # FCC/CRIU dump of the pod
    image_build_s: float = 11.0        # buildah OCI image assembly
    delta_build_s: float = 2.5         # incremental layer assembly (pre-copy)
    push_base_s: float = 6.0           # registry round-trips
    pull_base_s: float = 5.0
    registry_bw_Bps: float = 200e6     # artifact registry bandwidth
    # checkpoint data-path compute: wire time is charged on *encoded*
    # bytes, so the codec's own cost must be charged too (raw bytes fed
    # through a delta-codec encoder), as must the device-side fingerprint
    # pass (a streaming reduction, so near memory bandwidth)
    codec_Bps: float = 1.2e9
    fingerprint_Bps: float = 24e9
    restore_s: float = 13.0            # CRIU restore into a fresh container
    pod_create_s: float = 3.0          # scheduling + sandbox start
    pod_delete_s: float = 2.0          # SIGTERM + teardown
    sts_identity_release_s: float = 8.0  # StatefulSet graceful identity release
    route_switch_s: float = 0.9        # consumer rebind / traffic redirect
    cutover_coord_s: float = 0.5       # pause coordination during cutover
    processing_ms: float = 50.0        # per-message service time (paper: 50ms)
    heartbeat_interval_s: float = 2.0
    heartbeat_timeout_s: float = 6.0

    @classmethod
    def from_roofline(cls, roofline, **overrides) -> "TimingConstants":
        """Constants with the data-path throughputs recalibrated from a
        measured codec roofline (``benchmarks/roofline.py --codec`` ->
        ``results/codec_roofline.json``, or its loaded dict).

        The class defaults above stay the paper-fitted constants — every
        regression timeline is pinned to them bit-for-bit — so measured
        throughput is strictly opt-in via this constructor.
        """
        import json

        if isinstance(roofline, str):
            with open(roofline) as f:
                roofline = json.load(f)
        cal = roofline.get("calibration", roofline)
        kw = {}
        if cal.get("codec_Bps"):
            kw["codec_Bps"] = float(cal["codec_Bps"])
        if cal.get("fingerprint_Bps"):
            kw["fingerprint_Bps"] = float(cal["fingerprint_Bps"])
        kw.update(overrides)
        return cls(**kw)


class Node:
    def __init__(self, name: str, sim: Optional[Sim] = None):
        self.name = name
        self.alive = True
        self.pods: Dict[str, "Pod"] = {}
        self.last_heartbeat = 0.0
        # local image-layer cache (chunk keys): prefetched/pulled chunks are
        # free on later pulls — how pre-copy makes the final restore cheap
        self.image_chunks: set = set()
        # triggered when the node dies: in-flight link transfers touching
        # this node wait on it and abort; replaced fresh on revive
        self.down: Optional[Condition] = (Condition(sim, f"{name}:down")
                                          if sim is not None else None)
        # deadline-driven heartbeat bookkeeping: the generation counter
        # invalidates an armed detection deadline when the node revives
        # (and dies again) before it fires
        self._hb_gen = 0
        self._hb_armed_gen = -1


class Pod:
    """A consumer worker plus its service loop.

    Two execution regimes (docs/scaling.md): the per-message generator
    loop below (the seed behaviour, always used when any migration
    machinery is attached), and *fluid epochs* — when the pod is in
    steady state on a source-fed queue, the loop sleeps up to
    ``fluid_epoch_s`` and folds the whole epoch in one event, recomputing
    the service timeline with exact float arithmetic
    (``completion = max(arrival, cursor) + processing_ms/1000``).  Any
    observation point mid-epoch folds up to the current instant first, so
    the observable timeline is bit-identical to the per-message regime.
    """

    # fluid-epoch tuning: how long a steady-state pod may go unobserved
    # before it folds on its own (any observer folds it earlier, exactly)
    fluid_epoch_s = 20.0

    def __init__(self, name: str, node: Node, worker, queue: MessageQueue,
                 sim: Sim, timings: TimingConstants,
                 processing_ms: Optional[float] = None):
        self.name = name
        self.node = node
        self.worker = worker
        self.queue = queue
        self.sim = sim
        self.timings = timings
        self.processing_ms = (timings.processing_ms
                              if processing_ms is None else processing_ms)
        self.serving = False
        self.deleted = False
        self.paused = False
        self.service_log: List[tuple] = []  # (virtual_time, msg_id)
        # 10k-pod memory valve: per-message service history is O(messages);
        # large fleets that never inspect it can turn it off (both regimes
        # honour the flag, so differential comparisons stay fair)
        self.keep_service_log = True
        # single-slot hook (owned by the workload) + removable listeners
        # (owned by migrations, which must deregister on completion)
        self.on_processed: Optional[Callable] = None
        self.on_processed_listeners: List[Callable] = []
        self.in_flight = None  # message popped but not yet folded/requeued
        self._loop_started = False
        self._wake: Optional[Condition] = None
        # active fluid epoch (docs/scaling.md): the service timeline is
        # implicit — recomputed with exact event-loop arithmetic from the
        # queue/source state at fold time, never built as per-message
        # plan entries.  ``_fluid_cursor`` is the completion instant of
        # the last folded service (the chain base for the next one).
        self._fluid_active = False
        self._fluid_cursor = 0.0
        self._fluid_floor = -1
        self._fold_level_for: Optional[tuple] = None
        self._in_fold = False

    @property
    def busy(self) -> bool:
        """True while a popped message is mid-service (in flight)."""
        return self.in_flight is not None

    def add_on_processed(self, fn: Callable):
        # per-message listeners force exact mode: fold the active epoch
        # first so the listener sees every event from this instant on
        self._fluid_sync()
        self.on_processed_listeners.append(fn)
        if self.sim.sanitizer is not None:
            self.sim.sanitizer.check_listener_growth(
                f"pod {self.name!r} on_processed list",
                len(self.on_processed_listeners))

    def remove_on_processed(self, fn: Callable):
        if fn in self.on_processed_listeners:
            self.on_processed_listeners.remove(fn)

    def _notify_processed(self, msg):
        if self.on_processed:
            self.on_processed(self, msg)
        for fn in list(self.on_processed_listeners):
            fn(self, msg)

    # -- service loop ---------------------------------------------------------
    def start(self):
        self.serving = True
        if not self._loop_started:
            self._loop_started = True
            self.sim.process(self._run(), name=f"pod:{self.name}")

    def pause(self):
        self._fluid_sync()
        if self.sim.sanitizer is not None:
            self.sim.sanitizer.on_pause(self)
        self.paused = True
        self.serving = False

    def resume(self):
        self.paused = False
        self.serving = True
        self.wake()  # release a condition-stalled loop

    def stop(self):
        self._fluid_sync()
        self.deleted = True
        self.serving = False
        if self.sim.sanitizer is not None:
            self.sim.sanitizer.unprotect_pod(self)
        self.wake()

    def wake(self):
        """Unblock the loop (e.g. after a queue switch)."""
        if self._wake is not None:
            cond, self._wake = self._wake, None
            cond.trigger()

    # -- fluid epochs (docs/scaling.md) ---------------------------------------
    def _fluid_eligible(self) -> bool:
        """Steady state: a source-fed primary with no migration machinery
        attached and no per-message observers — the only regime where
        service can be planned analytically without changing anything
        observable."""
        q = self.queue
        return (self.sim.fluid_enabled
                and q._source is not None
                and q._primary_ref is None
                and not q._mirror_sinks
                and not q.stalled
                and not self.paused
                and not self.deleted
                and self.node.alive
                and self.on_processed is None
                and not self.on_processed_listeners)

    def _fluid_sync(self) -> None:
        """Fold the active epoch up to the current instant (no-op when
        none is active).  Every migration-relevant hook calls this before
        observing or mutating pod/queue state."""
        if self._fluid_active:
            self._fold_to(self.sim.now)

    def _on_queue_sync(self, now: float) -> None:
        if self._fluid_active:
            self._fold_to(now)

    def _fluid_epoch(self) -> Optional[Condition]:
        """Open up to ``fluid_epoch_s`` of steady-state service and return
        the condition to sleep on (woken by the epoch-end timer or by any
        hook that folds the epoch early).  ``None`` = nothing to cover;
        the caller falls through to the per-message wait path.

        The epoch stores no per-message state and draws no arrivals ahead
        of time: the exact service timeline is computed at fold time by
        drawing-and-consuming the source stream (``_fold_to``).  The wake
        timer is therefore NOT a completion estimate — folding always
        stamps every instant with event-loop arithmetic, so waking at any
        time is exact, and any observer folds the epoch earlier anyway.
        A message mid-service at the fold instant is carried across as a
        crosser, exactly like an observer-interrupted service."""
        q = self.queue
        src = q._source
        if src.closed and not src.pending and not q._items:
            return None  # source exhausted: park on the legacy wait path
        sim = self.sim
        now = sim.now
        self._fluid_cursor = now
        skip_until = getattr(self.worker, "skip_until", -1)
        last_id = self.worker.last_msg_id
        self._fluid_floor = skip_until if skip_until > last_id else last_id
        self._fluid_active = True
        self._wake = wake = sim.condition(f"{self.name}:wake")
        q._consumer_sync = self._on_queue_sync
        sim.call_at(now + self.fluid_epoch_s, self.wake, category="message")
        return wake

    def _fold_level(self) -> int:
        """How aggressively the worker's class lets a fold batch:
        2 = ``process_pairs`` (no Message allocation), 1 =
        ``process_batch`` (Message objects, one call), 0 = per-message
        ``process``.  A batch method may replace the per-message loop only
        when it was written with knowledge of the active ``process``: the
        first class in the MRO defining any of the three decides.  A
        subclass that overrides ``process`` without re-deriving the batch
        paths (extra state per message) falls back to the exact loop."""
        cls = type(self.worker)
        cached = self._fold_level_for
        if cached is not None and cached[0] is cls:
            return cached[1]
        level = 0
        for klass in cls.__mro__:
            d = klass.__dict__
            if "process_pairs" in d:
                level = 2
                break
            if "process_batch" in d:
                level = 1
                break
            if "process" in d:
                break
        self._fold_level_for = (cls, level)
        return level

    def _fold_to(self, t: float) -> None:
        """Consume every service with completion <= ``t``, recomputing the
        timeline with exact event-loop arithmetic (service = float
        ``processing_ms/1000`` added to max(arrival, cursor); already-
        folded ids consume zero time — the dedup guard).  A message
        mid-service at ``t`` becomes an in-flight *crosser* that finishes
        (or requeues, exactly like the legacy post-service interruption
        re-check) at its own completion event.  The epoch then closes —
        the loop wakes and re-opens one from the queue, which still holds
        everything unconsumed."""
        if self._in_fold:
            return
        self._in_fold = True
        try:
            q = self.queue
            src = q._source
            p = self.processing_ms / 1000.0
            cursor = self._fluid_cursor
            floor = self._fluid_floor
            log = self.service_log if self.keep_service_log else None
            level = self._fold_level()
            # allocation-free drain: ids + payloads only, no Message
            # objects — legal only when nothing needs the object
            fast = (level == 2 and src.on_publish is None
                    and not q._mirror_sinks)
            batch: List = []
            crosser = None
            items = q._items
            while items:
                msg = items[0]
                if msg.msg_id <= floor:
                    if cursor > t:
                        break
                    items.popleft()  # dedup skip: zero service time
                    continue
                done = cursor + p
                if done > t:
                    if cursor <= t:
                        items.popleft()
                        crosser = (msg, done)
                    break
                items.popleft()
                floor = msg.msg_id
                batch.append((msg.msg_id, msg.payload) if fast else msg)
                if log is not None:
                    log.append((done, msg.msg_id))
                cursor = done
            if crosser is None:
                pend = src.pending
                next_id = q._next_id
                append = batch.append
                log_append = None if log is None else log.append
                n_fast = 0
                # already-drawn arrivals first: boot backlog and the
                # overshoot draw a previous horizon left in flight
                while pend:
                    at, payload = pend[0]
                    if at > t:
                        break
                    start = at if at > cursor else cursor
                    done = start + p
                    if done > t:
                        pend.popleft()
                        msg = q._materialize(at, payload, enqueue=False)
                        crosser = (msg, done)
                        break
                    pend.popleft()
                    if fast:
                        n_fast += 1
                        append((next(next_id), payload))
                    else:
                        msg = q._materialize(at, payload, enqueue=False)
                        append(msg)
                    if log_append is not None:
                        log_append((done, batch[-1][0] if fast
                                    else batch[-1].msg_id))
                    cursor = done
                if crosser is None and not pend and not src.closed:
                    # fused draw-and-consume: each arrival goes straight
                    # from the source stream into the batch — same draw
                    # order and float arithmetic as ensure_drawn, minus
                    # the deque round-trip.  Exactly one overshoot draw
                    # (the producer's in-flight sleep) stays pending.
                    draw = src.draw
                    head_t = src.head_t
                    while True:
                        item = draw()
                        if item is None:
                            src.closed = True
                            break
                        payload = item[1]
                        head_t = head_t + float(item[0])
                        if head_t > t:
                            pend.append((head_t, payload))
                            break
                        start = head_t if head_t > cursor else cursor
                        done = start + p
                        if done > t:
                            src.head_t = head_t
                            msg = q._materialize(head_t, payload,
                                                 enqueue=False)
                            crosser = (msg, done)
                            break
                        if fast:
                            mid = next(next_id)
                            n_fast += 1
                            append((mid, payload))
                            if log_append is not None:
                                log_append((done, mid))
                        else:
                            src.head_t = head_t
                            msg = q._materialize(head_t, payload,
                                                 enqueue=False)
                            append(msg)
                            if log_append is not None:
                                log_append((done, msg.msg_id))
                        cursor = done
                    src.head_t = head_t
                if n_fast:
                    q.total_published += n_fast
            self._fluid_active = False
            q._consumer_sync = None
            if batch:
                worker = self.worker
                if fast:
                    worker.process_pairs(batch)
                elif level >= 1:
                    worker.process_batch(batch)
                else:
                    for m in batch:
                        worker.process(m)
            if crosser is not None:
                msg, done_t = crosser
                self.in_flight = msg
                self.sim.call_at(
                    done_t,
                    lambda m=msg, d=done_t: self._finish_crosser(m, d),
                    category="message")
            self.wake()
        finally:
            self._in_fold = False

    def _finish_crosser(self, msg, done_t: float) -> None:
        """Exact completion of a message that was mid-service when its
        epoch folded: re-checks the interruption flags at the completion
        instant, mirroring the legacy loop's post-service branch."""
        if self.deleted or self.paused or not self.node.alive:
            self.queue.requeue_front(msg)
            self.in_flight = None
        else:
            self.worker.process(msg)
            self.in_flight = None
            if self.keep_service_log:
                self.service_log.append((self.sim.now, msg.msg_id))
            self._notify_processed(msg)
        self.wake()

    def _run(self) -> Generator:
        while not self.deleted:
            if self._fluid_active:
                self._fold_to(self.sim.now)
            if self.in_flight is not None:
                # a fluid crosser is mid-service: its completion event
                # wakes us (spurious wakes just re-park)
                self._wake = self.sim.condition(f"{self.name}:wake")
                yield self._wake
                continue
            if self.paused or not self.node.alive:
                # condition-based stall, not a busy-poll: a paused pod (e.g.
                # the source of a long migration after the cutoff fired)
                # contributes ZERO sim events until resume()/stop()/node
                # recovery wakes it
                self._wake = self.sim.condition(f"{self.name}:stall")
                yield self._wake
                continue
            if self._fluid_eligible():
                wait = self._fluid_epoch()
                if wait is not None:
                    yield wait
                    continue
            msg = self.queue.try_get()
            if msg is None:
                self._wake = self.sim.condition(f"{self.name}:wake")
                yield self.sim.any_of(self.queue.wait_not_empty(), self._wake)
                continue
            # at-least-once dedup guard: ids are totally ordered, so a
            # message already folded into the state is skipped for free
            skip_until = getattr(self.worker, "skip_until", -1)
            if msg.msg_id <= max(skip_until, self.worker.last_msg_id):
                continue
            self.in_flight = msg
            yield self.processing_ms / 1000.0  # service time (virtual)
            if self.deleted or self.paused or not self.node.alive:
                # interrupted mid-service (pause, delete, or the node went
                # down under us — a soft partition must not fold state
                # while "offline"): message returns to the queue; the
                # id-dedup guard above makes the eventual redelivery
                # exactly-once
                self.queue.requeue_front(msg)
                self.in_flight = None
                continue
            self.worker.process(msg)  # real JAX state update
            self.in_flight = None
            if self.keep_service_log:
                self.service_log.append((self.sim.now, msg.msg_id))
            self._notify_processed(msg)


class StatefulSetController:
    """Sticky identity bookkeeping: replica name -> live pod (at most one)."""

    def __init__(self):
        self.identities: Dict[str, Optional[str]] = {}

    def claim(self, replica: str, pod_name: str):
        if self.identities.get(replica) is not None:
            raise RuntimeError(
                f"StatefulSet identity {replica} still held by "
                f"{self.identities[replica]}")
        self.identities[replica] = pod_name

    def release(self, replica: str):
        self.identities[replica] = None


class APIServer:
    """Control-plane facade: what the Migration Manager calls."""

    def __init__(self, sim: Sim, broker: Broker, registry: Registry,
                 timings: TimingConstants,
                 topology: Optional[NetworkTopology] = None):
        self.sim = sim
        self.broker = broker
        self.registry = registry
        self.timings = timings
        # default: the flat preset — one dedicated-capacity registry link,
        # bit-identical to the seed's bytes / registry_bw_Bps model
        self.topology = (topology if topology is not None else
                         flat_topology(
                             registry_bw_Bps=timings.registry_bw_Bps))
        self.topology.bind(sim)
        self.nodes: Dict[str, Node] = {}
        self.pods: Dict[str, Pod] = {}
        self.statefulsets = StatefulSetController()
        self.events: List[tuple] = []
        # registry availability (fault injection): while False every
        # node<->registry transfer fails fast with TransferAborted
        self.registry_up = True
        # in-flight registry transfers: (node_name, abort Condition) ->
        # creation site, so node deaths and registry outages can abort
        # exactly the affected flows without leaking callbacks on
        # long-lived conditions.  A dict (insertion-ordered), not a set:
        # set iteration order follows object hashes, and the abort fan-out
        # must not depend on ids
        self._live_transfers: Dict[tuple, Any] = {}
        # migration-event listeners (fault injection phase triggers, test
        # probes): called as fn(kind, t, data) for every MigrationContext
        # emit
        self.migration_listeners: List[Callable[[str, float, dict],
                                               None]] = []
        # rescan signal for the deadline-driven heartbeat monitor: node
        # set changed / node revived (fresh down condition to watch)
        self._hb_wake: Optional[Condition] = None

    def add_migration_listener(self, fn: Callable[[str, float, dict],
                                                  None]) -> None:
        self.migration_listeners.append(fn)
        if self.sim.sanitizer is not None:
            self.sim.sanitizer.check_listener_growth(
                "api migration_listeners", len(self.migration_listeners))

    def remove_migration_listener(self, fn: Callable) -> None:
        if fn in self.migration_listeners:
            self.migration_listeners.remove(fn)

    def _log(self, kind: str, **kw):
        self.events.append((self.sim.now, kind, kw))

    def notify_migration(self, kind: str, t: float, data: dict) -> None:
        for fn in list(self.migration_listeners):
            fn(kind, t, data)

    def _abort_transfers(self, node_name: Optional[str]) -> None:
        """Trigger the abort condition of every in-flight registry
        transfer touching ``node_name`` (None = all of them)."""
        for entry_node, cond in list(self._live_transfers):
            if node_name is None or entry_node == node_name:
                cond.trigger()

    # -- topology --------------------------------------------------------------
    def _hb_rescan(self) -> None:
        if self._hb_wake is not None:
            cond, self._hb_wake = self._hb_wake, None
            cond.trigger()

    def add_node(self, name: str, zone: Optional[str] = None) -> Node:
        """Register a node.  ``zone`` is required for nodes the topology
        does not already know when it spans more than one zone (see
        ``NetworkTopology.ensure_node``)."""
        # register with the topology FIRST: a zone conflict or a missing
        # zone on a multi-zone topology must not leave a half-added node
        self.topology.ensure_node(name, zone=zone)
        node = Node(name, sim=self.sim)
        self.nodes[name] = node
        self._hb_rescan()  # the monitor must watch the new node's down cond
        return node

    def kill_node(self, name: str):
        """Failure injection (hard crash): every pod on the node dies
        instantly, and every in-flight link transfer touching the node
        aborts."""
        node = self.nodes[name]
        node.alive = False
        for pod in list(node.pods.values()):
            pod.stop()
            self.pods.pop(pod.name, None)
        node.pods.clear()
        if node.down is not None:
            node.down.trigger()
        self._abort_transfers(name)
        self._log("node_killed", node=name)

    def partition_node(self, name: str):
        """Failure injection (soft/transient): the node drops off the
        network — its pods stall in place (state intact, nothing folded
        while "offline"; a mid-service message is requeued) and its
        in-flight transfers abort — but unlike :meth:`kill_node` the pods
        survive and resume on :meth:`revive_node`.  Models a network
        partition / kernel hang / reboot-without-data-loss: the flapping
        half of a ``node_flap`` fault."""
        node = self.nodes[name]
        for pod in node.pods.values():
            pod._fluid_sync()  # fold epochs at the exact partition instant
        node.alive = False
        for pod in node.pods.values():
            pod.wake()  # re-enter the loop so it sees node.alive == False
        if node.down is not None:
            node.down.trigger()
        self._abort_transfers(name)
        self._log("node_partitioned", node=name)

    def revive_node(self, name: str):
        """Bring a node back (maintenance over / transient partition healed)
        and wake any pod whose service loop stalled on the dead node."""
        node = self.nodes[name]
        node.alive = True
        node.last_heartbeat = self.sim.now
        node._hb_gen += 1  # invalidate any armed death-detection deadline
        node.down = Condition(self.sim, f"{name}:down")  # re-arm the abort
        for pod in list(node.pods.values()):
            pod.wake()
        self._hb_rescan()  # the monitor must watch the fresh down cond
        self._log("node_revived", node=name)

    # -- registry availability (fault injection) --------------------------------
    def set_registry_up(self, up: bool):
        """Registry outage toggle: while down, every push/pull/prefetch
        fails fast with ``TransferAborted`` and in-flight registry flows
        abort (the artifact registry is a single external dependency —
        when it is unreachable no node can move bytes)."""
        was = self.registry_up
        self.registry_up = up
        if was and not up:
            self._abort_transfers(None)
            self._log("registry_outage_begin")
        elif not was and up:
            self._log("registry_outage_end")

    # -- pod lifecycle (generator sub-processes) --------------------------------
    def create_pod(self, name: str, node_name: str, worker,
                   queue: MessageQueue, *, statefulset_identity=None,
                   processing_ms=None) -> Generator:
        t = self.timings
        yield t.pod_create_s
        node = self.nodes[node_name]
        if not node.alive:
            raise RuntimeError(f"node {node_name} is dead")
        if statefulset_identity is not None:
            self.statefulsets.claim(statefulset_identity, name)
        pod = Pod(name, node, worker, queue, self.sim, t,
                  processing_ms=processing_ms)
        node.pods[name] = pod
        self.pods[name] = pod
        self._log("pod_created", pod=name, node=node_name)
        return pod

    def delete_pod(self, name: str, *, statefulset_identity=None,
                   graceful: bool = True) -> Generator:
        t = self.timings
        pod = self.pods.get(name)
        if pod is not None:
            pod.stop()
        yield t.pod_delete_s if graceful else 0.1
        if statefulset_identity is not None:
            yield t.sts_identity_release_s
            self.statefulsets.release(statefulset_identity)
        if pod is not None:
            pod.node.pods.pop(name, None)
            self.pods.pop(name, None)
        self._log("pod_deleted", pod=name)

    # -- FCC: checkpoint / image / restore --------------------------------------
    def checkpoint_pod(self, pod: Pod) -> Generator:
        """FCC dump: snapshot the worker's state tree (real pytree)."""
        t = self.timings
        yield t.checkpoint_s
        pod._fluid_sync()  # the snapshot instant is migration-relevant
        state = pod.worker.state_tree()
        marker = pod.worker.last_msg_id
        self._log("checkpointed", pod=pod.name, last_msg_id=marker)
        return {"state": state, "last_msg_id": marker}

    def _data_path_cost_s(self, report) -> float:
        """Codec encode + device fingerprint compute for one push."""
        t = self.timings
        return (report.enc_raw_bytes / t.codec_Bps
                + report.fp_bytes / t.fingerprint_Bps)

    def _registry_transfer(self, node_name: Optional[str], nbytes: float,
                           base_s: float, extra_s: float = 0.0) -> Generator:
        """Charge one node<->registry transfer over the topology link.

        Dedicated links (the ``flat`` preset) are charged as one combined
        delay with the exact legacy ``base + bytes/bw + extra`` float
        arithmetic, so flat timelines stay bit-identical to the seed —
        including the seed's semantics that a mid-flight node death does
        NOT interrupt the delay (a dead node still fails fast before the
        transfer starts).  Shared links charge the fixed costs first, then
        join the link as a fair-share flow; if the node dies mid-flight
        the flow aborts with ``TransferAborted`` (the fleet orchestrator's
        guard isolates it)."""
        node = self.nodes.get(node_name) if node_name is not None else None
        if node is not None and not node.alive:
            raise TransferAborted(f"node {node_name} is dead")
        if not self.registry_up:
            raise TransferAborted("registry outage: transfer rejected")
        link = self.topology.registry_link(node_name)
        if not link.shared:
            dur = base_s + nbytes / link.capacity_Bps + extra_s
            if link.latency_s:
                dur += link.latency_s
            link.total_bytes += nbytes
            yield dur
            return
        yield base_s + extra_s
        # re-check after the fixed costs: the node may have died or the
        # registry gone down while they were being charged
        if node is not None and not node.alive:
            raise TransferAborted(f"node {node_name} is dead")
        if not self.registry_up:
            raise TransferAborted("registry outage: transfer rejected")
        # per-transfer abort condition, registered so node deaths and
        # registry outages can fan out to exactly the affected flows (and
        # nothing accumulates on long-lived conditions)
        abort = Condition(self.sim, "xfer-abort")
        entry = (node_name, abort)
        self._live_transfers[entry] = (
            capture_site() if self.sim.sanitizer is not None else None)
        try:
            yield from link.transfer(nbytes, abort=abort)
        finally:
            self._live_transfers.pop(entry, None)

    def build_and_push_image(self, checkpoint: dict, tag: str,
                             node_name: Optional[str] = None,
                             on_pushed: Optional[Callable[[str], None]]
                             = None) -> Generator:
        """Image Manager: OCI assembly + registry push (real bytes) over
        the pushing node's registry link.  ``on_pushed`` fires with the
        image id as soon as the registry holds it — BEFORE the transfer
        is charged, which can abort — so rollback can garbage-collect an
        image whose push died mid-wire."""
        t = self.timings
        yield t.image_build_s
        report = self.registry.push_image(
            {"state": checkpoint["state"]},
            meta={"last_msg_id": int(checkpoint["last_msg_id"]), "tag": tag},
            tag=tag,
        )
        if on_pushed is not None:
            on_pushed(report.image_id)
        yield from self._registry_transfer(
            node_name, report.written_bytes, t.push_base_s,
            extra_s=self._data_path_cost_s(report))
        self._log("image_pushed", tag=tag, image_id=report.image_id,
                  written=report.written_bytes, deduped=report.deduped_bytes)
        return report

    def push_delta_image(self, checkpoint: dict, tag: str,
                         parent_image_id: str, *,
                         compression="none", exact: bool = False,
                         node_name: Optional[str] = None,
                         on_pushed: Optional[Callable[[str], None]]
                         = None) -> Generator:
        """Pre-copy round: delta layer vs the parent image — the wire only
        carries *encoded* chunks the registry doesn't already hold.
        ``compression`` selects the per-leaf delta codec; ``exact=True``
        restricts it to lossless codecs (the pre-copy final flush).
        ``on_pushed`` fires with the image id before the (abortable)
        transfer — see ``build_and_push_image``."""
        t = self.timings
        yield t.delta_build_s
        report = self.registry.push_delta(
            {"state": checkpoint["state"]}, parent_image_id,
            meta={"last_msg_id": int(checkpoint["last_msg_id"]), "tag": tag},
            tag=tag, compression=compression, exact=exact,
        )
        if on_pushed is not None:
            on_pushed(report.image_id)
        yield from self._registry_transfer(
            node_name, report.written_bytes, t.push_base_s,
            extra_s=self._data_path_cost_s(report))
        self._log("delta_pushed", tag=tag, image_id=report.image_id,
                  parent=parent_image_id, delta=report.delta_bytes,
                  wire=report.wire_bytes, written=report.written_bytes,
                  codec=report.codec, lossy=report.lossy)
        return report

    def prefetch_image(self, node_name: str, image_id: str) -> Generator:
        """Warm a node's layer cache while the source keeps serving; the
        final restore then pulls only what prefetching missed."""
        t = self.timings
        node = self.nodes[node_name]
        chunks = self.registry.image_chunks(image_id)
        new_bytes = sum(size for key, size in chunks.items()
                        if key not in node.image_chunks)
        yield from self._registry_transfer(node_name, new_bytes,
                                           t.pull_base_s)
        # cache only after the transfer lands: a concurrent pull to the same
        # node must not ride for free on bytes still in flight
        node.image_chunks.update(chunks)
        self._log("image_prefetched", node=node_name, image_id=image_id,
                  bytes=new_bytes)
        return new_bytes

    def pull_and_restore(self, image_id: str, worker,
                         node_name: Optional[str] = None) -> Generator:
        """Target node: pull from registry, restore worker state.  With
        ``node_name``, the node's layer cache discounts already-held
        chunks (and is updated with the pulled ones)."""
        t = self.timings
        node = self.nodes[node_name] if node_name is not None else None
        trees, pulled = self.registry.pull_image(
            image_id,
            have_chunks=node.image_chunks if node is not None else None)
        yield from self._registry_transfer(node_name, pulled, t.pull_base_s)
        if node is not None:  # cache after the transfer lands (see prefetch)
            node.image_chunks.update(self.registry.image_chunks(image_id))
        yield t.restore_s
        worker.load_state(trees["state"])
        meta = self.registry.image_meta(image_id)
        self._log("restored", image_id=image_id, pulled=pulled,
                  last_msg_id=meta.get("last_msg_id"))
        return meta

    # -- failure detection / reconciliation -------------------------------------
    def start_heartbeats(self, on_node_dead: Callable[[str], None]):
        """Deadline-driven failure detector.

        The seed's monitor ticked every ``heartbeat_interval_s`` forever —
        at fleet scale those ticks dominate the heap.  This version wakes
        only when a node goes down (its ``down`` condition) and arms one
        detection deadline per death, with *unchanged detection times*:
        the tick grid ``s + k*interval`` is reconstructed lazily with the
        same sequential float additions the tick loop performed, the last
        refresh a dead node would have received is the greatest grid tick
        at or before the death instant, and detection fires at the first
        grid tick strictly more than ``heartbeat_timeout_s`` past it.
        A revive bumps the node's generation counter, voiding any armed
        deadline (tests/test_heartbeat.py pins the timelines).
        """
        t = self.timings
        interval = t.heartbeat_interval_s
        timeout = t.heartbeat_timeout_s
        # grid state: greatest conceptual tick <= now (None before the
        # first) and the next one, advanced by sequential float adds so
        # tick values are bit-identical to the legacy `yield interval` loop
        grid = {"last": None, "next": self.sim.now + interval}

        def arm(node: Node) -> None:
            gen = node._hb_gen
            if node._hb_armed_gen == gen:
                return
            if node.last_heartbeat == float("inf"):
                return  # already reported dead (fire-once marker)
            while grid["next"] <= self.sim.now:
                grid["last"] = grid["next"]
                grid["next"] = grid["next"] + interval
            lhb = node.last_heartbeat
            if grid["last"] is not None and grid["last"] > lhb:
                lhb = grid["last"]  # last refresh the tick loop recorded
            node.last_heartbeat = lhb
            tick = grid["next"]
            while not (tick - lhb > timeout):
                tick = tick + interval
            node._hb_armed_gen = gen

            def fire(node=node, gen=gen):
                if node._hb_gen != gen or node.alive:
                    return  # revived before the deadline
                if node.last_heartbeat == float("inf"):
                    return
                node.last_heartbeat = float("inf")  # fire once
                on_node_dead(node.name)

            self.sim.call_at(tick, fire, category="heartbeat")

        def monitor() -> Generator:
            while True:
                for node in self.nodes.values():
                    if not node.alive:
                        arm(node)
                watch = [n.down for n in self.nodes.values()
                         if n.alive and n.down is not None]
                self._hb_wake = self.sim.condition("heartbeat:wake")
                watch.append(self._hb_wake)
                yield self.sim.any_of(*watch)

        self.sim.process(monitor(), name="heartbeat-monitor")

    # -- vectorized fleet telemetry ---------------------------------------------
    def fleet_state(self) -> dict:
        """Numpy snapshot of per-pod state (sorted by pod name): queue
        depth, last-processed id, processed count, busy/serving flags.
        Syncs every pod first, so the arrays reflect the exact current
        instant in both execution regimes.  O(pods) arrays instead of
        O(pods) Python attribute walks per consumer — the orchestrator
        and fleet benchmarks read this at scale."""
        import numpy as np

        names = sorted(self.pods)
        pods = [self.pods[n] for n in names]
        now = self.sim.now
        for p in pods:
            p.queue.sync(now)
            p._fluid_sync()
        return {
            "pods": names,
            "node": [p.node.name for p in pods],
            "queue": [p.queue.name for p in pods],
            "queue_depth": np.array([p.queue.depth() for p in pods],
                                    dtype=np.int64),
            "total_published": np.array(
                [p.queue.total_published for p in pods], dtype=np.int64),
            "last_msg_id": np.array(
                [p.worker.last_msg_id for p in pods], dtype=np.int64),
            "n_processed": np.array(
                [getattr(p.worker, "n_processed", 0) for p in pods],
                dtype=np.int64),
            "busy": np.array([p.busy for p in pods], dtype=bool),
            "serving": np.array([p.serving for p in pods], dtype=bool),
        }


class Cluster:
    """Convenience bundle: sim + broker + registry + api server.

    ``topology`` selects the network model: ``None`` / ``"flat"`` (the
    seed-identical uncontended registry link), another preset name
    (``"two_zone"``, ``"edge_wan"``), a ready ``NetworkTopology``, or a
    factory ``(node_names, registry_bw_Bps) -> NetworkTopology``.

    ``faults`` injects a deterministic failure schedule: a
    ``repro.cluster.faults.FaultSchedule``, a list of ``Fault``s / fault
    spec strings, or ``None`` (no faults — the default).  The schedule is
    armed immediately: timed faults become sim processes, phase-triggered
    faults subscribe to migration events."""

    def __init__(self, registry_root: str,
                 timings: Optional[TimingConstants] = None,
                 num_nodes: int = 3,
                 chunk_bytes: Optional[int] = None,
                 topology=None,
                 faults=None,
                 sanitize: Optional[bool] = None,
                 tiebreak_seed: Optional[int] = None,
                 fluid: Optional[bool] = None,
                 census: Optional[bool] = None):
        self.sim = Sim(sanitize=sanitize, tiebreak_seed=tiebreak_seed,
                       fluid=fluid, census=census)
        self.broker = Broker(self.sim)
        self.registry = Registry(registry_root, chunk_bytes=chunk_bytes)
        self.timings = timings or TimingConstants()
        node_names = [f"node{i}" for i in range(num_nodes)]
        self.topology = make_topology(topology, node_names,
                                      self.timings.registry_bw_Bps)
        self.api = APIServer(self.sim, self.broker, self.registry,
                             self.timings, topology=self.topology)
        for name in node_names:
            self.api.add_node(name)
        self.faults = None
        if faults is not None:
            from repro.cluster.faults import FaultInjector, make_schedule
            self.faults = FaultInjector(self.api, make_schedule(faults))
            self.faults.arm()
