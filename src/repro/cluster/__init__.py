from repro.cluster.sim import (  # noqa: F401
    Condition,
    Link,
    Sim,
    TransferAborted,
)
from repro.cluster.network import (  # noqa: F401
    LinkSpec,
    NetworkTopology,
    TOPOLOGY_PRESETS,
    available_topologies,
    edge_wan_topology,
    flat_topology,
    make_topology,
    topology_entries,
    two_zone_topology,
)
from repro.cluster.cluster import (  # noqa: F401
    APIServer,
    Cluster,
    Node,
    Pod,
    TimingConstants,
)
from repro.cluster.faults import (  # noqa: F401
    FAULT_KINDS,
    Fault,
    FaultInjector,
    FaultSchedule,
    make_schedule,
    parse_fault,
)


def __getattr__(name):
    # lazy: controller pulls in repro.core (orchestrator, policy) — an
    # eager import here would cycle through core/__init__ back into this
    # package before it finishes initialising
    if name in ("RebalanceConfig", "RebalanceController",
                "run_rebalance_scenario"):
        from repro.cluster import controller
        return getattr(controller, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
