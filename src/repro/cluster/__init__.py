from repro.cluster.sim import Sim, Condition  # noqa: F401
from repro.cluster.cluster import (  # noqa: F401
    APIServer,
    Cluster,
    Node,
    Pod,
    TimingConstants,
)
