from repro.broker.broker import Broker, Message, MessageQueue  # noqa: F401
