"""In-process message broker with MS2M's secondary-queue semantics.

RabbitMQ analogue (the paper's inter-service fabric), as a library:
  * named FIFO queues with monotonically increasing per-queue message ids
    (the id order is what makes replay well-defined);
  * *secondary queues*: ``attach_secondary(primary)`` mirrors every publish
    on the primary into a migration buffer from that instant — the MS2M
    accumulation mechanism (paper §II, §III-B);
  * consumer waiting via sim Conditions (no busy polling);
  * per-instance dedicated queues for StatefulSet workers (paper §III-C).

The broker is deliberately time-free: all timing lives in the cluster
runtime; the broker only orders and stores.

Fleet-scale addition (docs/scaling.md): a queue may own an *arrival
source* (:meth:`MessageQueue.attach_source`) — a draw function yielding
``(gap_s, payload)`` pairs.  With ``Sim.fluid_enabled`` the source is
drawn in batches and arrivals are materialized lazily at observation
points (:meth:`MessageQueue.sync`), with closed-form id/time assignment
that reproduces the per-event producer bit-for-bit; with
``REPRO_SIM_FLUID=0`` the source degrades to a per-arrival pump process
whose event and RNG sequences are identical to the legacy inline
producer generators.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import TYPE_CHECKING, Any, Callable, Dict, List, Optional

if TYPE_CHECKING:  # annotation-only: avoids the broker <-> cluster cycle
    from repro.cluster.sim import Condition, Sim


@dataclasses.dataclass
class Message:
    msg_id: int
    payload: Any
    publish_time: float


class _ArrivalSource:
    """Batched arrival drawing for one queue.

    ``draw()`` returns ``(gap_s, payload)`` or ``None`` (source exhausted).
    Arrival times accumulate exactly like the legacy producer event loop:
    ``t_k = t_{k-1} + float(gap_k)`` starting from the sim clock at attach
    — the identical float additions the kernel performed in ``_step``, so
    lazily assigned publish times are bit-identical to eager ones.
    """

    __slots__ = ("draw", "on_publish", "pending", "head_t", "closed",
                 "pumped")

    def __init__(self, draw: Callable[[], Optional[tuple]],
                 on_publish: Optional[Callable[[Message], None]],
                 start_t: float):
        self.draw = draw
        self.on_publish = on_publish
        self.pending: deque = deque()  # (arrival_t, payload), ascending
        self.head_t = start_t
        self.closed = False
        # pump mode (REPRO_SIM_FLUID=0): a per-arrival process owns the
        # draws — the batched machinery (ensure_drawn/next_arrival/halt
        # trimming) must never touch the stream
        self.pumped = False

    def ensure_drawn(self, horizon: float) -> None:
        """Draw arrivals until the next undrawn one lies past ``horizon``.
        The overshooting arrival (first > horizon) stays pending — the
        legacy producer also had exactly one in-flight arrival drawn.
        Fluid folds bypass this (they draw-and-consume in one loop); it
        serves observers materializing a backlog outside a fold."""
        if self.closed:
            return
        draw = self.draw
        append = self.pending.append
        head_t = self.head_t
        while head_t <= horizon:
            item = draw()
            if item is None:
                self.closed = True
                break
            gap, payload = item
            head_t = head_t + float(gap)
            append((head_t, payload))
        self.head_t = head_t

    def next_arrival(self) -> Optional[float]:
        """Arrival time of the next pending message, drawing one if
        needed; ``None`` when the source is exhausted."""
        if not self.pending:
            if self.closed:
                return None
            item = self.draw()
            if item is None:
                self.closed = True
                return None
            gap, payload = item
            self.head_t = self.head_t + float(gap)
            self.pending.append((self.head_t, payload))
        return self.pending[0][0]

    def halt(self, now: float) -> None:
        """Stop-flag semantics of the legacy producer: every arrival
        <= ``now`` is kept, plus exactly the first one after ``now`` (the
        producer always had one drawn in-flight sleep that still lands),
        then the source closes.  Over-drawn RNG values beyond that are
        unobservable — each producer owns its generator."""
        if self.closed and not self.pending:
            return
        keep: deque = deque()
        extra_kept = False
        for t, payload in self.pending:
            if t <= now:
                keep.append((t, payload))
            elif not extra_kept:
                keep.append((t, payload))
                extra_kept = True
        if not extra_kept and not self.closed:
            item = self.draw()
            if item is not None:
                gap, payload = item
                self.head_t = self.head_t + float(gap)
                keep.append((self.head_t, payload))
        self.pending = keep
        self.closed = True


class MessageQueue:
    def __init__(self, name: str, sim: Sim):
        self.name = name
        self.sim = sim
        self._items: deque = deque()
        self._next_id = itertools.count()
        self._not_empty: Optional[Condition] = None
        # pooled, permanently-triggered "items visible" condition: steady
        # consumption must not churn a fresh Condition per message
        self._ready: Optional[Condition] = None
        self.total_published = 0
        # broker_stall fault: a stalled queue accepts publishes but delivers
        # nothing until unstalled (a wedged consumer channel) — no loss,
        # only delay
        self.stalled = False
        # fluid machinery (docs/scaling.md): arrival source, mirror sinks
        # fed at materialization time, back-reference from a mirror to its
        # primary (so observing the mirror materializes the primary first),
        # the consuming pod's fold hook, and the armed next-arrival timer
        self._source: Optional[_ArrivalSource] = None
        self._mirror_sinks: List["MessageQueue"] = []
        self._primary_ref: Optional["MessageQueue"] = None
        self._consumer_sync: Optional[Callable[[float], None]] = None
        self._timer_t: Optional[float] = None

    # arrival sources ------------------------------------------------------
    def attach_source(self, draw: Callable[[], Optional[tuple]],
                      on_publish: Optional[Callable[[Message], None]] = None
                      ) -> None:
        """Feed this queue from a draw function returning ``(gap_s,
        payload)`` per arrival (``None`` = exhausted).  Replaces the
        inline producer-process idiom; see module docstring for the two
        execution modes."""
        if self._source is not None:
            raise RuntimeError(f"queue {self.name!r} already has a source")
        self._source = _ArrivalSource(draw, on_publish, self.sim.now)
        if not self.sim.fluid_enabled:
            self._source.pumped = True
            self.sim.process(self._pump(), name=f"source:{self.name}")

    def halt_source(self) -> None:
        """Close the source with legacy stop-flag trimming (arrivals
        <= now plus the single in-flight one still land)."""
        src = self._source
        if src is None:
            return
        if src.pumped:
            # the pump publishes its one in-flight arrival at wake, sees
            # the closed flag and exits — exactly the legacy stop flag
            src.closed = True
            return
        self.sync(self.sim.now)
        src.halt(self.sim.now)

    def _pump(self):
        """Per-arrival pump used when fluid mode is off: event sequence,
        RNG call order and stop semantics identical to the legacy inline
        producer generators (publish, then re-check the stop condition)."""
        src = self._source
        while True:
            if src.closed:
                return
            item = src.draw()
            if item is None:
                src.closed = True
                return
            gap, payload = item
            yield float(gap)
            self._materialize(self.sim.now, payload)

    def sync(self, now: float) -> None:
        """Materialize every deferred observable effect up to ``now``:
        fold the consuming pod's fluid plan, then publish all source
        arrivals <= ``now`` (ids, mirror copies, on_publish callbacks) in
        order.  Called by every observation point — after it returns, the
        queue state is bit-identical to the legacy eager timeline."""
        pr = self._primary_ref
        if pr is not None:
            pr.sync(now)
        hook = self._consumer_sync
        if hook is not None:
            hook(now)
        src = self._source
        if src is not None and not src.pumped:
            src.ensure_drawn(now)
            pend = src.pending
            while pend and pend[0][0] <= now:
                t, payload = pend.popleft()
                self._materialize(t, payload)

    def _materialize(self, t: float, payload: Any,
                     enqueue: bool = True) -> Message:
        """Assign the next id and publish an arrival stamped at its true
        arrival time ``t``.  ``enqueue=False`` is the fused
        materialize-and-consume path used by a fluid fold (the consumer
        takes the message in the same operation, so it never enters
        ``_items``)."""
        msg = Message(next(self._next_id), payload, t)
        if enqueue:
            self._push(msg)
        else:
            self.total_published += 1
        src = self._source
        if src is not None and src.on_publish is not None:
            src.on_publish(msg)
        for sec in self._mirror_sinks:
            # mirrored copy keeps the primary's message id (replay identity)
            sec._push(Message(msg.msg_id, payload, t))
        return msg

    def _arm_arrival_timer(self) -> None:
        """Wake a per-message-mode consumer at the next lazy arrival.
        Self-healing: a stale timer just syncs (a no-op) and the waiter
        re-arms on its next wait."""
        q = self
        src = self._source
        if src is None and self._primary_ref is not None:
            q = self._primary_ref
            src = q._source
        if src is None or src.pumped:
            return
        t = src.next_arrival()
        if t is None or self._timer_t == t:
            return
        self._timer_t = t

        def fire(q=q, t=t):
            if self._timer_t == t:
                self._timer_t = None
            q.sync(self.sim.now)

        self.sim.call_at(t, fire, category="message")

    # publishing ---------------------------------------------------------
    def publish(self, payload: Any) -> Message:
        self.sync(self.sim.now)
        msg = Message(next(self._next_id), payload, self.sim.now)
        self._push(msg)
        return msg

    def _push(self, msg: Message):
        self._items.append(msg)
        self.total_published += 1
        if self._not_empty is not None and not self.stalled:
            cond, self._not_empty = self._not_empty, None
            cond.trigger()

    # stalling (fault injection) ------------------------------------------
    def stall(self):
        self.sync(self.sim.now)
        self.stalled = True

    def unstall(self):
        self.stalled = False
        self.sync(self.sim.now)
        if self._items and self._not_empty is not None:
            cond, self._not_empty = self._not_empty, None
            cond.trigger()

    # consuming ----------------------------------------------------------
    def try_get(self) -> Optional[Message]:
        self.sync(self.sim.now)
        if self.stalled:
            return None
        return self._items.popleft() if self._items else None

    def peek_last_id(self) -> int:
        """Highest id ever published (-1 if none)."""
        self.sync(self.sim.now)
        return self.total_published - 1 if self.total_published else -1

    def wait_not_empty(self) -> Condition:
        self.sync(self.sim.now)
        if self._items and not self.stalled:
            if self._ready is None:
                self._ready = self.sim.condition(f"{self.name}:ready")
                self._ready.trigger()
            return self._ready
        if not self.stalled:
            self._arm_arrival_timer()
        if self._not_empty is None:
            self._not_empty = self.sim.condition(f"{self.name}:not_empty")
        return self._not_empty

    def depth(self) -> int:
        self.sync(self.sim.now)
        return len(self._items)

    def requeue_front(self, msg: Message):
        self._items.appendleft(msg)


class Broker:
    def __init__(self, sim: Sim):
        self.sim = sim
        self.queues: Dict[str, MessageQueue] = {}
        self._mirrors: Dict[str, List[str]] = {}

    def declare_queue(self, name: str) -> MessageQueue:
        if name not in self.queues:
            self.queues[name] = MessageQueue(name, self.sim)
            self._mirrors.setdefault(name, [])
        return self.queues[name]

    def publish(self, queue: str, payload: Any) -> Message:
        msg = self.queues[queue].publish(payload)
        for mirror in self._mirrors.get(queue, []):
            # mirrored copy keeps the primary's message id (replay identity)
            self.queues[mirror]._push(
                Message(msg.msg_id, payload, self.sim.now))
        return msg

    # MS2M secondary queues ------------------------------------------------
    def attach_secondary(self, primary: str, name: Optional[str] = None) -> MessageQueue:
        """Mirror the primary's unconsumed backlog and all future
        publishes into a new queue.

        Copying the backlog is a correctness requirement, not an
        optimization: the migration invariant is "checkpoint image plus
        mirror covers every id", and the image only covers what the
        *source has folded* by checkpoint time.  A source that is behind
        (e.g. just resumed after a rolled-back migration attempt) may
        checkpoint at a marker below ids that were already published
        before the mirror attached — without the backlog copies those ids
        would be in neither the image nor the mirror and the target would
        silently lose them.  Ids the image does cover are deduplicated at
        replay (the consumer skips ids <= the checkpoint marker), so the
        copies are free for a caught-up source — attaching on an empty
        backlog remains the seed behaviour, bit for bit."""
        primary_q = self.queues[primary]
        # attaching a mirror is a migration-relevant instant: fold the
        # fluid plan and materialize due arrivals before snapshotting the
        # backlog, and from here on the consumer runs per-message
        primary_q.sync(self.sim.now)
        sec_name = name or f"{primary}.secondary"
        sec = self.declare_queue(sec_name)
        for msg in primary_q._items:  # ascending id order
            sec._push(Message(msg.msg_id, msg.payload, msg.publish_time))
        self._mirrors[primary].append(sec_name)
        primary_q._mirror_sinks.append(sec)
        sec._primary_ref = primary_q
        if self.sim.sanitizer is not None:
            self.sim.sanitizer.check_listener_growth(
                f"broker mirror list of {primary!r}",
                len(self._mirrors[primary]))
        return sec

    def is_mirrored(self, primary: str, sec_name: str) -> bool:
        return sec_name in self._mirrors.get(primary, [])

    def detach_secondary(self, primary: str, sec_name: str):
        self._mirrors[primary].remove(sec_name)
        primary_q = self.queues[primary]
        sec = self.queues.get(sec_name)
        if sec is not None:
            if sec in primary_q._mirror_sinks:
                primary_q._mirror_sinks.remove(sec)
            sec._primary_ref = None

    def delete_queue(self, name: str):
        gone = self.queues.pop(name, None)
        self._mirrors.pop(name, None)
        for mirrors in self._mirrors.values():
            if name in mirrors:
                mirrors.remove(name)
        if gone is not None:
            for q in self.queues.values():
                if gone in q._mirror_sinks:
                    q._mirror_sinks.remove(gone)
