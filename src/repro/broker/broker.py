"""In-process message broker with MS2M's secondary-queue semantics.

RabbitMQ analogue (the paper's inter-service fabric), as a library:
  * named FIFO queues with monotonically increasing per-queue message ids
    (the id order is what makes replay well-defined);
  * *secondary queues*: ``attach_secondary(primary)`` mirrors every publish
    on the primary into a migration buffer from that instant — the MS2M
    accumulation mechanism (paper §II, §III-B);
  * consumer waiting via sim Conditions (no busy polling);
  * per-instance dedicated queues for StatefulSet workers (paper §III-C).

The broker is deliberately time-free: all timing lives in the cluster
runtime; the broker only orders and stores.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import TYPE_CHECKING, Any, Callable, Dict, List, Optional

if TYPE_CHECKING:  # annotation-only: avoids the broker <-> cluster cycle
    from repro.cluster.sim import Condition, Sim


@dataclasses.dataclass
class Message:
    msg_id: int
    payload: Any
    publish_time: float


class MessageQueue:
    def __init__(self, name: str, sim: Sim):
        self.name = name
        self.sim = sim
        self._items: deque = deque()
        self._next_id = itertools.count()
        self._not_empty: Optional[Condition] = None
        self.total_published = 0
        # broker_stall fault: a stalled queue accepts publishes but delivers
        # nothing until unstalled (a wedged consumer channel) — no loss,
        # only delay
        self.stalled = False

    # publishing ---------------------------------------------------------
    def publish(self, payload: Any) -> Message:
        msg = Message(next(self._next_id), payload, self.sim.now)
        self._push(msg)
        return msg

    def _push(self, msg: Message):
        self._items.append(msg)
        self.total_published += 1
        if self._not_empty is not None and not self.stalled:
            cond, self._not_empty = self._not_empty, None
            cond.trigger()

    # stalling (fault injection) ------------------------------------------
    def stall(self):
        self.stalled = True

    def unstall(self):
        self.stalled = False
        if self._items and self._not_empty is not None:
            cond, self._not_empty = self._not_empty, None
            cond.trigger()

    # consuming ----------------------------------------------------------
    def try_get(self) -> Optional[Message]:
        if self.stalled:
            return None
        return self._items.popleft() if self._items else None

    def peek_last_id(self) -> int:
        """Highest id ever published (-1 if none)."""
        return self.total_published - 1 if self.total_published else -1

    def wait_not_empty(self) -> Condition:
        if self._items and not self.stalled:
            done = self.sim.condition()
            done.trigger()
            return done
        if self._not_empty is None:
            self._not_empty = self.sim.condition(f"{self.name}:not_empty")
        return self._not_empty

    def depth(self) -> int:
        return len(self._items)

    def requeue_front(self, msg: Message):
        self._items.appendleft(msg)


class Broker:
    def __init__(self, sim: Sim):
        self.sim = sim
        self.queues: Dict[str, MessageQueue] = {}
        self._mirrors: Dict[str, List[str]] = {}

    def declare_queue(self, name: str) -> MessageQueue:
        if name not in self.queues:
            self.queues[name] = MessageQueue(name, self.sim)
            self._mirrors.setdefault(name, [])
        return self.queues[name]

    def publish(self, queue: str, payload: Any) -> Message:
        msg = self.queues[queue].publish(payload)
        for mirror in self._mirrors.get(queue, []):
            # mirrored copy keeps the primary's message id (replay identity)
            self.queues[mirror]._push(
                Message(msg.msg_id, payload, self.sim.now))
        return msg

    # MS2M secondary queues ------------------------------------------------
    def attach_secondary(self, primary: str, name: Optional[str] = None) -> MessageQueue:
        """Mirror the primary's unconsumed backlog and all future
        publishes into a new queue.

        Copying the backlog is a correctness requirement, not an
        optimization: the migration invariant is "checkpoint image plus
        mirror covers every id", and the image only covers what the
        *source has folded* by checkpoint time.  A source that is behind
        (e.g. just resumed after a rolled-back migration attempt) may
        checkpoint at a marker below ids that were already published
        before the mirror attached — without the backlog copies those ids
        would be in neither the image nor the mirror and the target would
        silently lose them.  Ids the image does cover are deduplicated at
        replay (the consumer skips ids <= the checkpoint marker), so the
        copies are free for a caught-up source — attaching on an empty
        backlog remains the seed behaviour, bit for bit."""
        sec_name = name or f"{primary}.secondary"
        sec = self.declare_queue(sec_name)
        for msg in self.queues[primary]._items:  # ascending id order
            sec._push(Message(msg.msg_id, msg.payload, msg.publish_time))
        self._mirrors[primary].append(sec_name)
        if self.sim.sanitizer is not None:
            self.sim.sanitizer.check_listener_growth(
                f"broker mirror list of {primary!r}",
                len(self._mirrors[primary]))
        return sec

    def is_mirrored(self, primary: str, sec_name: str) -> bool:
        return sec_name in self._mirrors.get(primary, [])

    def detach_secondary(self, primary: str, sec_name: str):
        self._mirrors[primary].remove(sec_name)

    def delete_queue(self, name: str):
        self.queues.pop(name, None)
        self._mirrors.pop(name, None)
        for mirrors in self._mirrors.values():
            if name in mirrors:
                mirrors.remove(name)
