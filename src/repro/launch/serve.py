"""Serving driver: batched decode with KV-cache management — the worker
type that MS2M migrates.  Runs for real with a reduced config on this host.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --smoke \
      --requests 16 --decode-steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.train import step as steplib


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8, help="batch size")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    B = args.requests

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, args.prompt_len)), jnp.int32)}
    if cfg.frontend == "audio_frames":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.frontend == "image_patches":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.num_patches, cfg.d_model)), jnp.float32)

    prefill = jax.jit(steplib.build_prefill_step(cfg), donate_argnums=(1,))
    decode = jax.jit(steplib.build_decode_step(cfg), donate_argnums=(1,))

    cache = T.init_cache(cfg, B, args.max_seq)
    t0 = time.perf_counter()
    logits, cache = prefill(params, cache, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"[serve] prefill {args.prompt_len} tokens x {B} requests: "
          f"{t_prefill*1e3:.0f}ms")

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    pos = jnp.full((B, 1), args.prompt_len, jnp.int32)
    generated = [tok]
    t0 = time.perf_counter()
    for i in range(args.decode_steps):
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = pos + 1
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    toks_s = B * args.decode_steps / dt
    print(f"[serve] decoded {args.decode_steps} steps x {B} requests: "
          f"{dt*1e3:.0f}ms ({toks_s:.0f} tok/s)")
    out = jnp.concatenate(generated, axis=1)
    print(f"[serve] sample continuation (request 0): {np.asarray(out[0])[:16]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
