"""Migration driver CLI — the Migration Manager as an operator command.

  PYTHONPATH=src python -m repro.launch.migrate \
      --strategy ms2m_cutoff --rate 12 --arch paper_consumer \
      --batched-replay --registry /tmp/reg

Runs the full workload (producer -> consumer pod -> migration -> verify)
on the virtual-time cluster with a real JAX consumer and prints the
MigrationReport (phases, downtime, image bytes, verification).
"""
from __future__ import annotations

import argparse
import json
import tempfile

from repro.core import (
    make_jax_worker_factory,
    measure_replay_speedup,
    run_migration_experiment,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="ms2m_individual",
                    choices=["stop_and_copy", "ms2m_individual",
                             "ms2m_cutoff", "ms2m_statefulset"])
    ap.add_argument("--rate", type=float, default=10.0)
    ap.add_argument("--processing-ms", type=float, default=50.0)
    ap.add_argument("--t-replay-max", type=float, default=45.0)
    ap.add_argument("--registry", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hash-consumer", action="store_true",
                    help="cheap fold worker instead of the JAX model")
    ap.add_argument("--batched-replay", action="store_true")
    args = ap.parse_args(argv)

    worker_factory = None
    speedup = 1.0
    if not args.hash_consumer:
        worker_factory, cfg = make_jax_worker_factory(max_seq=2048)
        if args.batched_replay:
            w = worker_factory()
            speedup = measure_replay_speedup(cfg, w.params, n=128,
                                             max_seq=512)
            print(f"[migrate] measured replay speedup: {speedup:.1f}x")

    registry = args.registry or tempfile.mkdtemp(prefix="repro-registry-")
    r = run_migration_experiment(
        args.strategy, args.rate, registry_root=registry,
        processing_ms=args.processing_ms, t_replay_max=args.t_replay_max,
        seed=args.seed, worker_factory=worker_factory,
        batched_replay=args.batched_replay, replay_speedup=speedup)
    print(json.dumps(r.row(), indent=2))
    print(f"[migrate] downtime={r.downtime:.2f}s "
          f"migration={r.migration_time:.2f}s verified={r.verified}")
    return 0 if r.verified else 1


if __name__ == "__main__":
    raise SystemExit(main())
