"""Migration driver CLI — the Migration Manager as an operator command.

  PYTHONPATH=src python -m repro.launch.migrate \
      --strategy ms2m_cutoff --rate 12 --arch paper_consumer \
      --batched-replay --registry /tmp/reg

Runs the full workload (producer -> consumer pod -> migration -> verify)
on the virtual-time cluster with a real JAX consumer and prints the
MigrationReport (phases, downtime, image bytes, verification).

``--workload serving`` switches to the serving harness instead: an
open-loop Poisson *request* stream (``--rate`` in req/s) against a
slot-based serving worker, per-request latency tracing and the
exactly-once completion audit — the natural driver for the
``serving_handoff`` strategy (but any registered strategy runs).

The strategy list comes from the registry, so operator-registered schemes
(imported via ``--strategy-module``) are drivable without touching this
file.
"""
from __future__ import annotations

import argparse
import importlib
import json
import tempfile

from repro.cluster import (FAULT_KINDS, available_topologies, parse_fault,
                           topology_entries)
from repro.core import (
    MigrationPolicy,
    available_strategies,
    make_jax_worker_factory,
    measure_replay_speedup,
    registry_entries,
    run_migration_experiment,
)


def list_topologies() -> int:
    """Print every network topology preset with its docstring summary."""
    for row in topology_entries():
        print(f"{row['name']:12s} {row['summary']}")
    return 0


def list_strategies() -> int:
    """Print every registered strategy with its control-plane flags and
    docstring summary (operator-registered schemes included when imported
    via ``--strategy-module``)."""
    for row in registry_entries():
        flags = [f for f, on in (("wants_cutoff", row["wants_cutoff"]),
                                 ("handles_identity",
                                  row["handles_identity"])) if on]
        print(f"{row['name']:20s} [{', '.join(flags) or '-'}]")
        print(f"    {row['summary']}")
    return 0


def main(argv=None) -> int:
    # pre-parse --strategy-module on a separate help-less parser so custom
    # schemes register before --strategy choices are validated, without
    # swallowing -h/--help or prefix-matching --strategy
    module_help = ("import this module first (for @register_strategy side "
                   "effects) so custom schemes are available")
    pre_ap = argparse.ArgumentParser(add_help=False, allow_abbrev=False)
    pre_ap.add_argument("--strategy-module", default=None)
    pre, _ = pre_ap.parse_known_args(argv)
    if pre.strategy_module:
        importlib.import_module(pre.strategy_module)

    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--strategy-module", default=None, help=module_help)
    ap.add_argument("--list-strategies", action="store_true",
                    help="print registry entries (name, wants_cutoff/"
                         "handles_identity flags, docstring) and exit")
    ap.add_argument("--strategy", default="ms2m_individual",
                    choices=available_strategies())
    ap.add_argument("--topology", default="flat",
                    choices=available_topologies(),
                    help="network topology preset the cluster runs over "
                         "(flat = the uncontended seed model)")
    ap.add_argument("--list-topologies", action="store_true",
                    help="print the topology presets and exit")
    ap.add_argument("--workload", default="fold",
                    choices=("fold", "serving", "rebalance"),
                    help="fold = the paper's consumer workload; serving = "
                         "open-loop request stream against a slot-based "
                         "serving worker with latency tracing and the "
                         "exactly-once completion audit; rebalance = an "
                         "N-pod fleet under faults, reactive by default "
                         "(add --controller for the predictive rebalancer)")
    ap.add_argument("--rate", type=float, default=10.0,
                    help="arrival rate (msgs/s, or req/s with "
                         "--workload serving)")
    ap.add_argument("--num-slots", type=int, default=8,
                    help="decode slots of the serving worker "
                         "(--workload serving)")
    ap.add_argument("--decode-rounds", type=int, default=1,
                    help="decode rounds per admission for the JAX serving "
                         "engine: generation spans messages "
                         "(--workload serving without --hash-consumer)")
    ap.add_argument("--processing-ms", type=float, default=50.0)
    ap.add_argument("--t-replay-max", type=float, default=45.0)
    ap.add_argument("--registry", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hash-consumer", action="store_true",
                    help="cheap fold worker instead of the JAX model")
    ap.add_argument("--batched-replay", action="store_true")
    ap.add_argument("--precopy", action="store_true",
                    help="iterative delta pre-copy transfer engine")
    ap.add_argument("--precopy-max-rounds", type=int, default=5)
    ap.add_argument("--compression", default="none",
                    choices=("none", "xor_rle", "int8", "auto"),
                    help="delta codec for pre-copy rounds (wire bytes)")
    ap.add_argument("--events", action="store_true",
                    help="also print the structured MigrationEvent trace")
    ap.add_argument("--fault", action="append", default=[],
                    metavar="KIND@TRIGGER[,k=v,...]",
                    help="inject a fault (repeatable), e.g. "
                         "node_flap@12,node=node1,duration=5 or "
                         "registry_outage@precopy_round:1,duration=8; "
                         "kinds: " + ", ".join(FAULT_KINDS))
    # -- rebalance workload: the predictive controller and its knobs ------
    ap.add_argument("--controller", action="store_true",
                    help="enable the predictive RebalanceController "
                         "(--workload rebalance; off = reactive baseline)")
    ap.add_argument("--controller-tick", type=float, default=1.0,
                    help="control-loop period, virtual seconds")
    ap.add_argument("--controller-horizon", type=float, default=30.0,
                    help="messages-at-risk exposure cap (s)")
    ap.add_argument("--controller-suspect", type=float, default=90.0,
                    help="how long a flapped node stays suspect (s)")
    ap.add_argument("--controller-cooldown", type=float, default=30.0,
                    help="per-queue quiet period after a move (s)")
    ap.add_argument("--controller-max-moves", type=int, default=2,
                    help="new migrations admitted per control tick")
    ap.add_argument("--controller-min-risk", type=float, default=0.25,
                    help="combined risk below which pods are ignored")
    ap.add_argument("--controller-min-score", type=float, default=1e-9,
                    help="messages-at-risk per byte admission bar")
    ap.add_argument("--arrival-schedule", default="steady",
                    choices=("steady", "diurnal", "flash_crowd"),
                    help="arrival-rate modulation of the rebalance fleet")
    ap.add_argument("--n-pods", type=int, default=6,
                    help="fleet size (--workload rebalance)")
    ap.add_argument("--num-nodes", type=int, default=4,
                    help="cluster size (--workload rebalance)")
    ap.add_argument("--t-end", type=float, default=150.0,
                    help="scenario length, virtual s (--workload rebalance)")
    ap.add_argument("--max-attempts", type=int, default=1,
                    help="migration attempts before giving up (failed "
                         "attempts are rolled back: source serving again)")
    ap.add_argument("--retry-backoff", type=float, default=2.0,
                    help="seconds between migration attempts")
    args = ap.parse_args(argv)

    if args.list_strategies:
        return list_strategies()
    if args.list_topologies:
        return list_topologies()

    if args.workload == "rebalance":
        from repro.cluster.controller import (RebalanceConfig,
                                              run_rebalance_scenario)

        config = None
        if args.controller:
            config = RebalanceConfig(
                tick_s=args.controller_tick,
                horizon_s=args.controller_horizon,
                suspect_s=args.controller_suspect,
                cooldown_s=args.controller_cooldown,
                max_moves_per_tick=args.controller_max_moves,
                min_risk=args.controller_min_risk,
                min_score=args.controller_min_score,
                strategy=args.strategy)
        faults = [parse_fault(spec) for spec in args.fault] or None
        registry = args.registry or tempfile.mkdtemp(prefix="repro-registry-")
        r = run_rebalance_scenario(
            registry_root=registry, n_pods=args.n_pods,
            num_nodes=args.num_nodes, message_rate=args.rate,
            schedule=args.arrival_schedule, faults=faults, seed=args.seed,
            t_end=args.t_end, controller=config,
            processing_ms=args.processing_ms, topology=args.topology,
            policy=MigrationPolicy(max_attempts=args.max_attempts,
                                   retry_backoff_s=args.retry_backoff))
        print(json.dumps(r.row(), indent=2))
        if args.events:
            print(json.dumps(r.events, indent=2))
        print(f"[migrate] controller={'on' if config else 'off'} "
              f"unserved={r.unserved_queue_seconds:.1f}qs "
              f"moves={r.n_moves} moved_bytes={r.moved_wire_bytes} "
              f"all_verified={r.all_verified}")
        return 0 if r.all_verified else 1

    if args.workload == "serving":
        from repro.serving.handoff import run_serving_experiment

        policy = MigrationPolicy(
            precopy=args.precopy,
            precopy_max_rounds=args.precopy_max_rounds,
            compression=args.compression,
            t_replay_max=args.t_replay_max,
            max_attempts=args.max_attempts,
            retry_backoff_s=args.retry_backoff,
        )
        faults = [parse_fault(spec) for spec in args.fault] or None
        registry = args.registry or tempfile.mkdtemp(prefix="repro-registry-")
        r = run_serving_experiment(
            args.strategy, args.rate, registry_root=registry,
            processing_ms=args.processing_ms, seed=args.seed,
            worker="hash" if args.hash_consumer else "engine",
            num_slots=args.num_slots, decode_rounds=args.decode_rounds,
            topology=args.topology, faults=faults, policy=policy,
            allow_failure=faults is not None)
        print(json.dumps(r.row(), indent=2))
        lat = r.latency()
        if r.failed:
            print(f"[migrate] FAILED after {r.failure.get('attempts')} "
                  f"attempt(s): {r.failure.get('error')} (rolled back: "
                  f"source_serving={r.failure.get('source_serving')})")
        print(f"[migrate] p50={lat['p50']} p99={lat['p99']} "
              f"p999={lat['p999']} downtime={r.downtime:.2f}s "
              f"exactly_once={r.exactly_once} "
              f"state_verified={r.state_verified}")
        return 0 if r.exactly_once and r.state_verified is not False else 1

    worker_factory = None
    speedup = 1.0
    if not args.hash_consumer:
        worker_factory, cfg = make_jax_worker_factory(max_seq=2048)
        if args.batched_replay:
            w = worker_factory()
            speedup = measure_replay_speedup(cfg, w.params, n=128,
                                             max_seq=512)
            print(f"[migrate] measured replay speedup: {speedup:.1f}x")

    policy = MigrationPolicy(
        batched_replay=args.batched_replay,
        replay_speedup=speedup if args.batched_replay else 1.0,
        precopy=args.precopy,
        precopy_max_rounds=args.precopy_max_rounds,
        compression=args.compression,
        t_replay_max=args.t_replay_max,
        max_attempts=args.max_attempts,
        retry_backoff_s=args.retry_backoff,
    )
    faults = [parse_fault(spec) for spec in args.fault] or None
    registry = args.registry or tempfile.mkdtemp(prefix="repro-registry-")
    r = run_migration_experiment(
        args.strategy, args.rate, registry_root=registry,
        processing_ms=args.processing_ms, t_replay_max=args.t_replay_max,
        seed=args.seed, worker_factory=worker_factory, policy=policy,
        topology=args.topology, faults=faults,
        allow_failure=faults is not None)
    print(json.dumps(r.row(), indent=2))
    if r.failed:
        print(f"[migrate] FAILED after {r.failure.get('attempts')} "
              f"attempt(s): {r.failure.get('error')} "
              f"(rolled back: source_serving="
              f"{r.failure.get('source_serving')})")
        return 1
    if args.events:
        print(json.dumps(r.report.event_rows(), indent=2))
    print(f"[migrate] downtime={r.downtime:.2f}s "
          f"migration={r.migration_time:.2f}s verified={r.verified} "
          f"attempts={r.report.attempts}")
    return 0 if r.verified else 1


if __name__ == "__main__":
    raise SystemExit(main())
