"""End-to-end training driver (runs for real on this host with a reduced
config; the same code path lowers on the production meshes via dryrun.py).

Fault tolerance wired in:
  * periodic async checkpoints to the registry (images are content-addressed
    — unchanged chunks dedup to zero upload);
  * restart: ``--resume`` restores the latest image and *replays the batch
    journal* deterministically (the data pipeline is a pure function of
    (seed, step)), i.e. the MS2M recovery path applied to training;
  * straggler mitigation hooks: per-step EWMA of step time; a straggling
    worker would be live-migrated by the controller (examples/
    statefulset_trainer_migration.py demonstrates it on the cluster runtime).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm_360m --steps 50 \
      --smoke --registry /tmp/reg
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import Checkpointer, Registry
from repro.data import DataConfig, SyntheticTokenDataset
from repro.models import transformer as T
from repro.models.common import split_params
from repro.optim import adamw
from repro.train import step as steplib


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--registry", default="/tmp/repro_registry")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    tcfg = steplib.TrainStepConfig(
        remat="none", lr_peak=args.lr, warmup_steps=10, total_steps=args.steps,
        opt=adamw.AdamWConfig(weight_decay=0.01))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    ds = SyntheticTokenDataset(dcfg)

    registry = Registry(args.registry)
    ckpt = Checkpointer(registry, f"train-{args.arch}",
                        interval_steps=args.ckpt_every)

    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    values, _ = split_params(params)
    params = values
    opt_state = adamw.adamw_init(params, tcfg.opt)
    start_step = 0

    if args.resume:
        images = registry.list_images()
        best = None
        for img in images:
            meta = registry.image_meta(img)
            if meta.get("worker") == f"train-{args.arch}":
                if best is None or meta["step"] > best[0]:
                    best = (meta["step"], img)
        if best is not None:
            trees, _ = registry.pull_image(best[1])
            params = jax.tree.map(jnp.asarray, trees["params"])
            opt_state = jax.tree.map(jnp.asarray, trees["opt"])
            start_step = best[0] + 1
            print(f"[train] resumed from step {best[0]} image {best[1]}")

    step_fn = jax.jit(steplib.build_train_step(cfg, tcfg),
                      donate_argnums=(0, 1))

    ewma = None
    for step in range(start_step, args.steps):
        batch = jax.tree.map(jnp.asarray, ds.batch(step))
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jnp.asarray(step, jnp.int32))
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt  # straggler signal
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} gnorm "
                  f"{float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms "
                  f"(ewma {ewma*1e3:.0f}ms)")
        ckpt.maybe_save(step, {"params": params, "opt": opt_state})
    ckpt.save(args.steps - 1, {"params": params, "opt": opt_state}, block=True)
    print("[train] done; final loss", float(metrics["loss"]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
