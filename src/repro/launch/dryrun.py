import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first use.

DOC = """Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract the roofline terms.

For each cell:
  * build the production mesh (16x16 single-pod / 2x16x16 multi-pod);
  * derive shardings for params/optimizer/cache/batch from logical axes;
  * ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` — zero device
    allocation (AOT on placeholder host devices);
  * record memory_analysis(), cost_analysis(), and collective bytes parsed
    from the compiled HLO (all-gather/all-reduce/reduce-scatter/all-to-all/
    collective-permute), then the three roofline terms:
        compute    = FLOPs_per_chip / 197e12
        memory     = bytes_per_chip / 819e9
        collective = coll_bytes_per_chip / 50e9

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out results/dryrun.json
"""
__doc__ = DOC

import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES, ModelConfig, ShapeSpec
from repro.optim import adamw
from repro.train import step as steplib

# --------------------------------------------------------------------------
# hardware constants (TPU v5e)
# --------------------------------------------------------------------------
PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum result-operand bytes of every collective op in the HLO."""
    per_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            # match "= <type> all-reduce(" and fused variants like
            # "all-reduce-start("; skip "-done" (same buffer, avoid double count)
            marker = f" {kind}("
            marker_start = f" {kind}-start("
            if marker in stripped or marker_start in stripped:
                idx = stripped.find(marker)
                if idx < 0:
                    idx = stripped.find(marker_start)
                lhs = stripped[:idx]
                if "=" in lhs:
                    lhs = lhs.split("=", 1)[1]
                total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))
                per_kind[kind] += total
                counts[kind] += 1
                break
    return {
        "bytes_by_kind": per_kind,
        "counts": counts,
        "total_bytes": sum(per_kind.values()),
    }


# --------------------------------------------------------------------------
# per-cell dry run
# --------------------------------------------------------------------------

def _mem_dict(compiled) -> Dict[str, Any]:
    try:
        m = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(m, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(m, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(m, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(m, "generated_code_size_in_bytes", 0)),
            "alias_bytes": int(getattr(m, "alias_size_in_bytes", 0)),
        }
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def _cost_dict(compiled) -> Dict[str, float]:
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        return {
            "flops": float(c.get("flops", 0.0)),
            "bytes_accessed": float(c.get("bytes accessed", 0.0)),
            "transcendentals": float(c.get("transcendentals", 0.0)),
        }
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def opt_config_for(cfg: ModelConfig) -> adamw.AdamWConfig:
    # 400B-class: factored second moment + bf16 first moment so the
    # optimizer state fits 16 GB/chip at 256-way sharding (DESIGN.md §3).
    if cfg.param_count() > 100e9:
        return adamw.AdamWConfig(factored=True, moment_dtype="bfloat16")
    return adamw.AdamWConfig()


def _compile_cell(cfg, shape, mesh, rules, tcfg=None, unroll=False):
    """Lower + compile one step for (cfg, shape) on mesh; returns compiled."""
    if shape.kind == "train":
        tcfg = tcfg or steplib.TrainStepConfig(opt=opt_config_for(cfg),
                                               unroll=unroll)
        if unroll and not tcfg.unroll:
            tcfg = dataclasses.replace(tcfg, unroll=True)
        p_shapes, p_shard, o_shapes, o_shard = steplib.train_state_shardings(
            cfg, mesh, tcfg.opt, rules, param_dtype=tcfg.param_dtype)
        b_shard = steplib.batch_shardings(cfg, shape, mesh, rules)
        specs = steplib.input_specs(cfg, shape)
        step_fn = steplib.build_train_step(cfg, tcfg)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, o_shard, b_shard, None),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = jitted.lower(
                p_shapes, o_shapes, specs, jax.ShapeDtypeStruct((), jnp.int32))
            return lowered.compile()
    elif shape.kind == "prefill":
        p_shapes, p_axes = steplib.param_shapes_and_axes(cfg)
        p_shard = steplib._shardings_from(mesh, p_axes, p_shapes, rules)
        c_shapes, c_shard = steplib.cache_shardings(cfg, shape, mesh, rules)
        b_shard = steplib.batch_shardings(cfg, shape, mesh, rules)
        step_fn = steplib.build_prefill_step(cfg, unroll=unroll)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, c_shard, b_shard),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )
        specs = steplib.input_specs(cfg, shape)
        with mesh:
            lowered = jitted.lower(p_shapes, c_shapes, specs)
            return lowered.compile()
    else:  # decode
        p_shapes, p_axes = steplib.param_shapes_and_axes(cfg)
        p_shard = steplib._shardings_from(mesh, p_axes, p_shapes, rules)
        c_shapes, c_shard = steplib.cache_shardings(cfg, shape, mesh, rules)
        b_shard = steplib.batch_shardings(cfg, shape, mesh, rules)
        step_fn = steplib.build_decode_step(cfg, unroll=unroll)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, c_shard, b_shard["tokens"],
                          b_shard["positions"]),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )
        specs = steplib.input_specs(cfg, shape)
        with mesh:
            lowered = jitted.lower(p_shapes, c_shapes, specs["tokens"],
                                   specs["positions"])
            return lowered.compile()


def _reduced_cfg(cfg: ModelConfig, k: int) -> ModelConfig:
    """Same config with k layer groups (for cost calibration)."""
    kw = {"num_layers": cfg.group_size * k}
    if cfg.is_encoder_decoder:
        kw["num_encoder_layers"] = max(
            1, cfg.num_encoder_layers // cfg.num_groups * k)
    return dataclasses.replace(cfg, **kw)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             tcfg: Optional[steplib.TrainStepConfig] = None,
             calibrate: bool = True) -> Dict[str, Any]:
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    # real wall-clock compile timing, not sim time
    t_start = time.time()  # simlint: disable=SIM002
    row: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
    }
    if not cfg.runnable(shape):
        row["status"] = "SKIP"
        row["reason"] = ("long_500k needs sub-quadratic attention; "
                         f"{cfg.name} is full-attention (DESIGN.md §4)")
        return row

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rules = steplib.arch_rules(cfg)
    rules.dropped.clear()

    # 1) full-depth scanned compile: proves the cell compiles at scale and
    #    yields the honest memory analysis.
    compiled = _compile_cell(cfg, shape, mesh, rules, tcfg=tcfg)
    cost = _cost_dict(compiled)
    mem = _mem_dict(compiled)
    coll = parse_collective_bytes(compiled.as_text())

    # 2) cost calibration: XLA counts a scan body once, so derive per-group
    #    costs from two small *unrolled* variants (k=1, k=2 groups):
    #    total(G) = c1 + (G-1) * (c2 - c1).
    G = cfg.num_groups
    calib = None
    if calibrate and G > 1:
        c1 = _compile_cell(_reduced_cfg(cfg, 1), shape, mesh, rules,
                           tcfg=tcfg, unroll=True)
        c2 = _compile_cell(_reduced_cfg(cfg, 2), shape, mesh, rules,
                           tcfg=tcfg, unroll=True)
        cost1, cost2 = _cost_dict(c1), _cost_dict(c2)
        coll1 = parse_collective_bytes(c1.as_text())
        coll2 = parse_collective_bytes(c2.as_text())

        def corr(a, b):
            return a + (G - 1) * (b - a)

        calib = {
            "flops": corr(cost1.get("flops", 0.0), cost2.get("flops", 0.0)),
            "bytes_accessed": corr(cost1.get("bytes_accessed", 0.0),
                                   cost2.get("bytes_accessed", 0.0)),
            "coll_bytes": corr(coll1["total_bytes"], coll2["total_bytes"]),
            "coll_by_kind": {
                k: corr(coll1["bytes_by_kind"][k], coll2["bytes_by_kind"][k])
                for k in coll1["bytes_by_kind"]},
            "k1": {"cost": cost1, "coll": coll1["total_bytes"]},
            "k2": {"cost": cost2, "coll": coll2["total_bytes"]},
        }
        flops_pd = calib["flops"]
        bytes_pd = calib["bytes_accessed"]
        coll_pd = calib["coll_bytes"]
    else:
        flops_pd = cost.get("flops", 0.0)
        bytes_pd = cost.get("bytes_accessed", 0.0)
        coll_pd = coll["total_bytes"]

    tokens = shape.batch * (shape.seq if shape.kind != "decode" else 1)
    # MODEL_FLOPS: 6ND for a train step (fwd+bwd), 2ND forward-only
    model_flops = cfg.model_flops(tokens) * (1.0 if shape.kind == "train" else 1.0 / 3.0)

    compute_t = flops_pd / PEAK_FLOPS
    memory_t = bytes_pd / HBM_BW
    coll_t = coll_pd / LINK_BW
    dominant = max(
        (("compute", compute_t), ("memory", memory_t), ("collective", coll_t)),
        key=lambda kv: kv[1])[0]

    row.update({
        "status": "OK",
        "chips": chips,
        "cost_analysis": cost,
        "memory_analysis": mem,
        "collectives": coll,
        "roofline": {
            "compute_s": compute_t,
            "memory_s": memory_t,
            "collective_s": coll_t,
            "dominant": dominant,
            "flops_per_chip": flops_pd,
            "bytes_per_chip": bytes_pd,
            "coll_bytes_per_chip": coll_pd,
            "model_flops_global": model_flops,
            "hlo_flops_global": flops_pd * chips,
            "useful_flops_ratio": (model_flops / (flops_pd * chips)
                                   if flops_pd else 0.0),
        },
        "dropped_shardings": [list(map(str, d)) for d in rules.dropped[:20]],
        "compile_seconds": round(time.time() - t_start, 1),  # simlint: disable=SIM002
    })
    return row


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args(argv)

    archs = configs.list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    import os as _os
    _os.makedirs(_os.path.dirname(args.out) or ".", exist_ok=True)
    rows = []
    mode = "a" if args.append else "w"
    failures = 0
    with open(args.out, mode) as f:
        for arch in archs:
            for shape in shapes:
                for multi in meshes:
                    label = f"{arch} x {shape} x {'2x16x16' if multi else '16x16'}"
                    print(f"[dryrun] {label} ...", flush=True)
                    try:
                        row = run_cell(arch, shape, multi)
                    except Exception as e:  # noqa: BLE001
                        row = {"arch": arch, "shape": shape,
                               "mesh": "2x16x16" if multi else "16x16",
                               "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                               "traceback": traceback.format_exc()[-2000:]}
                        failures += 1
                    rows.append(row)
                    f.write(json.dumps(row) + "\n")
                    f.flush()
                    status = row["status"]
                    extra = ""
                    if status == "OK":
                        r = row["roofline"]
                        extra = (f" dominant={r['dominant']}"
                                 f" compute={r['compute_s']*1e3:.1f}ms"
                                 f" mem={r['memory_s']*1e3:.1f}ms"
                                 f" coll={r['collective_s']*1e3:.1f}ms"
                                 f" useful={r['useful_flops_ratio']:.2f}"
                                 f" ({row['compile_seconds']}s)")
                    print(f"[dryrun] {label}: {status}{extra}", flush=True)
    ok = sum(1 for r in rows if r["status"] == "OK")
    skip = sum(1 for r in rows if r["status"] == "SKIP")
    print(f"[dryrun] done: {ok} OK, {skip} SKIP, {failures} FAIL")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
