"""Production mesh builders.

v5e pod = 16x16 (256 chips); multi-pod = 2 pods = 512 chips with a leading
``pod`` axis (cross-pod collectives traverse DCN).  Functions, not module
constants: importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-host debug mesh (1x1) with the same axis names."""
    return jax.make_mesh((1, 1), ("data", "model"))
