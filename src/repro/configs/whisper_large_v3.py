"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder, audio.

32 encoder + 32 decoder layers, d_model=1280, 20 heads (MHA), d_ff=5120
(plain GELU MLP, non-gated), vocab=51866.  The conv mel frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings [B, 1500, d_model].
Sinusoidal encoder positions, learned decoder positions, tied unembedding.
"""
import dataclasses

from repro.models.config import BlockKind as BK, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    pattern=((BK.ATTN_GLOBAL, BK.MLP),),
    rope_kind="none",
    mlp_gated=False,
    mlp_act="gelu",
    is_encoder_decoder=True,
    num_encoder_layers=32,
    encoder_seq=1500,
    frontend="audio_frames",
    tie_embeddings=True,
    attn_sharding="seq",  # 20 heads don't divide the 16-way model axis
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, num_encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=512, head_dim=16,
        encoder_seq=24, dtype="float32",
    )
