"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full published config; ``get_smoke(name)``
returns a reduced same-family config for CPU smoke tests (small dims, same
pattern).  The full configs are only ever lowered via ShapeDtypeStructs
(launch/dryrun.py) — never allocated on this host.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCHS: List[str] = [
    "codeqwen1_5_7b",
    "gemma3_4b",
    "chatglm3_6b",
    "smollm_360m",
    "whisper_large_v3",
    "llama4_maverick_400b_a17b",
    "granite_moe_1b_a400m",
    "recurrentgemma_2b",
    "qwen2_vl_72b",
    "xlstm_350m",
    "paper_consumer",  # the paper's own evaluation microservice model
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def _module(name: str):
    name = _ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def list_archs(include_paper: bool = False) -> List[str]:
    archs = [a for a in ARCHS if a != "paper_consumer"]
    return ARCHS if include_paper else archs
