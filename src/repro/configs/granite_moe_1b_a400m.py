"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base] — MoE.

24L, d_model=1024, 16 heads (GQA kv=8), d_ff=512 per expert, vocab=49155,
32 experts top-8, every layer MoE, tied embeddings.
"""
import dataclasses

from repro.models.config import BlockKind as BK, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    pattern=((BK.ATTN_GLOBAL, BK.MOE),),
    num_experts=32,
    num_experts_per_tok=8,
    capacity_factor=1.25,
    tie_embeddings=True,
    attn_sharding="heads",  # 16 heads / 16-way model axis
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=32, vocab_size=512, head_dim=16, num_experts=4,
        num_experts_per_tok=2, dtype="float32",
    )
