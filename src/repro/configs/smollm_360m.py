"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-360M] — llama-arch small.

32L, d_model=960, 15 heads (GQA kv=5), d_ff=2560, vocab=49152, tied.
"""
import dataclasses

from repro.models.config import BlockKind as BK, ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    head_dim=64,
    pattern=((BK.ATTN_GLOBAL, BK.MLP),),
    tie_embeddings=True,
    attn_sharding="seq",  # 15 heads don't divide the 16-way model axis
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=60, num_heads=3, num_kv_heads=1,
        d_ff=128, vocab_size=512, head_dim=20, dtype="float32",
    )
