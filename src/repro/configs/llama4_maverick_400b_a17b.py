"""Llama-4-Maverick-400B-A17B [hf:meta-llama/Llama-4-* family] — MoE.

48L, d_model=5120, 40 heads (GQA kv=8), d_ff=8192, vocab=202048,
MoE 128 experts top-1 with a shared expert, MoE interleaved every other
layer (dense SwiGLU on the rest) — the published Maverick layout, which
also reconciles the 400B-total / 17B-active budget:
  total  ~= 2*1.03B embed + 3.0B attn + 24*(128+1)*126M moe + 24*126M dense
         ~= 397B;     active ~= 14-17B (top-1 + shared + dense + attn).
"""
import dataclasses

from repro.models.config import BlockKind as BK, ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    head_dim=128,
    pattern=((BK.ATTN_GLOBAL, BK.MLP), (BK.ATTN_GLOBAL, BK.MOE)),
    num_experts=128,
    num_experts_per_tok=1,
    shared_expert=True,
    capacity_factor=1.25,
    rope_theta=500_000.0,
    tie_embeddings=False,
    attn_sharding="seq",  # 40 heads don't divide the 16-way model axis
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=512, head_dim=16, num_experts=4,
        num_experts_per_tok=1, dtype="float32",
    )
