"""ChatGLM3-6B [arXiv:2406.12793] — dense, 2D (partial) RoPE, GQA kv=2.

28L, d_model=4096, 32 heads (GQA kv=2), d_ff=13696, vocab=65024.
RoPE applied to half the head dim (GLM's 2D rope), untied embeddings.
"""
import dataclasses

from repro.models.config import BlockKind as BK, ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    head_dim=128,
    pattern=((BK.ATTN_GLOBAL, BK.MLP),),
    rope_kind="partial",
    rope_fraction=0.5,
    tie_embeddings=False,
    attn_sharding="heads",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16, dtype="float32",
    )
