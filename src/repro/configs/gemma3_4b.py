"""Gemma3-4B [hf:google/gemma-3-4b-pt family] — dense, 5:1 local:global.

34L, d_model=2560, 8 heads (GQA kv=4), head_dim=256, d_ff=10240,
vocab=262144, sliding window 1024, QK-norm, dual rope thetas
(1M global / 10k local), 128k context.

Pattern note: 34 layers with a strict 6-layer (5L+1G) period don't divide;
we use a 17-layer period (5L,G,5L,G,5L) x 2 groups = 30 local + 4 global,
preserving the ~5:1 ratio while keeping the scan-group compilation model.
"""
import dataclasses

from repro.models.config import BlockKind as BK, ModelConfig

_P17 = ((BK.ATTN_LOCAL, BK.MLP),) * 5 + ((BK.ATTN_GLOBAL, BK.MLP),) \
    + ((BK.ATTN_LOCAL, BK.MLP),) * 5 + ((BK.ATTN_GLOBAL, BK.MLP),) \
    + ((BK.ATTN_LOCAL, BK.MLP),) * 5

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262_144,
    head_dim=256,
    pattern=_P17,
    window=1024,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    use_qk_norm=True,
    logits_softcap=30.0,
    tie_embeddings=True,
    attn_sharding="seq",  # 8 heads don't divide the 16-way model axis
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=17, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16, window=8, dtype="float32",
    )
