"""Qwen2-VL-72B [arXiv:2409.12191] — VLM backbone with M-RoPE.

80L, d_model=8192, 64 heads (GQA kv=8), d_ff=29568, vocab=152064.
M-RoPE sections (16,24,24) over temporal/height/width position ids.
The vision tower is a STUB: ``input_specs()`` provides precomputed patch
embeddings [B, 256, d_model] merged into the token stream, plus [3,B,S]
position ids (t==h==w for text tokens).
"""
import dataclasses

from repro.models.config import BlockKind as BK, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152_064,
    head_dim=128,
    pattern=((BK.ATTN_GLOBAL, BK.MLP),),
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    frontend="image_patches",
    num_patches=256,
    tie_embeddings=False,
    attn_sharding="heads",  # 64 heads / 16-way model axis
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16, num_patches=4,
        mrope_sections=(8, 12, 12), dtype="float32",
    )
