"""RecurrentGemma-2B [arXiv:2402.19427] — griffin hybrid: RG-LRU + local attn.

26L, d_model=2560, 10 heads (MQA kv=1), head_dim=256, d_ff=7680,
vocab=256000, local window 2048, recurrence width 2560.

Pattern note: griffin's strict (R,R,A) period doesn't divide 26; we use a
13-layer period (R,R,A)x4 + R = 9R+4A per group, x2 groups = 18 recurrent +
8 local-attention layers (~2.25:1, matching the paper's 2:1 design intent).

O(1) decode state => runs the long_500k shape (subquadratic=True).
"""
import dataclasses

from repro.models.config import BlockKind as BK, ModelConfig

_P13 = (((BK.RGLRU, BK.MLP),) * 2 + ((BK.ATTN_LOCAL, BK.MLP),)) * 4 \
    + ((BK.RGLRU, BK.MLP),)

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    pattern=_P13,
    window=2048,
    rglru_width=2560,
    conv1d_width=4,
    tie_embeddings=True,
    attn_sharding="seq",  # 10 heads don't divide the 16-way model axis
    subquadratic=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=13, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=128, vocab_size=512, head_dim=16, window=8, rglru_width=64,
        dtype="float32",
    )
