"""The paper's evaluation workload: the *consumer microservice*.

The paper's consumer is a Spring Boot service whose in-memory state is the
fold of RabbitMQ messages.  Our consumer is its JAX analogue: a small LM
serving replica whose migratable state is the KV/recurrent cache built by
processing a stream of requests (messages).  Small enough that the
migration benchmarks run the *real* model on CPU (no simulation of the
compute), so µ_target in the cutoff formula is measured, not assumed.
"""
import dataclasses

from repro.models.config import BlockKind as BK, ModelConfig

CONFIG = ModelConfig(
    name="paper-consumer",
    family="dense",
    num_layers=4,
    d_model=256,
    num_heads=8,
    num_kv_heads=4,
    d_ff=1024,
    vocab_size=2048,
    head_dim=32,
    pattern=((BK.ATTN_GLOBAL, BK.MLP),),
    tie_embeddings=True,
    attn_sharding="heads",
    dtype="float32",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(CONFIG, num_layers=2, d_model=64, d_ff=128,
                               num_heads=4, num_kv_heads=2, head_dim=16,
                               vocab_size=512)
