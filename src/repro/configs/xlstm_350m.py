"""xLSTM-350M [arXiv:2405.04517] — sLSTM + mLSTM blocks (xLSTM[7:1]).

24L, d_model=1024, 4 heads, vocab=50304, no separate FFN (d_ff=0; mLSTM
blocks carry a factor-2 pre-up-projection internally).  Pattern: 7 mLSTM +
1 sLSTM per group x 3 groups.

O(1) decode state => runs the long_500k shape (subquadratic=True).
"""
import dataclasses

from repro.models.config import BlockKind as BK, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    pattern=((BK.MLSTM, BK.NONE),) * 7 + ((BK.SLSTM, BK.NONE),),
    tie_embeddings=True,
    attn_sharding="seq",
    subquadratic=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=8, d_model=64, num_heads=4, num_kv_heads=4,
        vocab_size=512, head_dim=16, dtype="float32",
    )
