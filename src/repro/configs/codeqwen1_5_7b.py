"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B] — dense, qwen1.5 arch.

32L, d_model=4096, 32 heads (MHA: kv=32), d_ff=13440, vocab=92416.
RoPE theta 1e6 (64k context), untied embeddings, SwiGLU.
"""
import dataclasses

from repro.models.config import BlockKind as BK, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    head_dim=128,
    pattern=((BK.ATTN_GLOBAL, BK.MLP),),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    attn_sharding="heads",  # 32 heads / 16-way model axis
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, head_dim=16, dtype="float32",
    )
