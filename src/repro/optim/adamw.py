"""AdamW built in-repo (no optax): sharded moments, decoupled weight decay,
global-norm clipping, optional factored second moment (Adafactor-style) for
the 400B-class archs where full fp32 moments would not fit 16 GB/chip.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    factored: bool = False  # factored 2nd moment for giant models
    min_factored_dim: int = 128


def _factorable(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 128 and shape[-2] >= 128


def adamw_init(params, cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)

    def init_leaf(p):
        m = jnp.zeros(p.shape, mdt)
        if cfg.factored and _factorable(p.shape):
            v = {
                "row": jnp.zeros(p.shape[:-1], mdt),
                "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], mdt),
            }
        else:
            v = jnp.zeros(p.shape, mdt)
        return {"m": m, "v": v}

    return {
        "count": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(init_leaf, params),
    }


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def _vhat(mu_v, g2, b2, shape):
    """Update + reconstruct the (possibly factored) second moment."""
    if isinstance(mu_v, dict):  # factored
        row = b2 * mu_v["row"] + (1 - b2) * jnp.mean(g2, axis=-1)
        col = b2 * mu_v["col"] + (1 - b2) * jnp.mean(g2, axis=-2)
        mean_row = jnp.mean(row, axis=-1, keepdims=True)
        v_full = (row[..., None] * col[..., None, :]
                  / jnp.maximum(mean_row[..., None], 1e-30))
        return {"row": row, "col": col}, v_full
    v = b2 * mu_v + (1 - b2) * g2
    return v, v


def adamw_update(params, grads, state, cfg: AdamWConfig, lr=None):
    """Returns (new_params, new_state, metrics).  params fp32 leaves."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state["count"] + 1
    lr = cfg.lr if lr is None else lr
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mu):
        g = g.astype(jnp.float32)
        m = cfg.b1 * mu["m"].astype(jnp.float32) + (1 - cfg.b1) * g
        new_v, v_full = _vhat(
            jax.tree.map(lambda x: x.astype(jnp.float32), mu["v"]),
            jnp.square(g), cfg.b2, p.shape)
        mhat = m / b1c
        vhat = v_full / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (step + cfg.weight_decay * p.astype(jnp.float32))
        mdt = jnp.dtype(cfg.moment_dtype)
        return new_p.astype(p.dtype), {
            "m": m.astype(mdt),
            "v": jax.tree.map(lambda x: x.astype(mdt), new_v),
        }

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    out = [upd(p, g, mu) for p, g, mu in zip(flat_p, flat_g, flat_mu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    return new_params, {"count": count, "mu": new_mu}, {"grad_norm": gnorm}


def opt_state_logical_axes(param_axes, cfg: AdamWConfig):
    """Optimizer-state logical axes mirror the parameter axes (FSDP: moments
    shard exactly like their parameter)."""
    def leaf_axes(ax):
        if cfg.factored:
            # we can't know factorability without shapes; callers using
            # factored mode derive axes from eval_shape instead.
            raise NotImplementedError
        return {"m": ax, "v": ax}

    return {
        "count": (),
        "mu": jax.tree.map(
            leaf_axes, param_axes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x),
        ),
    }
