"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

At 512+ chips the pod-to-pod (DCN-ish) all-reduce of bf16 gradients is the
first collective to saturate; int8 block-quantization with error feedback
(residual carried to the next step) cuts those bytes 2x with negligible
quality loss — a standard distributed-optimization trick (1-bit Adam / EF21
family), applied here only across the ``pod`` axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 256
# scale = max|x| * (1/127), NOT max|x| / 127: under jit XLA rewrites a
# division by a constant into a reciprocal multiply (1-ulp different),
# while eager mode keeps the true division — the multiply form is the
# one expression both agree on bit-exactly, which the fused Pallas codec
# kernels (kernels/codec.py) rely on to reproduce this quantizer
# byte-identically from inside a jitted pallas_call.  A numpy scalar (not
# a jnp array) so Pallas kernel bodies can close over it as a literal.
_INV127 = np.float32(1.0) / np.float32(127.0)


def ef_init(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quant(x):
    """Blockwise symmetric int8 quantization along the last dim."""
    orig_shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) * _INV127
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), orig_shape, pad


def _dequant(q, scale, orig_shape, pad):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(orig_shape)


def compress_gradients(grads, ef_state):
    """-> (quantized tree, new ef_state).  g_q = Q(g + e); e' = g + e - g_q."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale, shape, pad = _quant(x)
        deq = _dequant(q, scale, shape, pad)
        return {"q": q, "scale": scale, "pad": pad}, x - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([p[0] for p in pairs]), tdef.unflatten([p[1] for p in pairs])


def decompress_gradients(comp, like):
    def one(c, g):
        return _dequant(c["q"], c["scale"], g.shape, c["pad"]).astype(g.dtype)

    flat_g, tdef = jax.tree.flatten(like)
    flat_c = tdef.flatten_up_to(comp)
    return tdef.unflatten([one(c, g) for c, g in zip(flat_c, flat_g)])
