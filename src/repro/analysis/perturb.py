"""Virtual-time schedule perturbation: the latent-race detector.

``Sim(tiebreak_seed=N)`` permutes the pop order of *equal-timestamp* heap
events (a bijective splitmix64 hash of the event counter — virtual time
itself never changes).  A correct simulation must not care which of two
events at the same instant runs first unless it explicitly ordered them;
so any run whose *observable result* changes under a tie-break seed has a
latent scheduling race — exactly the class of bug a lucky heap order
hides until a refactor reshuffles event insertion.

Two sweeps, run by ``tools/sim_perturb.py`` (the CI ``sim-perturb`` job):

  * **regression sweep** (hard gate) — the flat-topology single-pod
    migration experiment for each built-in strategy, run unperturbed and
    under K tie-break seeds; every ``ExperimentResult.row()`` must be
    bit-identical to the unperturbed baseline (concurrency is 1 and the
    timeline is float-timed, so nothing may legitimately reorder);
  * **chaos sweep** (invariant gate) — seeded fault-schedule fleet runs
    under each tie-break seed; retries and fair-share flows may reorder
    legitimately, but the crash-consistency invariant (every completed
    migration state-verified, every failure rolled back with the source
    serving) must hold under every permutation.
"""
from __future__ import annotations

import contextlib
import json
import os
import tempfile
from typing import Dict, Iterable, List, Optional, Sequence

_TIEBREAK_ENV = "REPRO_SIM_TIEBREAK"

DEFAULT_TIEBREAK_SEEDS = (1, 2, 3, 4, 5)
REGRESSION_STRATEGIES = ("ms2m_individual", "ms2m_precopy",
                         "ms2m_statefulset")


def canon(row: Dict) -> str:
    """Canonical byte-stable form of a result row for bit-identity
    comparison."""
    return json.dumps(row, sort_keys=True)


@contextlib.contextmanager
def tiebreak(seed: Optional[int]):
    """Set the process-wide tie-break seed for every ``Sim`` constructed
    inside the block (the experiment entry points build their own
    ``Cluster``/``Sim``, so the env fallback is the plumbing)."""
    prev = os.environ.get(_TIEBREAK_ENV)
    try:
        if seed is None:
            os.environ.pop(_TIEBREAK_ENV, None)
        else:
            os.environ[_TIEBREAK_ENV] = str(seed)
        yield
    finally:
        if prev is None:
            os.environ.pop(_TIEBREAK_ENV, None)
        else:
            os.environ[_TIEBREAK_ENV] = prev


def regression_row(strategy: str, *, tiebreak_seed: Optional[int] = None,
                   message_rate: float = 8.0, seed: int = 0) -> Dict:
    """One flat-topology single-pod migration experiment; returns its
    result row."""
    from repro.core.workload import run_migration_experiment

    with tempfile.TemporaryDirectory() as root, tiebreak(tiebreak_seed):
        res = run_migration_experiment(strategy, message_rate,
                                       registry_root=root, seed=seed)
    return res.row()


def perturb_regressions(
        tiebreak_seeds: Sequence[int] = DEFAULT_TIEBREAK_SEEDS,
        strategies: Iterable[str] = REGRESSION_STRATEGIES,
        message_rate: float = 8.0, seed: int = 0) -> Dict:
    """The hard bit-identity gate: every strategy's flat-topology timeline
    row must match the unperturbed baseline under every tie-break seed."""
    cells: List[Dict] = []
    for strategy in strategies:
        base = canon(regression_row(strategy, tiebreak_seed=None,
                                    message_rate=message_rate, seed=seed))
        divergent = []
        for ts in tiebreak_seeds:
            row = canon(regression_row(strategy, tiebreak_seed=ts,
                                       message_rate=message_rate, seed=seed))
            if row != base:
                divergent.append(ts)
        cells.append({"strategy": strategy,
                      "tiebreak_seeds": list(tiebreak_seeds),
                      "divergent_seeds": divergent,
                      "bit_identical": not divergent})
    return {"sweep": "regression", "ok": all(c["bit_identical"]
                                             for c in cells),
            "cells": cells}


def perturb_chaos(tiebreak_seeds: Sequence[int] = DEFAULT_TIEBREAK_SEEDS,
                  chaos_seeds: Sequence[int] = (10_000, 10_001),
                  n_faults: int = 1) -> Dict:
    """The invariant gate: seeded fault-schedule fleet runs must keep the
    crash-consistency invariant under every tie-break permutation.
    (Rows may legitimately reorder here — retries re-place targets — so
    this gates on the invariant, not bit identity.)"""
    from benchmarks.chaos import _run_one

    cells: List[Dict] = []
    for cs in chaos_seeds:
        broken = []
        for ts in tiebreak_seeds:
            with tiebreak(ts):
                out = _run_one("ms2m_precopy", cs, n_faults)
            if not out["invariant_ok"]:
                broken.append(ts)
        cells.append({"chaos_seed": cs, "tiebreak_seeds": list(tiebreak_seeds),
                      "invariant_broken_seeds": broken,
                      "invariant_ok": not broken})
    return {"sweep": "chaos", "ok": all(c["invariant_ok"] for c in cells),
            "cells": cells}


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="sim-perturb",
        description="run the regression + chaos suites under tie-break "
                    "perturbation seeds and flag timeline/invariant "
                    "divergence as a latent scheduling race")
    ap.add_argument("--seeds", type=int, default=5,
                    help="number of tie-break seeds (default 5)")
    ap.add_argument("--skip-chaos", action="store_true",
                    help="regression bit-identity sweep only")
    ap.add_argument("--json", action="store_true",
                    help="emit the full report as JSON")
    args = ap.parse_args(argv)

    seeds = tuple(range(1, args.seeds + 1))
    reports = [perturb_regressions(seeds)]
    if not args.skip_chaos:
        reports.append(perturb_chaos(seeds))

    ok = all(r["ok"] for r in reports)
    if args.json:
        print(json.dumps({"ok": ok, "reports": reports}, indent=2))
    else:
        for r in reports:
            for cell in r["cells"]:
                label = cell.get("strategy") or f"chaos:{cell['chaos_seed']}"
                bad = (cell.get("divergent_seeds")
                       or cell.get("invariant_broken_seeds"))
                status = ("OK" if not bad
                          else f"RACE under tie-break seeds {bad}")
                print(f"[{r['sweep']:10s}] {label:24s} {status}")
        print(f"sim-perturb {'OK' if ok else 'FAILED'} "
              f"({len(seeds)} tie-break seeds)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
