"""Runtime leak/race sanitizer for the discrete-event kernel.

Enabled with ``Sim(sanitize=True)`` or ``REPRO_SIM_SANITIZE=1``, the
sanitizer attaches creation-site provenance to kernel objects
(``Condition``, ``Link`` flows, waiting processes) and raises a
:class:`SanitizerViolation` — carrying the offending object's creation
stack — the moment one of these invariants breaks:

  * **callback/listener leak** — a single condition, pod listener list or
    broker mirror list grows past ``max_listeners`` registrations.  Both
    historical leaks (the PR 1 ``on_processed`` listener leak and the
    PR 4 ``any_of`` loser-callback leak) are exactly this signature:
    every migration/wakeup added one entry and nothing ever removed it;
  * **conflicting double-trigger** — a triggered condition is triggered
    again with a *different* payload value.  (Idempotent re-triggers with
    no value are part of the kernel contract and stay legal.)
  * **stale pause** — a pod that a migration rollback restored to service
    is paused again with no migration owning it: the signature of a stale
    cutoff deadline firing after ``MigrationContext.closed`` (the PR 5
    bug class);
  * **dangling waiters / flows at quiescence** — ``Sim.assert_quiescent``
    reports processes parked on conditions that can never trigger and
    link flows still in flight after the heap drained.

The checks are O(1) per kernel operation; with sanitize off the kernel
pays a single ``is None`` test per hook.
"""
from __future__ import annotations

import os
import traceback
from typing import Any, Dict, List, Optional, Tuple

_ENV_MAX = "REPRO_SIM_SANITIZE_MAX"
_SKIP_BASENAMES = ("sanitizer.py",)


def capture_site(limit: int = 6) -> Tuple[str, ...]:
    """A compact creation-site stack: innermost-last ``file:line in fn``
    strings, with sanitizer frames dropped."""
    frames = traceback.extract_stack()[:-1]  # drop capture_site itself
    out = []
    for fr in frames:
        base = os.path.basename(fr.filename)
        if base in _SKIP_BASENAMES:
            continue
        out.append(f"{base}:{fr.lineno} in {fr.name}")
    return tuple(out[-limit:])


def format_site(site: Optional[Tuple[str, ...]]) -> str:
    if not site:
        return "<no provenance: sanitize was off at creation>"
    return " -> ".join(site)


class SanitizerViolation(AssertionError):
    """A kernel-hygiene invariant broke.  ``created`` is the offending
    object's creation site, ``site`` the stack of the operation that
    tripped the check."""

    def __init__(self, kind: str, message: str,
                 created: Optional[Tuple[str, ...]] = None,
                 site: Optional[Tuple[str, ...]] = None):
        self.kind = kind
        self.created = created
        self.site = site
        lines = [f"[{kind}] {message}"]
        if created:
            lines.append(f"  created at: {format_site(created)}")
        if site:
            lines.append(f"  detected at: {format_site(site)}")
        super().__init__("\n".join(lines))


# condition-name patterns that legitimately hold waiters/callbacks at
# quiescence: idle service loops parked on queue/wake conditions, node
# down-watchers, and the kernel's own any_of fan-in conditions
DEFAULT_IDLE_SUFFIXES = (":not_empty", ":wake", ":stall", ":down")
DEFAULT_IDLE_NAMES = ("any",)


class SimSanitizer:
    """Per-``Sim`` sanitizer state (see module docstring)."""

    def __init__(self, max_listeners: Optional[int] = None):
        if max_listeners is None:
            max_listeners = int(os.environ.get(_ENV_MAX, "64"))
        self.max_listeners = max_listeners
        # proc -> the untriggered Condition it is parked on (strong refs:
        # bounded by the number of live processes)
        self._waiting: Dict[Any, Any] = {}
        self._links: List[Any] = []
        # pods restored by a migration rollback with no migration owning
        # them: pausing one is the stale-cutoff-deadline bug class
        self._protected_pods: Dict[int, Tuple[Any, Tuple[str, ...]]] = {}
        self.stats: Dict[str, int] = {"conditions": 0, "registrations": 0,
                                      "disarmed_timers": 0}

    # -- provenance -----------------------------------------------------------
    def track_condition(self, cond) -> None:
        self.stats["conditions"] += 1
        cond.created = capture_site()

    def track_link(self, link) -> None:
        link.created = capture_site()
        self._links.append(link)

    # -- callback / listener growth -------------------------------------------
    def on_register_callback(self, cond) -> None:
        """Called after every ``Condition.on_trigger`` registration."""
        self.stats["registrations"] += 1
        n = len(cond._callbacks)
        if n > self.max_listeners:
            raise SanitizerViolation(
                "callback_leak",
                f"condition {cond.name!r} holds {n} callbacks and keeps "
                f"growing — a long-lived condition is accumulating "
                f"registrations nothing detaches (the any_of loser-leak "
                f"signature)",
                created=getattr(cond, "created", None),
                site=capture_site())

    def check_listener_growth(self, owner: str, n: int,
                              created: Optional[Tuple[str, ...]] = None
                              ) -> None:
        """Generic growth tripwire for listener lists outside the kernel
        (pod ``on_processed`` listeners, broker mirrors, migration
        listeners)."""
        if n > self.max_listeners:
            raise SanitizerViolation(
                "listener_leak",
                f"{owner} holds {n} listeners and keeps growing — "
                f"registrations are not being deregistered (the "
                f"on_processed listener-leak signature)",
                created=created, site=capture_site())

    # -- double trigger ---------------------------------------------------------
    def on_retrigger(self, cond, value) -> None:
        """A triggered condition was triggered again.  Value-less (or
        same-value) re-triggers are the kernel's idempotency contract;
        a *conflicting* payload means two owners both believe they
        completed this condition."""
        if value is not None and value is not cond.value:
            raise SanitizerViolation(
                "double_trigger",
                f"condition {cond.name!r} re-triggered with a conflicting "
                f"value {value!r} (already carries {cond.value!r})",
                created=getattr(cond, "created", None),
                site=capture_site())

    # -- waiter bookkeeping -----------------------------------------------------
    def on_wait(self, proc, cond) -> None:
        self._waiting[proc] = cond

    def on_ready(self, proc) -> None:
        self._waiting.pop(proc, None)

    # -- stale-pause watchpoints ------------------------------------------------
    def protect_pod(self, pod) -> None:
        """Arm a watchpoint: ``pod`` was just restored to service by a
        migration rollback; until a new migration claims it (or it is
        stopped), pausing it again means a stale timer outlived its
        migration."""
        self._protected_pods[id(pod)] = (pod, capture_site())

    def unprotect_pod(self, pod) -> None:
        self._protected_pods.pop(id(pod), None)

    def on_pause(self, pod) -> None:
        hit = self._protected_pods.get(id(pod))
        if hit is not None:
            _, restored_at = hit
            raise SanitizerViolation(
                "stale_pause",
                f"pod {pod.name!r} was restored to service by a migration "
                f"rollback and is being paused again with no migration "
                f"owning it — a stale cutoff deadline (or similar timer) "
                f"outlived MigrationContext.closed",
                created=restored_at, site=capture_site())

    def note_disarmed_timer(self) -> None:
        """A context-guarded timer fired after its migration closed and
        correctly disarmed itself (benign; counted for telemetry)."""
        self.stats["disarmed_timers"] += 1

    # -- quiescence -------------------------------------------------------------
    def dangling(self, allow_suffixes=DEFAULT_IDLE_SUFFIXES,
                 allow_names=DEFAULT_IDLE_NAMES) -> List[str]:
        """Human-readable descriptions of every leak visible once the
        event heap has drained: processes parked on conditions that can
        never trigger, and link flows still in flight."""
        out: List[str] = []
        for proc, cond in self._waiting.items():
            if cond.triggered:
                continue
            name = cond.name or ""
            if name in allow_names or name.endswith(allow_suffixes):
                continue
            out.append(
                f"process {proc.name!r} waits forever on condition "
                f"{name!r} (created at: "
                f"{format_site(getattr(cond, 'created', None))})")
        for link in self._links:
            for flow in link._flows:
                out.append(
                    f"link {link.name!r} still carries a flow with "
                    f"{flow.remaining:.0f}/{flow.nbytes:.0f} bytes left "
                    f"(created at: "
                    f"{format_site(getattr(flow, 'created', None))})")
        return out
