# Machine-checked guarantees for the event kernel: the determinism lint
# (repro.analysis.lint / tools/simlint.py), the runtime leak/race
# sanitizer (Sim(sanitize=True)), and the virtual-time schedule
# perturbation harness (Sim(tiebreak_seed=N) / tools/sim_perturb.py).
# See docs/determinism.md for the contract these enforce.
from repro.analysis.sanitizer import (  # noqa: F401
    SanitizerViolation,
    SimSanitizer,
    capture_site,
    format_site,
)
