"""simlint: the determinism lint for the event-kernel codebase.

AST rules encoding the determinism contract (docs/determinism.md) that
every ``src/repro`` module must obey for virtual-time runs to be
reproducible:

  * **SIM001** — ``except Exception`` (or bare ``except``) inside a
    generator function with no ``except Interrupt`` ahead of it.
    ``sim.Interrupt`` subclasses ``Exception``, so a broad handler on a
    generator-process call path silently eats kernel control flow;
  * **SIM002** — wall-clock or unseeded randomness where the virtual
    clock should rule: ``time.time``/``time.monotonic``,
    ``datetime.now``, bare stdlib ``random.*``, legacy unseeded
    ``np.random.*`` (``default_rng(seed)`` and ``jax.random`` with
    explicit keys stay legal);
  * **SIM003** — ordering-sensitive iteration at scheduling decision
    points: ``for x in set(...)`` / set literals, fan-out into ``any_of``
    built from a live ``dict.keys()`` view, and collections mutated while
    being iterated;
  * **SIM004** — busy-poll loops (``while ...: yield <small const>``):
    polling burns heap events and couples behaviour to the poll phase —
    wait on a Condition instead;
  * **SIM005** — ``Condition.on_trigger`` registration inside a loop in a
    function with no paired ``detach``: each pass grows the callback list
    of a (potentially long-lived) condition forever.

Suppress a finding with ``# simlint: disable=SIM002`` (comma-separated
list) on the flagged line or the line directly above it.  ``--json``
emits machine-readable findings.  Exit status 1 iff any un-suppressed
finding remains.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import os
import re
import sys
from typing import Dict, List, Optional, Sequence, Set

RULES: Dict[str, str] = {
    "SIM001": "broad except can swallow sim.Interrupt in a generator",
    "SIM002": "wall-clock time or unseeded randomness under the virtual clock",
    "SIM003": "ordering-sensitive iteration at a scheduling decision point",
    "SIM004": "busy-poll loop (while ...: yield <small const>)",
    "SIM005": "on_trigger registration in a loop without a paired detach",
}

_DISABLE_RE = re.compile(r"#\s*simlint:\s*disable=([A-Z0-9, ]+)")

# stdlib wall-clock calls (time.perf_counter stays legal: it measures the
# duration of real JAX compute, never drives the schedule)
_WALLCLOCK_TIME = {"time", "time_ns", "monotonic", "monotonic_ns"}
_WALLCLOCK_DATETIME = {"now", "utcnow", "today"}
# numpy legacy global-state RNG (np.random.default_rng(seed) is fine)
_NP_LEGACY_RANDOM = {"rand", "randn", "randint", "random", "random_sample",
                     "choice", "shuffle", "permutation", "seed", "uniform",
                     "normal", "exponential", "poisson"}
_BUSY_POLL_MAX_S = 1.0


@dataclasses.dataclass
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _suppressed(lines: Sequence[str], finding: Finding) -> bool:
    """True when the flagged line (or the line directly above) carries a
    ``# simlint: disable=...`` pragma naming the rule."""
    for lineno in (finding.line, finding.line - 1):
        if 1 <= lineno <= len(lines):
            m = _DISABLE_RE.search(lines[lineno - 1])
            if m and finding.rule in {r.strip()
                                      for r in m.group(1).split(",")}:
                return True
    return False


def _is_generator(fn: ast.AST) -> bool:
    """Does this function body yield (ignoring nested defs)?"""
    for node in ast.walk(fn):
        if node is fn:
            continue
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            # don't descend into nested functions
            for sub in list(ast.walk(node)):
                sub._simlint_skip = True  # type: ignore[attr-defined]
    for node in ast.walk(fn):
        if getattr(node, "_simlint_skip", False) or node is fn:
            continue
        if isinstance(node, (ast.Yield, ast.YieldFrom)):
            return True
    return False


def _names_in(expr: Optional[ast.AST]) -> Set[str]:
    """Identifier names mentioned in an except-clause type expression."""
    if expr is None:
        return set()
    out: Set[str] = set()
    for node in ast.walk(expr):
        if isinstance(node, ast.Name):
            out.add(node.id)
        elif isinstance(node, ast.Attribute):
            out.add(node.attr)
    return out


def _expr_key(expr: ast.AST) -> str:
    """A stable textual key for 'is this the same collection expression'."""
    return ast.dump(expr)


class _Checker(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []
        self._fn_stack: List[dict] = []
        self._loop_depth = 0

    def _flag(self, node: ast.AST, rule: str, message: str) -> None:
        self.findings.append(Finding(self.path, node.lineno,
                                     node.col_offset, rule, message))

    # -- function context ------------------------------------------------------
    def _visit_function(self, node) -> None:
        info = {"node": node, "is_gen": _is_generator(node),
                "on_trigger_sites": [], "has_detach": False,
                "outer_loop_depth": self._loop_depth}
        self._fn_stack.append(info)
        saved, self._loop_depth = self._loop_depth, 0
        self.generic_visit(node)
        self._loop_depth = saved
        self._fn_stack.pop()
        if not info["has_detach"]:
            for site in info["on_trigger_sites"]:
                self._flag(
                    site, "SIM005",
                    "on_trigger registered inside a loop with no paired "
                    "detach in this function: each pass grows the "
                    "condition's callback list forever")

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def _in_generator(self) -> bool:
        return bool(self._fn_stack) and self._fn_stack[-1]["is_gen"]

    # -- SIM001 ----------------------------------------------------------------
    def visit_Try(self, node: ast.Try) -> None:
        if self._in_generator():
            interrupt_handled = False
            for handler in node.handlers:
                names = _names_in(handler.type)
                if "Interrupt" in names or "GeneratorExit" in names:
                    interrupt_handled = True
                    continue
                broad = handler.type is None or bool(
                    names & {"Exception", "BaseException"})
                reraises = (len(handler.body) == 1
                            and isinstance(handler.body[0], ast.Raise)
                            and handler.body[0].exc is None)
                if broad and not interrupt_handled and not reraises:
                    self._flag(
                        handler, "SIM001",
                        "broad except in a generator with no prior "
                        "'except Interrupt: raise': sim.Interrupt "
                        "subclasses Exception and would be swallowed here")
        self.generic_visit(node)

    # -- SIM002 ----------------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if isinstance(fn, ast.Attribute):
            base = fn.value
            if isinstance(base, ast.Name):
                if base.id == "time" and fn.attr in _WALLCLOCK_TIME:
                    self._flag(node, "SIM002",
                               f"time.{fn.attr}() reads the wall clock; "
                               f"use sim.now under the virtual clock")
                elif base.id == "datetime" and fn.attr in _WALLCLOCK_DATETIME:
                    self._flag(node, "SIM002",
                               f"datetime.{fn.attr}() reads the wall clock")
                elif base.id == "random":
                    self._flag(node, "SIM002",
                               f"bare random.{fn.attr}() draws from global "
                               f"unseeded state; use np.random.default_rng"
                               f"(seed)")
            elif (isinstance(base, ast.Attribute) and base.attr == "random"
                    and isinstance(base.value, ast.Name)
                    and base.value.id in ("np", "numpy")
                    and fn.attr in _NP_LEGACY_RANDOM):
                self._flag(node, "SIM002",
                           f"np.random.{fn.attr}() uses the legacy global "
                           f"RNG; use np.random.default_rng(seed)")
            if fn.attr == "on_trigger" and self._fn_stack:
                if self._loop_depth > 0:
                    self._fn_stack[-1]["on_trigger_sites"].append(node)
            elif fn.attr in ("detach", "remove_on_processed",
                            "remove_migration_listener", "unlisten_all"):
                for info in self._fn_stack:
                    info["has_detach"] = True
        elif isinstance(fn, ast.Name) and fn.id == "unlisten_all":
            for info in self._fn_stack:
                info["has_detach"] = True
        self._check_anyof_fanout(node)
        self.generic_visit(node)

    # -- SIM003 ----------------------------------------------------------------
    def _check_anyof_fanout(self, node: ast.Call) -> None:
        fn = node.func
        name = (fn.attr if isinstance(fn, ast.Attribute)
                else fn.id if isinstance(fn, ast.Name) else "")
        if name != "any_of":
            return
        for arg in node.args:
            if not isinstance(arg, ast.Starred):
                continue
            v = arg.value
            live_view = (isinstance(v, ast.Call)
                         and isinstance(v.func, ast.Attribute)
                         and v.func.attr in ("keys", "values", "items"))
            unordered = (isinstance(v, (ast.Set, ast.SetComp))
                         or (isinstance(v, ast.Call)
                             and isinstance(v.func, ast.Name)
                             and v.func.id in ("set", "frozenset")))
            if live_view:
                self._flag(node, "SIM003",
                           "any_of fan-out built from a live dict view; "
                           "snapshot it (list(...)) in explicit order "
                           "before yielding")
            elif unordered:
                self._flag(node, "SIM003",
                           "any_of fan-out built from an unordered set: "
                           "callback arm order follows object hashes")

    def _visit_loop(self, node) -> None:
        if isinstance(node, ast.For):
            it = node.iter
            if (isinstance(it, (ast.Set, ast.SetComp))
                    or (isinstance(it, ast.Call)
                        and isinstance(it.func, ast.Name)
                        and it.func.id in ("set", "frozenset"))):
                self._flag(node, "SIM003",
                           "iterating a set at a decision point: visit "
                           "order follows object hashes — sort or use an "
                           "insertion-ordered dict")
            elif isinstance(it, (ast.Name, ast.Attribute)):
                key = _expr_key(it)
                for sub in ast.walk(node):
                    mutates = (
                        (isinstance(sub, ast.Call)
                         and isinstance(sub.func, ast.Attribute)
                         and sub.func.attr in ("pop", "add", "remove",
                                               "discard", "popitem",
                                               "clear", "append")
                         and _expr_key(sub.func.value) == key)
                        or (isinstance(sub, ast.Delete)
                            and any(isinstance(t, ast.Subscript)
                                    and _expr_key(t.value) == key
                                    for t in sub.targets))
                        or (isinstance(sub, ast.Assign)
                            and any(isinstance(t, ast.Subscript)
                                    and _expr_key(t.value) == key
                                    for t in sub.targets)))
                    if mutates:
                        self._flag(sub, "SIM003",
                                   "collection mutated while being "
                                   "iterated: snapshot it (list(...)) "
                                   "first")
                        break
        if isinstance(node, ast.While) and self._in_generator():
            for stmt in ast.walk(node):
                if (isinstance(stmt, ast.Expr)
                        and isinstance(stmt.value, ast.Yield)
                        and isinstance(stmt.value.value, ast.Constant)
                        and isinstance(stmt.value.value.value, (int, float))
                        and 0 < stmt.value.value.value <= _BUSY_POLL_MAX_S):
                    self._flag(stmt, "SIM004",
                               f"busy-poll: yields a constant "
                               f"{stmt.value.value.value!r}s inside a "
                               f"while loop — wait on a Condition instead")
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    visit_For = _visit_loop
    visit_While = _visit_loop


def lint_source(text: str, path: str = "<string>") -> List[Finding]:
    tree = ast.parse(text, filename=path)
    checker = _Checker(path)
    checker.visit(tree)
    lines = text.splitlines()
    out = [f for f in checker.findings if not _suppressed(lines, f)]
    out.sort(key=lambda f: (f.line, f.col, f.rule))
    return out


def lint_file(path: str) -> List[Finding]:
    with open(path, encoding="utf-8") as f:
        return lint_source(f.read(), path)


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    for root in paths:
        if os.path.isfile(root):
            findings.extend(lint_file(root))
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames.sort()
            for fname in sorted(filenames):
                if fname.endswith(".py"):
                    findings.extend(lint_file(os.path.join(dirpath, fname)))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="simlint",
        description="determinism lint for the event-kernel codebase")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as a JSON array")
    args = ap.parse_args(argv)

    findings = lint_paths(args.paths)
    if args.json:
        print(json.dumps([f.as_dict() for f in findings], indent=2))
    else:
        for f in findings:
            print(f.format())
        if not findings:
            print(f"simlint OK ({len(RULES)} rules, no findings)")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
