"""Deterministic latency statistics shared by the serving harness, the
operator CLI and the benchmark report rows.

Tail latency is the headline metric of the serving-handoff subsystem
(SHADOW's point: for serving workloads *perceived* latency matters, not
control-plane downtime), so the percentile math must be bit-reproducible
across runs and platforms: plain sorted-order linear interpolation over
float64, no numpy version-dependent quantile methods.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

# the serving benchmarks' standard tail grid
LATENCY_PERCENTILES = (50.0, 99.0, 99.9)


def percentile(values: Sequence[float], p: float) -> float:
    """Linear-interpolated percentile (the classic ``(n-1)``-rank method).

    ``p`` is in [0, 100].  Deterministic: sorted copy, rank
    ``p/100 * (n-1)``, linear interpolation between the two neighbouring
    order statistics — exactly numpy's default, but pinned here so a
    numpy method change can never silently move the reported tails.
    """
    if not 0.0 <= p <= 100.0:
        raise ValueError(f"percentile out of range: {p}")
    xs = sorted(float(v) for v in values)
    if not xs:
        raise ValueError("percentile of an empty sequence")
    if len(xs) == 1:
        return xs[0]
    rank = (p / 100.0) * (len(xs) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def percentiles(values: Sequence[float],
                ps: Sequence[float] = LATENCY_PERCENTILES
                ) -> Dict[str, float]:
    """``{"p50": ..., "p99": ..., "p999": ...}`` — key = ``p`` + the
    percentile with the decimal point dropped (99.9 -> ``p999``)."""
    out: Dict[str, float] = {}
    for p in ps:
        key = "p" + f"{p:g}".replace(".", "")
        out[key] = percentile(values, p)
    return out


def latency_summary(latencies: Sequence[float],
                    ps: Sequence[float] = LATENCY_PERCENTILES,
                    ndigits: Optional[int] = 4) -> Dict[str, float]:
    """The serving benchmarks' standard latency row: sample count, mean,
    max and the tail grid, all rounded to ``ndigits`` for stable JSON.
    Empty input yields an all-None row (a run that completed nothing
    must not crash the report)."""
    keys = ["p" + f"{p:g}".replace(".", "") for p in ps]
    if not latencies:
        row: Dict[str, float] = {"n": 0, "mean": None, "max": None}
        row.update({k: None for k in keys})
        return row
    xs = [float(v) for v in latencies]
    row = {"n": len(xs), "mean": sum(xs) / len(xs), "max": max(xs)}
    row.update(percentiles(xs, ps))
    if ndigits is not None:
        row = {k: (round(v, ndigits) if isinstance(v, float) else v)
               for k, v in row.items()}
    return row


def summarize_spans(spans: Sequence[float],
                    ndigits: int = 3) -> Dict[str, float]:
    """p50/p99 digest for benchmark aggregate rows (fleet spans, chaos
    exposure windows): the distribution shape, not just the mean."""
    if not spans:
        return {"p50": None, "p99": None}
    return {"p50": round(percentile(spans, 50.0), ndigits),
            "p99": round(percentile(spans, 99.0), ndigits)}


__all__: List[str] = ["LATENCY_PERCENTILES", "percentile", "percentiles",
                      "latency_summary", "summarize_spans"]
