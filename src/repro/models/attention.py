"""GQA attention block: params, RoPE dispatch, KV-cache management, sharding.

Two activation-sharding strategies (cfg.attn_sharding):
  * "heads": query heads sharded over the ``model`` mesh axis (requires
    num_heads % model_size == 0 — codeqwen/chatglm/granite/qwen2-vl).
  * "seq":   sequence sharded over ``model`` for train/prefill (KV gathered),
    for archs whose head counts don't divide the axis (gemma3/smollm/
    whisper/llama4/recurrentgemma/xlstm).
Decode always shards the KV cache along its sequence axis ("kv_seq" ->
model): single-token attention lowers to flash-decode partial reductions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import common
from repro.models.common import param, value_of
from repro.sharding.rules import DEFAULT_RULES, with_sharding_constraint_logical


def _act_rules(cfg):
    if cfg.attn_sharding == "seq":
        return DEFAULT_RULES.overriding(
            seq="model", act_heads=None, act_qout=None, act_kv_heads=None
        )
    return DEFAULT_RULES


def constrain(x, axes, cfg):
    return with_sharding_constraint_logical(x, axes, _act_rules(cfg))


def init_attention(key, cfg, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qdim, kvdim = cfg.num_heads * hd, cfg.num_kv_heads * hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": param(ks[0], (d, qdim), ("embed", "qout")),
        "wk": param(ks[1], (d, kvdim), ("embed", "kv_out")),
        "wv": param(ks[2], (d, kvdim), ("embed", "kv_out")),
        "wo": param(ks[3], (qdim, d), ("qout", "embed")),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = common.zeros_param((hd,), ("stats",))
        p["k_norm"] = common.zeros_param((hd,), ("stats",))
    return p


def _project_qkv(params, x, kv_x, cfg):
    """x [B,S,D] -> q [B,S,H,hd], k/v [B,Skv,Hkv,hd] (pre-RoPE)."""
    B, S, _ = x.shape
    Skv = kv_x.shape[1]
    hd = cfg.resolved_head_dim
    dt = x.dtype
    q = (x @ value_of(params["wq"]).astype(dt)).reshape(B, S, cfg.num_heads, hd)
    k = (kv_x @ value_of(params["wk"]).astype(dt)).reshape(B, Skv, cfg.num_kv_heads, hd)
    v = (kv_x @ value_of(params["wv"]).astype(dt)).reshape(B, Skv, cfg.num_kv_heads, hd)
    if cfg.use_qk_norm:
        q = common.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


def attn_forward(params, x, positions, cfg, *, local: bool = False,
                 causal: bool = True, kv_x=None, kv_positions=None):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    B, S, D = x.shape
    kv_x = x if kv_x is None else kv_x
    q, k, v = _project_qkv(params, x, kv_x, cfg)
    if kv_x is x and cfg.rope_kind != "none":
        q = common.rope_for(cfg, q, positions, local)
        k = common.rope_for(
            cfg, k, positions if kv_positions is None else kv_positions, local
        )
    q = constrain(q, ("batch", "seq", "act_heads", None), cfg)
    window = cfg.window if local else 0
    if (local and cfg.attn_sharding == "seq" and kv_x is x
            and S % max(window, 1) == 0):
        # local-window layers never need the full KV: keep K/V seq-sharded;
        # the banded attention's previous-chunk shift lowers to a neighbor
        # collective-permute (halo exchange) instead of a full all-gather
        # (§Perf cell D, EXPERIMENTS.md).
        k = constrain(k, ("batch", "seq", "act_kv_heads", None), cfg)
        v = constrain(v, ("batch", "seq", "act_kv_heads", None), cfg)
    else:
        k = constrain(k, ("batch", None, "act_kv_heads", None), cfg)
        v = constrain(v, ("batch", None, "act_kv_heads", None), cfg)
    out = ops.attention(q, k, v, causal=causal, window=window)
    out = constrain(out, ("batch", "seq", "act_heads", None), cfg)
    out = out.reshape(B, S, -1) @ value_of(params["wo"]).astype(x.dtype)
    return constrain(out, ("batch", "seq", "act_embed"), cfg)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, seq: int, *, local: bool = False,
                  dtype=None):
    """Cache for one attention layer.  Local layers keep a ring buffer of
    ``window`` slots; global layers keep the full horizon.

    ``cfg.kv_cache_dtype == "int8"`` stores blockwise-quantized K/V (one
    bf16 scale per (slot, kv-head)) — halving decode HBM traffic vs bf16
    (§Perf iteration C2)."""
    S = min(seq, cfg.window) if local else seq
    hd = cfg.resolved_head_dim
    cache = {"pos": jnp.full((batch, S), -1, jnp.int32)}
    if cfg.kv_cache_dtype == "int8":
        cache["k"] = jnp.zeros((batch, S, cfg.num_kv_heads, hd), jnp.int8)
        cache["v"] = jnp.zeros((batch, S, cfg.num_kv_heads, hd), jnp.int8)
        cache["k_scale"] = jnp.zeros((batch, S, cfg.num_kv_heads), jnp.bfloat16)
        cache["v_scale"] = jnp.zeros((batch, S, cfg.num_kv_heads), jnp.bfloat16)
    else:
        dt = dtype or jnp.dtype(cfg.kv_cache_dtype or cfg.dtype)
        cache["k"] = jnp.zeros((batch, S, cfg.num_kv_heads, hd), dt)
        cache["v"] = jnp.zeros((batch, S, cfg.num_kv_heads, hd), dt)
    return cache


def kv_cache_logical_axes(local: bool = False, quantized: bool = False):
    axes = {
        "k": ("batch", "kv_seq", "act_kv_heads", None),
        "v": ("batch", "kv_seq", "act_kv_heads", None),
        "pos": ("batch", "kv_seq"),
    }
    if quantized:
        axes["k_scale"] = ("batch", "kv_seq", "act_kv_heads")
        axes["v_scale"] = ("batch", "kv_seq", "act_kv_heads")
    return axes


def _quantize_kv(x):
    """x [..., hd] -> (int8 values, bf16 scale[...])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequantize_kv(cache, dt):
    if "k_scale" not in cache:
        return cache["k"], cache["v"]
    k = cache["k"].astype(dt) * cache["k_scale"].astype(dt)[..., None]
    v = cache["v"].astype(dt) * cache["v_scale"].astype(dt)[..., None]
    return k, v


def prefill_into_cache(params, x, positions, cfg, cache, *, local: bool):
    """Run full attention over the prompt AND populate the cache."""
    out = attn_forward(params, x, positions, cfg, local=local)
    _, k, v = _project_qkv(params, x, x, cfg)
    if cfg.rope_kind != "none":
        k = common.rope_for(cfg, k, positions, local)
    # cache slot ids are 1-D: for M-RoPE [3,B,S] the temporal component
    # (index 0) is the causality axis
    pos1d = positions[0] if positions.ndim == 3 else positions
    S_cache = cache["k"].shape[1]
    S = x.shape[1]
    quant = "k_scale" in cache
    if quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        entries = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    else:
        entries = {"k": k.astype(cache["k"].dtype),
                   "v": v.astype(cache["v"].dtype)}
    entries["pos"] = pos1d.astype(jnp.int32)
    if S >= S_cache:  # keep last S_cache positions (ring for local layers)
        sl = slice(S - S_cache, S)
        # ring convention: position p lives at slot p % S_cache (decode
        # writes there) -> roll the kept window into ring order
        shift = (S - S_cache) % S_cache
        new = {name: jnp.roll(a[:, sl], shift, axis=1).astype(cache[name].dtype)
               for name, a in entries.items()}
    else:
        new = {name: jax.lax.dynamic_update_slice_in_dim(
                   cache[name], a.astype(cache[name].dtype), 0, axis=1)
               for name, a in entries.items()}
    return out, new


def attn_append(params, x, positions, cfg, cache, *, local: bool):
    """Append a chunk of k tokens to the cache and attend over it.

    x [B,k,D]; positions [B,k] absolute.  The batched-replay path: one call
    folds k messages with parallel (MXU/BLAS-efficient) attention instead of
    k sequential decode steps.
    """
    from repro.kernels import ref as _ref

    B, K, _ = x.shape
    q, k, v = _project_qkv(params, x, x, cfg)
    if cfg.rope_kind != "none":
        q = common.rope_for(cfg, q, positions, local)
        k = common.rope_for(cfg, k, positions, local)
    S_cache = cache["k"].shape[1]
    slots = (positions % S_cache).astype(jnp.int32)  # [B,k]
    b_idx = jnp.arange(B)[:, None]
    new_cache = dict(cache)
    if "k_scale" in cache:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        new_cache["k"] = cache["k"].at[b_idx, slots].set(kq)
        new_cache["v"] = cache["v"].at[b_idx, slots].set(vq)
        new_cache["k_scale"] = cache["k_scale"].at[b_idx, slots].set(ks)
        new_cache["v_scale"] = cache["v_scale"].at[b_idx, slots].set(vs)
    else:
        new_cache["k"] = cache["k"].at[b_idx, slots].set(
            k.astype(cache["k"].dtype))
        new_cache["v"] = cache["v"].at[b_idx, slots].set(
            v.astype(cache["v"].dtype))
    new_cache["pos"] = cache["pos"].at[b_idx, slots].set(
        positions.astype(jnp.int32))
    k_pos = new_cache["pos"]
    k_all, v_all = _dequantize_kv(new_cache, x.dtype)
    out = _ref.chunk_attention(
        q, k_all, v_all, q_pos=positions, k_pos=k_pos,
        window=cfg.window if local else 0)
    out = out.reshape(B, K, -1) @ value_of(params["wo"]).astype(x.dtype)
    return out, new_cache


def attn_decode(params, x, positions, cfg, cache, *, local: bool):
    """One-token decode.  x [B,1,D]; positions [B,1] absolute positions."""
    B = x.shape[0]
    q, k, v = _project_qkv(params, x, x, cfg)
    if cfg.decode_heads_replicated:
        # flash-decode layout: q replicated over `model`, cache seq-sharded;
        # attention reduces over the sharded seq axis (partials + psum)
        q = with_sharding_constraint_logical(
            q, ("batch", None, None, None), DEFAULT_RULES)
        k = with_sharding_constraint_logical(
            k, ("batch", None, None, None), DEFAULT_RULES)
        v = with_sharding_constraint_logical(
            v, ("batch", None, None, None), DEFAULT_RULES)
    if cfg.rope_kind != "none":
        q = common.rope_for(cfg, q, positions, local)
        k = common.rope_for(cfg, k, positions, local)
    S_cache = cache["k"].shape[1]
    pos_scalar = positions[:, -1] if positions.ndim == 2 else positions[0, :, -1]
    slot = (pos_scalar % S_cache).astype(jnp.int32)  # ring for local layers
    # Per-row scatter (not one-hot multiply): decode must not rewrite the
    # whole cache — only attention *reads* it. Keeps the memory roofline
    # term at O(cache read) instead of 3x.
    b_idx = jnp.arange(B)
    new_cache = dict(cache)
    if "k_scale" in cache:
        kq, ks = _quantize_kv(k[:, 0])
        vq, vs = _quantize_kv(v[:, 0])
        new_cache["k"] = cache["k"].at[b_idx, slot].set(kq)
        new_cache["v"] = cache["v"].at[b_idx, slot].set(vq)
        new_cache["k_scale"] = cache["k_scale"].at[b_idx, slot].set(ks)
        new_cache["v_scale"] = cache["v_scale"].at[b_idx, slot].set(vs)
    else:
        new_cache["k"] = cache["k"].at[b_idx, slot].set(
            k[:, 0].astype(cache["k"].dtype))
        new_cache["v"] = cache["v"].at[b_idx, slot].set(
            v[:, 0].astype(cache["v"].dtype))
    new_cache["pos"] = cache["pos"].at[b_idx, slot].set(
        pos_scalar.astype(jnp.int32))
    k_pos = new_cache["pos"]
    if local:
        k_pos = jnp.where(pos_scalar[:, None] - k_pos < cfg.window, k_pos, -1)
    k_all, v_all = _dequantize_kv(new_cache, x.dtype)
    out = ops.decode_attention(q, k_all, v_all, pos_scalar, k_pos)
    out = out.reshape(B, 1, -1) @ value_of(params["wo"]).astype(x.dtype)
    return out, new_cache
