"""Shared layers: param leaves, initializers, norms, RoPE variants, embeddings.

No flax — params are plain pytrees.  Each leaf is created through ``param``,
which records its logical sharding axes in a parallel tree (see
``split_params``): model code stays a pure function of (params, inputs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ParamLeaf:
    """An array tagged with logical sharding axes; flattens to the array."""

    value: jnp.ndarray
    axes: Tuple[Optional[str], ...]

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)


def param(key, shape, axes, scale: Optional[float] = None, dtype=jnp.float32):
    """Truncated-normal init with fan-in scaling (scale=None) or constant std."""
    if scale is None:
        fan_in = shape[0] if len(shape) >= 1 else 1
        scale = 1.0 / np.sqrt(max(1, fan_in))
    init = scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return ParamLeaf(init.astype(dtype), tuple(axes))


def zeros_param(shape, axes, dtype=jnp.float32):
    return ParamLeaf(jnp.zeros(shape, dtype), tuple(axes))


def ones_param(shape, axes, dtype=jnp.float32):
    return ParamLeaf(jnp.ones(shape, dtype), tuple(axes))


def const_param(value, axes):
    return ParamLeaf(jnp.asarray(value), tuple(axes))


def is_param(x) -> bool:
    return isinstance(x, ParamLeaf)


def split_params(tree):
    """(ParamLeaf tree) -> (values tree, logical-axes tree)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def merge_params(values, axes):
    return jax.tree.map(lambda v, a: ParamLeaf(v, a), values, axes,
                        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, list))


def value_of(p):
    return p.value if isinstance(p, ParamLeaf) else p


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    scale = value_of(scale)
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def soft_cap(x, cap: float):
    if cap and cap > 0.0:
        return cap * jnp.tanh(x / cap)
    return x


# ---------------------------------------------------------------------------
# rotary position embeddings (default / partial / M-RoPE)
# ---------------------------------------------------------------------------

def _rope_angles(positions, dim: int, theta: float):
    """positions [..., S] -> cos/sin [..., S, dim/2] (fp32)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float = 10_000.0, fraction: float = 1.0):
    """x [B,S,H,D]; positions [B,S].  ``fraction`` < 1 rotates only the first
    ``fraction*D`` dims (chatglm-style partial / "2d" rope)."""
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    cos, sin = _rope_angles(positions, rot, theta)  # [B,S,rot/2]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


def apply_mrope(x, positions_thw, sections: Tuple[int, int, int], theta: float):
    """Qwen2-VL multimodal RoPE.

    x [B,S,H,D]; positions_thw [3,B,S] (temporal, height, width ids).  The
    D/2 frequency slots are split into ``sections`` (t,h,w); each section
    takes its angle from the corresponding position component.  For pure-text
    tokens the three ids are equal, reducing to standard RoPE.
    """
    d = x.shape[-1]
    half = d // 2
    secs = np.array(sections, dtype=np.int64)
    secs = (secs * half // secs.sum()).tolist()
    secs[-1] = half - sum(secs[:-1])
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    # select which position component feeds each frequency slot
    comp = jnp.repeat(jnp.arange(3), jnp.array(secs), total_repeat_length=half)  # [half]
    onehot = jax.nn.one_hot(comp, 3, dtype=jnp.float32)  # [half,3]
    # pos_for_slot [B,S,half] = sum_c onehot[half,c] * positions[c,B,S]
    pos_slot = jnp.einsum("kc,cbs->bsk", onehot, positions_thw.astype(jnp.float32))
    ang = pos_slot * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def rope_for(cfg, x, positions, local: bool):
    """Dispatch on cfg.rope_kind.  ``positions`` is [B,S] or [3,B,S] (mrope)."""
    if cfg.rope_kind == "none":
        return x
    if cfg.rope_kind == "mrope":
        if positions.ndim == 2:  # text-only fallback: t=h=w
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return apply_mrope(x, positions, cfg.mrope_sections, cfg.rope_theta)
    theta = cfg.rope_theta_local if local else cfg.rope_theta
    frac = cfg.rope_fraction if cfg.rope_kind == "partial" else 1.0
    return apply_rope(x, positions, theta, frac)


def sinusoidal_positions(seq: int, dim: int):
    pos = np.arange(seq)[:, None]
    i = np.arange(dim // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * i / dim)
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), dtype=jnp.float32
    )


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, cfg):
    return {
        "table": param(
            key, (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), scale=0.02
        )
    }


def embed(params, ids, cfg):
    table = value_of(params["table"]).astype(cfg.compute_dtype)
    x = jnp.take(table, ids, axis=0)
    return x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))


def unembed(params, x, cfg, table=None):
    """Logits via the (tied) embedding table or a dedicated head."""
    t = value_of(table if table is not None else params["table"])
    return jnp.einsum("bsd,vd->bsv", x, t.astype(x.dtype))
