"""Mixture-of-Experts FFN with capacity-bounded sort-based dispatch.

Design goals (in roofline order):
  1. HLO FLOPs must track *active* parameters — so dispatch/combine are
     gathers/scatters (byte traffic, ~zero FLOPs), and expert compute is a
     single [E,C,D]x[E,D,F] batched einsum whose FLOPs = capacity-bounded
     active compute.  The dense one-hot-einsum dispatch used by early
     Switch implementations costs O(T^2 D) FLOPs and would poison the
     MODEL_FLOPS/HLO_FLOPs ratio.
  2. Experts shard over the ``model`` mesh axis (expert parallelism); token
     buffers get an explicit sharding constraint so dispatch lowers to an
     all-to-all-shaped exchange rather than full replication.

Routing: top-k softmax gating with a Switch-style load-balancing auxiliary
loss and capacity factor; overflowing tokens drop (their residual passes
through — standard behaviour).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import param, value_of
from repro.models import mlp as _mlp
from repro.sharding.rules import with_sharding_constraint_logical as constrain


def init_moe(key, cfg):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": param(ks[0], (d, E), ("embed", "experts"), scale=0.02),
        "w_gate": param(ks[1], (E, d, ff), ("experts", "embed", "expert_mlp")),
        "w_up": param(ks[2], (E, d, ff), ("experts", "embed", "expert_mlp")),
        "w_down": param(ks[3], (E, ff, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.shared_expert:
        p["shared"] = _mlp.init_mlp(ks[4], cfg)
    return p


def expert_capacity(cfg, tokens: int) -> int:
    cap = int(tokens * cfg.num_experts_per_tok * cfg.capacity_factor
              / cfg.num_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8


def moe_forward(params, x, cfg):
    """x [B,S,D] -> (out [B,S,D], aux_loss scalar)."""
    if cfg.moe_routing == "local":
        return _moe_forward_local(params, x, cfg)
    return _moe_forward_global(params, x, cfg)


def _expert_axes(cfg):
    return "act_experts" if cfg.expert_sharding == "model" else None


def _router(params, xf, cfg):
    """shared: logits/top-k/aux over a flat token dim (batched or global)."""
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    logits = (xf @ value_of(params["router"]).astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_ids = jax.lax.top_k(probs, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    one_hot_top1 = jax.nn.one_hot(gate_ids[..., 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(
        jnp.mean(one_hot_top1.reshape(-1, E), 0)
        * jnp.mean(probs.reshape(-1, E), 0))
    aux = aux + 1e-3 * jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)
    return gate_w, gate_ids, aux


def _moe_forward_local(params, x, cfg):
    """Grouped local routing, formulated scatter-free.

    Every bookkeeping op is batched over the batch-row axis (sharded over
    `data`) and is either a local sort or a ``take_along_axis`` gather —
    the only batched-index forms the SPMD partitioner keeps collective-free
    (measured: advanced-index gathers and every scatter form insert
    all-gathers/all-reduces/permute pipelines; see EXPERIMENTS.md §Perf A).

      dispatch: entries sorted by expert are contiguous runs; slot (e,c)
                reads entry ``starts[e]+c`` — a gather, not a scatter.
      combine:  un-sort by the inverse permutation and sum the K expert
                contributions per token — reshape+sum, not a scatter.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    C = expert_capacity(cfg, S)  # per-row capacity
    dt = x.dtype
    eax = _expert_axes(cfg)

    gate_w, gate_ids, aux = _router(params, x, cfg)  # [B,S,K]

    flat_e = gate_ids.reshape(B, S * K)
    order = jnp.argsort(flat_e, axis=-1, stable=True)  # per-row sort: local
    inv_order = jnp.argsort(order, axis=-1)  # inverse permutation
    e_s = jnp.take_along_axis(flat_e, order, axis=-1)
    t_s = order // K  # token id of sorted entry (entries are token-major)

    # run starts per expert: starts[b,e] = first sorted index of expert e
    starts = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E), side="left"))(e_s)

    # ---- dispatch as a gather: slot (e,c) <- sorted entry starts[e]+c ----
    src = (starts[:, :, None] + jnp.arange(C)[None, None, :])  # [B,E,C]
    src_flat = src.reshape(B, E * C)
    in_range = src_flat < S * K
    src_safe = jnp.minimum(src_flat, S * K - 1)
    e_at_src = jnp.take_along_axis(e_s, src_safe, axis=1)
    hit = in_range & (e_at_src == (jnp.arange(E * C)[None] // C))
    tok = jnp.take_along_axis(t_s, src_safe, axis=1)  # [B,E*C]
    gathered = jnp.take_along_axis(
        x, jnp.where(hit, tok, 0)[..., None], axis=1)
    expert_in = (gathered * hit[..., None].astype(dt)).reshape(B, E, C, D)
    expert_in = constrain(expert_in, ("batch", eax, None, None))

    wg = value_of(params["w_gate"]).astype(dt)
    wu = value_of(params["w_up"]).astype(dt)
    wd = value_of(params["w_down"]).astype(dt)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", expert_in, wg))
    h = h * jnp.einsum("becd,edf->becf", expert_in, wu)
    h = constrain(h, ("batch", eax, None, None))
    expert_out = jnp.einsum("becf,efd->becd", h, wd)
    expert_out = constrain(expert_out, ("batch", eax, None, None))

    # ---- combine as a gather: sorted entry i sits at slot e_s*C + rank ----
    flat_out = expert_out.reshape(B, E * C, D)
    rank = jnp.arange(S * K)[None] - jnp.take_along_axis(starts, e_s, axis=1)
    kept = rank < C  # capacity overflow drops (token keeps its residual)
    slot = jnp.where(kept, e_s * C + rank, 0)
    per_entry = jnp.take_along_axis(flat_out, slot[..., None], axis=1)
    per_entry = per_entry * kept[..., None].astype(dt)
    # un-sort back to token-major order and fold the K contributions
    unsorted = jnp.take_along_axis(per_entry, inv_order[..., None], axis=1)
    w = gate_w.reshape(B, S, K).astype(dt)
    out = jnp.einsum("bskd,bsk->bsd", unsorted.reshape(B, S, K, D), w)

    if cfg.shared_expert:
        out = out + _mlp.mlp_forward(params["shared"], x, cfg)
    return constrain(out, ("batch", "seq", "act_embed")), aux


def _moe_forward_global(params, x, cfg):
    """Baseline: one global token pool (global sort/scatter)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    C = expert_capacity(cfg, T)
    dt = x.dtype
    xf = x.reshape(T, D)

    logits = (xf @ value_of(params["router"]).astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [T,E]
    gate_w, gate_ids = jax.lax.top_k(probs, K)  # [T,K]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Switch load-balance loss: E * mean(frac_tokens_e * mean_prob_e)
    one_hot_top1 = jax.nn.one_hot(gate_ids[:, 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(jnp.mean(one_hot_top1, 0) * jnp.mean(probs, 0))
    # router z-loss (stabilizes logits)
    aux = aux + 1e-3 * jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)

    # ---- sort-based dispatch ----
    flat_e = gate_ids.reshape(-1)  # [T*K]
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_w = gate_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    e_s, t_s, w_s = flat_e[order], flat_t[order], flat_w[order]
    seg_start = jnp.searchsorted(e_s, e_s, side="left")
    pos = jnp.arange(T * K) - seg_start  # rank within expert
    keep = pos < C
    slot = jnp.where(keep, e_s * C + pos, E * C)  # E*C = dump slot

    gathered = jnp.take(xf, t_s, axis=0) * keep[:, None].astype(dt)  # [T*K, D]
    buf = jnp.zeros((E * C + 1, D), dt).at[slot].add(gathered)
    expert_in = buf[: E * C].reshape(E, C, D)
    expert_in = constrain(expert_in, ("act_experts", None, None))

    wg = value_of(params["w_gate"]).astype(dt)
    wu = value_of(params["w_up"]).astype(dt)
    wd = value_of(params["w_down"]).astype(dt)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, wg))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, wu)
    h = constrain(h, ("act_experts", None, None))
    expert_out = jnp.einsum("ecf,efd->ecd", h, wd)
    expert_out = constrain(expert_out, ("act_experts", None, None))

    # ---- combine ----
    flat_out = expert_out.reshape(E * C, D)
    vals = jnp.take(flat_out, jnp.minimum(slot, E * C - 1), axis=0)
    vals = vals * (w_s * keep).astype(dt)[:, None]
    out = jnp.zeros((T, D), dt).at[t_s].add(vals)

    if cfg.shared_expert:
        out = out + _mlp.mlp_forward(params["shared"], x, cfg).reshape(T, D)
    out = out.reshape(B, S, D)
    return constrain(out, ("batch", "seq", "act_embed")), aux
