"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix-memory, parallelizable)
and sLSTM (scalar-memory, strictly sequential) — both with O(1) decode state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models.common import param, value_of, zeros_param, rms_norm
from repro.sharding.rules import with_sharding_constraint_logical as constrain


# ---------------------------------------------------------------------------
# mLSTM block (pre-up-projection, factor 2)
# ---------------------------------------------------------------------------

def _inner(cfg):
    return 2 * cfg.d_model


def init_mlstm_block(key, cfg):
    d = cfg.d_model
    m = _inner(cfg)
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": param(ks[0], (d, m), ("embed", "rec_width")),
        "w_gate": param(ks[1], (d, m), ("embed", "rec_width")),
        "wq": param(ks[2], (m, m), ("rec_width", "qout")),
        "wk": param(ks[3], (m, m), ("rec_width", "qout")),
        "wv": param(ks[4], (m, m), ("rec_width", "qout")),
        "w_if": param(ks[5], (m, 2 * H), ("rec_width", None), scale=0.02),
        "b_if": zeros_param((2 * H,), (None,)),
        "out_norm": zeros_param((m // H,), ("stats",)),
        "w_down": param(ks[6], (m, d), ("rec_width", "embed")),
    }


def _mlstm_qkv(params, u, cfg):
    B, S, m = u.shape
    H = cfg.num_heads
    hd = m // H
    dt = u.dtype
    q = (u @ value_of(params["wq"]).astype(dt)).reshape(B, S, H, hd)
    k = (u @ value_of(params["wk"]).astype(dt)).reshape(B, S, H, hd)
    v = (u @ value_of(params["wv"]).astype(dt)).reshape(B, S, H, hd)
    if_g = u @ value_of(params["w_if"]).astype(dt) + value_of(params["b_if"]).astype(dt)
    i_gate, f_gate = jnp.split(if_g.astype(jnp.float32), 2, axis=-1)  # [B,S,H]
    f_gate = f_gate + 3.0  # forget-gate bias init: remember by default
    return q, k, v, i_gate, f_gate


def mlstm_block_forward(params, x, cfg, state=None):
    """x [B,S,D] -> (out, new_state (C,n,m))."""
    dt = x.dtype
    H = cfg.num_heads
    gate = jax.nn.silu(x @ value_of(params["w_gate"]).astype(dt))
    u = x @ value_of(params["w_up"]).astype(dt)
    u = constrain(u, ("batch", "seq", "rec_width"))
    q, k, v, ig, fg = _mlstm_qkv(params, u, cfg)
    hs, new_state = ops.mlstm_scan(q, k, v, ig, fg, state)
    hs = rms_norm(hs, params["out_norm"], cfg.norm_eps)  # per-head norm
    hs = hs.reshape(x.shape[0], x.shape[1], -1).astype(dt)
    out = (hs * gate) @ value_of(params["w_down"]).astype(dt)
    return constrain(out, ("batch", "seq", "act_embed")), new_state


def mlstm_decode_step(params, x, cfg, state):
    dt = x.dtype
    gate = jax.nn.silu(x @ value_of(params["w_gate"]).astype(dt))
    u = x @ value_of(params["w_up"]).astype(dt)
    q, k, v, ig, fg = _mlstm_qkv(params, u, cfg)
    new_state, h = ref.mlstm_decode_step(
        state, q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0]
    )
    h = rms_norm(h[:, None], params["out_norm"], cfg.norm_eps)
    h = h.reshape(x.shape[0], 1, -1).astype(dt)
    out = (h * gate) @ value_of(params["w_down"]).astype(dt)
    return out, new_state


def init_mlstm_state(cfg, batch: int):
    m = _inner(cfg)
    H = cfg.num_heads
    hd = m // H
    return (
        jnp.zeros((batch, H, hd, hd), jnp.float32),
        jnp.zeros((batch, H, hd), jnp.float32),
        jnp.full((batch, H), ref.NEG_INF, jnp.float32),
    )


def mlstm_state_logical_axes():
    return (
        ("batch", "act_kv_heads", "rec_width", None),
        ("batch", "act_kv_heads", "rec_width"),
        ("batch", "act_kv_heads"),
    )


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def init_slstm_block(key, cfg):
    d = cfg.d_model
    H = cfg.num_heads
    hb = d // H
    ks = jax.random.split(key, 10)
    p = {"w_out": param(ks[8], (d, d), ("rec_width", "embed"))}
    for i, g in enumerate(("i", "f", "z", "o")):
        p[f"w_{g}"] = param(ks[i], (d, d), ("embed", "rec_width"))
        # block-diagonal per-head recurrence (xLSTM §2.2)
        p[f"r_{g}"] = param(ks[4 + i], (H, hb, hb),
                            ("act_kv_heads", None, "rec_width"), scale=0.02)
        p[f"b_{g}"] = zeros_param((d,), ("rec_width",))
    return p


def _slstm_inputs(params, x):
    dt = x.dtype
    pre = {}
    for g in ("i", "f", "z", "o"):
        pre[g] = x @ value_of(params[f"w_{g}"]).astype(dt) + value_of(params[f"b_{g}"]).astype(dt)
    return pre


def slstm_block_forward(params, x, cfg, state=None):
    pre = _slstm_inputs(params, x)
    hs, new_state = ops.slstm_scan(
        pre["i"], pre["f"], pre["z"], pre["o"],
        value_of(params["r_i"]), value_of(params["r_f"]),
        value_of(params["r_z"]), value_of(params["r_o"]), state,
    )
    out = hs.astype(x.dtype) @ value_of(params["w_out"]).astype(x.dtype)
    return constrain(out, ("batch", "seq", "act_embed")), new_state


def slstm_decode_step(params, x, cfg, state):
    out, new_state = slstm_block_forward(params, x, cfg, state)
    return out, new_state


def init_slstm_state(cfg, batch: int):
    d = cfg.d_model
    return (
        jnp.zeros((batch, d), jnp.float32),
        jnp.ones((batch, d), jnp.float32),
        jnp.zeros((batch, d), jnp.float32),
        jnp.zeros((batch, d), jnp.float32),
    )


def slstm_state_logical_axes():
    ax = ("batch", "rec_width")
    return (ax, ax, ax, ax)
