"""Griffin/recurrentgemma recurrent block: gated branch + temporal conv1d +
RG-LRU (arXiv:2402.19427 fig. 2).  State is O(1) in sequence length — the
architecture family for which MS2M migration is checkpoint-dominant (tiny
replay log contribution per message).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.common import param, value_of, zeros_param
from repro.sharding.rules import with_sharding_constraint_logical as constrain


def init_rglru_block(key, cfg):
    d = cfg.d_model
    w = cfg.rglru_width or d
    H = cfg.num_heads
    hb = w // H  # block-diagonal gate head width
    ks = jax.random.split(key, 8)
    return {
        "w_x": param(ks[0], (d, w), ("embed", "rec_width")),
        "w_gate_branch": param(ks[1], (d, w), ("embed", "rec_width")),
        "conv_w": param(ks[2], (cfg.conv1d_width, w), ("conv", "rec_width"), scale=0.1),
        "conv_b": zeros_param((w,), ("rec_width",)),
        # block-diagonal input/recurrence gates (per-head [hb, hb])
        "gate_a_w": param(ks[3], (H, hb, hb), ("act_kv_heads", None, "rec_width")),
        "gate_x_w": param(ks[4], (H, hb, hb), ("act_kv_heads", None, "rec_width")),
        "gate_a_b": zeros_param((w,), ("rec_width",)),
        "gate_x_b": zeros_param((w,), ("rec_width",)),
        # a-parameter initialized so a = sigmoid(Λ) spans ~[0.9, 0.999]
        "a_param": param(ks[5], (w,), ("rec_width",), scale=0.5),
        "w_out": param(ks[6], (w, d), ("rec_width", "embed")),
    }


def _conv1d(x, w, b, state=None):
    """Causal depthwise conv over time.  x [B,S,W]; w [K,W]; state [B,K-1,W]."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+K-1, W]
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, -(K - 1) :] if K > 1 else None
    return out + b[None, None, :], new_state


def _block_gates(params, x, cfg):
    """Block-diagonal gate projections. x [B,S,W] -> (gate_a, gate_x)."""
    H = cfg.num_heads
    B, S, W = x.shape
    hb = W // H
    xh = x.reshape(B, S, H, hb)
    ga = jnp.einsum("bshi,hij->bshj", xh, value_of(params["gate_a_w"]).astype(x.dtype))
    gx = jnp.einsum("bshi,hij->bshj", xh, value_of(params["gate_x_w"]).astype(x.dtype))
    ga = ga.reshape(B, S, W) + value_of(params["gate_a_b"]).astype(x.dtype)
    gx = gx.reshape(B, S, W) + value_of(params["gate_x_b"]).astype(x.dtype)
    return ga, gx


def rglru_block_forward(params, x, cfg, state=None):
    """x [B,S,D] -> (out [B,S,D], new_state {h, conv}).

    state: {"h": [B,W] f32, "conv": [B,K-1,W]} or None (zeros).
    """
    dt = x.dtype
    gate_branch = jax.nn.gelu(x @ value_of(params["w_gate_branch"]).astype(dt))
    u = x @ value_of(params["w_x"]).astype(dt)
    u = constrain(u, ("batch", "seq", "rec_width"))
    conv_state = None if state is None else state["conv"]
    u, new_conv = _conv1d(u, value_of(params["conv_w"]).astype(dt),
                          value_of(params["conv_b"]).astype(dt), conv_state)
    ga, gx = _block_gates(params, u, cfg)
    h0 = None if state is None else state["h"]
    hs, h_last = ops.rglru_scan(u, value_of(params["a_param"]), ga, gx, h0)
    hs = constrain(hs, ("batch", "seq", "rec_width"))
    out = (hs * gate_branch) @ value_of(params["w_out"]).astype(dt)
    new_state = {"h": h_last, "conv": new_conv}
    return constrain(out, ("batch", "seq", "act_embed")), new_state


def rglru_decode_step(params, x, cfg, state):
    """x [B,1,D] -> (out [B,1,D], new_state)."""
    from repro.kernels import ref as _ref

    dt = x.dtype
    gate_branch = jax.nn.gelu(x @ value_of(params["w_gate_branch"]).astype(dt))
    u = x @ value_of(params["w_x"]).astype(dt)
    u, new_conv = _conv1d(u, value_of(params["conv_w"]).astype(dt),
                          value_of(params["conv_b"]).astype(dt), state["conv"])
    ga, gx = _block_gates(params, u, cfg)
    h = _ref.rglru_decode_step(
        state["h"], u[:, 0], value_of(params["a_param"]), ga[:, 0], gx[:, 0]
    )
    out = (h[:, None, :].astype(dt) * gate_branch) @ value_of(params["w_out"]).astype(dt)
    return out, {"h": h, "conv": new_conv}


def init_rglru_state(cfg, batch: int):
    w = cfg.rglru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), jnp.float32),
    }


def rglru_state_logical_axes():
    return {"h": ("batch", "rec_width"), "conv": ("batch", None, "rec_width")}
