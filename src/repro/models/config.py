"""Unified model configuration covering all ten assigned architectures.

One dataclass, one decoder implementation: every architecture is expressed as
a repeating *layer pattern* (a tuple of (mixer, ffn) block kinds) that the
decoder scans over.  E.g. gemma3 is 5x(local attention, mlp) + 1x(global
attention, mlp); recurrentgemma is 2x(RG-LRU, mlp) + 1x(local attention, mlp);
llama4 alternates dense and MoE FFNs; xlstm is 7x mLSTM + 1x sLSTM.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Tuple

import jax.numpy as jnp


class BlockKind(str, enum.Enum):
    # sequence mixers
    ATTN_GLOBAL = "attn_global"
    ATTN_LOCAL = "attn_local"
    RGLRU = "rglru"
    MLSTM = "mlstm"
    SLSTM = "slstm"
    # ffns
    MLP = "mlp"
    MOE = "moe"
    NONE = "none"


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # repeating block pattern: tuple of (mixer_kind, ffn_kind)
    pattern: Tuple[Tuple[BlockKind, BlockKind], ...] = (
        (BlockKind.ATTN_GLOBAL, BlockKind.MLP),
    )
    window: int = 4_096  # local-attention window
    # rope
    rope_kind: str = "default"  # default | partial (chatglm 2d) | mrope | none
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0  # gemma3 uses a different theta locally
    rope_fraction: float = 1.0  # chatglm applies rope to half the head dim
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w splits (pairs)
    # moe
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    shared_expert: bool = False
    router_aux_coef: float = 0.01
    # "local": per-batch-row routing (sort/gather/scatter stay data-shard
    #   local; the dispatch crosses shards only through the expert einsum).
    # "global": single global token pool (baseline; its sharded sort/scatter
    #   lower to full-token-buffer collectives — see EXPERIMENTS.md §Perf).
    moe_routing: str = "local"
    # "model": expert parallelism (weights sharded over the model axis);
    # "replicated": experts replicated (right call for small MoEs like
    #   granite, where EP dispatch is inherently ICI-bound).
    expert_sharding: str = "model"
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1_500  # whisper: 30 s of audio -> 1500 frames
    # modality frontend stubs
    frontend: str = "none"  # none | audio_frames | image_patches
    num_patches: int = 0  # vlm: patch embeddings per request
    # recurrent dims
    rglru_width: int = 0  # 0 -> d_model
    conv1d_width: int = 4  # griffin temporal conv
    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    logits_softcap: float = 0.0  # gemma-style soft capping
    attn_sharding: str = "heads"  # heads | seq (activation strategy)
    use_qk_norm: bool = False  # gemma3-style
    mlp_gated: bool = True  # SwiGLU (False -> plain gelu MLP, whisper-style)
    mlp_act: str = "silu"  # silu | gelu
    dtype: str = "bfloat16"
    kv_cache_dtype: str = ""  # "" -> compute dtype; "int8" -> quantized KV
    # decode-time q-head placement: replicating heads keeps single-token
    # attention local to the seq-sharded cache (flash-decode); sharding
    # them forces a per-layer cache all-gather (§Perf C1).
    decode_heads_replicated: bool = False
    # long-context applicability: True iff decode state is O(window) not O(seq)
    subquadratic: bool = False

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def group_size(self) -> int:
        return len(self.pattern)

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.group_size == 0, (
            f"{self.name}: {self.num_layers} layers not divisible by pattern "
            f"of {self.group_size}"
        )
        return self.num_layers // self.group_size

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 2048 so `vocab -> model(16)` shards."""
        return math.ceil(self.vocab_size / 2048) * 2048

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    # ---- analytic parameter counts (for MODEL_FLOPS = 6*N*D roofline) ----
    def param_count(self, active: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        qdim = self.num_heads * hd
        kvdim = self.num_kv_heads * hd
        n = 0
        counted_layers = 0
        for mixer, ffn in self.pattern:
            if mixer in (BlockKind.ATTN_GLOBAL, BlockKind.ATTN_LOCAL):
                n += d * qdim + 2 * d * kvdim + qdim * d
            elif mixer == BlockKind.RGLRU:
                w = self.rglru_width or d
                # in/out proj (x and gate branches) + conv + gates + recurrent
                n += 2 * d * w + w * d + self.conv1d_width * w + 2 * w * (w // max(1, self.num_heads)) + 2 * w
            elif mixer == BlockKind.MLSTM:
                # up-proj + gate, qkv at inner dim m=2d, i/f gates, down-proj
                m = 2 * d
                n += 2 * d * m + 3 * m * m + m * 2 * self.num_heads + m * d
            elif mixer == BlockKind.SLSTM:
                hb = d // max(1, self.num_heads)
                # 4 input projections + 4 block-diagonal recurrences + out
                n += 4 * d * d + 4 * d * hb + d * d
            if ffn == BlockKind.MLP:
                n += 3 * d * self.d_ff
            elif ffn == BlockKind.MOE:
                per_expert = 3 * d * self.d_ff
                if active:
                    k = self.num_experts_per_tok + (1 if self.shared_expert else 0)
                    n += k * per_expert + d * self.num_experts
                else:
                    n += self.num_experts * per_expert + d * self.num_experts
                    if self.shared_expert:
                        n += per_expert
            n += 2 * d  # the two rmsnorm scales
            counted_layers += 1
        n = n * (self.num_layers // counted_layers)
        n += self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        if self.is_encoder_decoder:
            # encoder self-attn + mlp + cross-attn params in decoder
            enc = self.num_encoder_layers * (d * qdim + 2 * d * kvdim + qdim * d + 2 * d * self.d_ff + 2 * d)
            cross = self.num_layers * (d * qdim + 2 * d * kvdim + qdim * d + d)
            n += enc + cross
        return int(n)

    def model_flops(self, tokens: int, active: bool = True) -> float:
        """MODEL_FLOPS = 6 * N(_active) * D  (D = tokens processed)."""
        return 6.0 * self.param_count(active=active) * tokens

    def runnable(self, shape: ShapeSpec) -> bool:
        if shape.name == "long_500k" and not self.subquadratic:
            return False
        return True
