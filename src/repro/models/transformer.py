"""Unified LM covering all ten architectures.

The decoder is a ``lax.scan`` over *layer groups* — one group is the
architecture's repeating pattern (e.g. gemma3's 5 local + 1 global) — so an
80-layer model compiles one group body once.  Per-group params/caches are
stacked along a leading ``layers`` axis.

Entry points:
  init_lm / lm_forward / lm_loss              — training
  init_cache / lm_prefill / lm_decode_step    — serving
All are pure functions of (params, inputs); caches are explicit pytrees —
which is exactly what makes MS2M replay bit-exact (core/replay.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, common, mlp, moe, rglru, xlstm
from repro.models.config import BlockKind, ModelConfig
from repro.models.common import ParamLeaf, param, value_of, zeros_param
from repro.sharding.rules import with_sharding_constraint_logical as constrain

MIXERS_WITH_KV = (BlockKind.ATTN_GLOBAL, BlockKind.ATTN_LOCAL)


def _scan_or_unroll(body, x, xs, unroll: bool):
    """lax.scan, or an inlined python loop for cost-calibration lowers."""
    if not unroll:
        return jax.lax.scan(body, x, xs)
    G = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for gi in range(G):
        x, y = body(x, jax.tree.map(lambda a: a[gi], xs))
        ys.append(y)
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return x, stacked


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg, mixer: BlockKind, ffn: BlockKind, cross: bool):
    ks = jax.random.split(key, 5)
    blk: Dict[str, Any] = {"norm1": zeros_param((cfg.d_model,), ("embed",))}
    if mixer in MIXERS_WITH_KV:
        blk["attn"] = attention.init_attention(ks[0], cfg)
    elif mixer == BlockKind.RGLRU:
        blk["rglru"] = rglru.init_rglru_block(ks[0], cfg)
    elif mixer == BlockKind.MLSTM:
        blk["mlstm"] = xlstm.init_mlstm_block(ks[0], cfg)
    elif mixer == BlockKind.SLSTM:
        blk["slstm"] = xlstm.init_slstm_block(ks[0], cfg)
    if cross:
        blk["cross_attn"] = attention.init_attention(ks[3], cfg, cross=True)
        blk["norm_cross"] = zeros_param((cfg.d_model,), ("embed",))
    if ffn == BlockKind.MLP:
        blk["norm2"] = zeros_param((cfg.d_model,), ("embed",))
        blk["mlp"] = mlp.init_mlp(ks[1], cfg)
    elif ffn == BlockKind.MOE:
        blk["norm2"] = zeros_param((cfg.d_model,), ("embed",))
        blk["moe"] = moe.init_moe(ks[2], cfg)
    return blk


def _stack_layers(tree):
    """Prefix every ParamLeaf's logical axes with 'layers' (post-vmap)."""
    return jax.tree.map(
        lambda p: ParamLeaf(p.value, ("layers",) + p.axes),
        tree, is_leaf=common.is_param,
    )


def _init_groups(key, cfg, n_groups: int, cross: bool = False):
    """Stacked per-position block params: {'b0': stacked, 'b1': ...}."""
    groups = {}
    for i, (mixer, ffn) in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(key, i), n_groups)
        stacked = jax.vmap(
            lambda k: _init_block(k, cfg, mixer, ffn, cross)
        )(keys)
        groups[f"b{i}"] = _stack_layers(stacked)
    return groups


def init_lm(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": common.init_embedding(ks[0], cfg),
        "groups": _init_groups(ks[1], cfg, cfg.num_groups,
                               cross=cfg.is_encoder_decoder),
        "final_norm": zeros_param((cfg.d_model,), ("embed",)),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = param(
            ks[2], (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), scale=0.02
        )
    if cfg.is_encoder_decoder:
        enc_cfg = cfg  # same dims; whisper enc/dec share d_model
        assert cfg.num_encoder_layers % 1 == 0
        params["encoder"] = {
            "groups": _init_groups(ks[3], enc_cfg, cfg.num_encoder_layers),
            "final_norm": zeros_param((cfg.d_model,), ("embed",)),
        }
        params["dec_pos_embed"] = param(
            ks[4], (8192, cfg.d_model), (None, "embed"), scale=0.02
        )  # learned decoder positions (whisper), capped at 8192 and tiled
    if cfg.frontend == "image_patches":
        params["patch_adapter"] = param(
            ks[5], (cfg.d_model, cfg.d_model), ("embed", "act_embed"), scale=0.02
        )
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _apply_block(blk, x, positions, cfg, i: int, *, enc_out=None,
                 causal: bool = True):
    """Full-sequence (train/prefill-without-cache) block application."""
    mixer, ffn = cfg.pattern[i]
    aux = jnp.zeros((), jnp.float32)
    h = common.rms_norm(x, blk["norm1"], cfg.norm_eps)
    if mixer in MIXERS_WITH_KV:
        local = mixer == BlockKind.ATTN_LOCAL
        y = attention.attn_forward(blk["attn"], h, positions, cfg,
                                   local=local, causal=causal)
    elif mixer == BlockKind.RGLRU:
        y, _ = rglru.rglru_block_forward(blk["rglru"], h, cfg)
    elif mixer == BlockKind.MLSTM:
        y, _ = xlstm.mlstm_block_forward(blk["mlstm"], h, cfg)
    elif mixer == BlockKind.SLSTM:
        y, _ = xlstm.slstm_block_forward(blk["slstm"], h, cfg)
    else:
        raise ValueError(mixer)
    x = x + y
    if enc_out is not None and "cross_attn" in blk:
        h = common.rms_norm(x, blk["norm_cross"], cfg.norm_eps)
        y = attention.attn_forward(blk["cross_attn"], h, positions, cfg,
                                   causal=False, kv_x=enc_out)
        x = x + y
    if ffn == BlockKind.MLP:
        h = common.rms_norm(x, blk["norm2"], cfg.norm_eps)
        x = x + mlp.mlp_forward(blk["mlp"], h, cfg)
    elif ffn == BlockKind.MOE:
        h = common.rms_norm(x, blk["norm2"], cfg.norm_eps)
        y, aux = moe.moe_forward(blk["moe"], h, cfg)
        x = x + y
    return x, aux


def _remat_wrap(fn, remat: str):
    if remat == "none":
        return fn
    policy = {
        "full": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[remat]
    return jax.checkpoint(fn, policy=policy)


def _run_groups(groups, x, positions, cfg, *, enc_out=None, causal=True,
                remat: str = "none", n_positions: Optional[int] = None,
                unroll: bool = False):
    """Scan the stacked group params over the activations.

    ``unroll=True`` applies the groups as an inlined python loop instead of
    ``lax.scan`` — used by the dry-run's cost-calibration lowers (XLA cost
    analysis counts a while-loop body once, so per-layer costs are derived
    from small unrolled variants; see launch/dryrun.py).
    """
    npos = n_positions or len(cfg.pattern)

    def body(carry, group_params):
        x, aux = carry

        def inner(x):
            a = jnp.zeros((), jnp.float32)
            for i in range(npos):
                x, ai = _apply_block(group_params[f"b{i}"], x, positions, cfg,
                                     i, enc_out=enc_out, causal=causal)
                a = a + ai
            return x, a

        x, a = _remat_wrap(inner, remat)(x)
        return (x, aux + a), None

    carry = (x, jnp.zeros((), jnp.float32))
    if unroll:
        G = jax.tree.leaves(groups)[0].shape[0]
        for gi in range(G):
            gp = jax.tree.map(lambda a: a[gi], groups)
            carry, _ = body(carry, gp)
        x, aux = carry
    else:
        (x, aux), _ = jax.lax.scan(body, carry, groups)
    return x, aux


# ---------------------------------------------------------------------------
# forward / loss (train)
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg):
    """tokens (+ stub modality embeddings) -> x [B,S,D], positions."""
    x = common.embed(params["embed"], batch["tokens"], cfg)
    positions = batch.get("positions")
    if positions is None:
        B, S = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.frontend == "image_patches" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        pe = pe @ value_of(params["patch_adapter"]).astype(x.dtype)
        P = pe.shape[1]
        x = jax.lax.dynamic_update_slice_in_dim(x, pe, 1, axis=1)
    return constrain(x, ("batch", "seq", "act_embed")), positions


def _encode(params, batch, cfg, unroll: bool = False):
    """Whisper encoder over precomputed (stub) frame embeddings."""
    frames = batch["frames"].astype(cfg.compute_dtype)  # [B, F, D]
    F = frames.shape[1]
    pos = common.sinusoidal_positions(F, cfg.d_model).astype(frames.dtype)
    x = frames + pos[None]
    x = constrain(x, ("batch", "seq", "act_embed"))
    B = frames.shape[0]
    positions = jnp.broadcast_to(jnp.arange(F)[None], (B, F))
    enc = params["encoder"]
    x, _ = _run_groups(enc["groups"], x, positions, cfg, causal=False,
                       unroll=unroll)
    return common.rms_norm(x, enc["final_norm"], cfg.norm_eps)


def _logits(params, x, cfg):
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = (params["unembed"] if "unembed" in params
             else params["embed"]["table"])
    logits = jnp.einsum(
        "bsd,vd->bsv", x, value_of(table).astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    logits = common.soft_cap(logits, cfg.logits_softcap)
    return constrain(logits, ("batch", None, "act_vocab"))


def lm_forward(params, batch, cfg: ModelConfig, *, remat: str = "none",
               unroll: bool = False):
    """batch: tokens [B,S] (+frames/patch_embeds/positions). -> (logits, aux)."""
    enc_out = (_encode(params, batch, cfg, unroll=unroll)
               if cfg.is_encoder_decoder else None)
    x, positions = _embed_inputs(params, batch, cfg)
    if cfg.is_encoder_decoder:
        S = x.shape[1]
        pe = value_of(params["dec_pos_embed"]).astype(x.dtype)
        idx = jnp.arange(S) % pe.shape[0]
        x = x + pe[idx][None]
    x, aux = _run_groups(params["groups"], x, positions, cfg,
                         enc_out=enc_out, remat=remat, unroll=unroll)
    return _logits(params, x, cfg), aux


def lm_loss(params, batch, cfg: ModelConfig, *, remat: str = "none",
            unroll: bool = False):
    """Next-token cross-entropy with masking; returns (loss, metrics)."""
    logits, aux = lm_forward(params, batch, cfg, remat=remat, unroll=unroll)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    xent = -(ll * mask).sum() / denom
    loss = xent + cfg.router_aux_coef * aux
    return loss, {"xent": xent, "aux": aux, "tokens": denom}


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def _init_block_cache(cfg, i: int, batch: int, seq: int):
    mixer, _ = cfg.pattern[i]
    if mixer in MIXERS_WITH_KV:
        return attention.init_kv_cache(
            cfg, batch, seq, local=(mixer == BlockKind.ATTN_LOCAL))
    if mixer == BlockKind.RGLRU:
        return rglru.init_rglru_state(cfg, batch)
    if mixer == BlockKind.MLSTM:
        return xlstm.init_mlstm_state(cfg, batch)
    if mixer == BlockKind.SLSTM:
        return xlstm.init_slstm_state(cfg, batch)
    raise ValueError(mixer)


def _block_cache_axes(cfg, i: int):
    mixer, _ = cfg.pattern[i]
    if mixer in MIXERS_WITH_KV:
        return attention.kv_cache_logical_axes(
            quantized=cfg.kv_cache_dtype == "int8")
    if mixer == BlockKind.RGLRU:
        return rglru.rglru_state_logical_axes()
    if mixer == BlockKind.MLSTM:
        return xlstm.mlstm_state_logical_axes()
    if mixer == BlockKind.SLSTM:
        return xlstm.slstm_state_logical_axes()
    raise ValueError(mixer)


def init_cache(cfg: ModelConfig, batch: int, seq: int):
    """Decode cache: per-pattern-position trees stacked over groups."""
    G = cfg.num_groups
    cache = {}
    for i in range(len(cfg.pattern)):
        one = _init_block_cache(cfg, i, batch, seq)
        cache[f"b{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (G,) + a.shape), one
        )
    if cfg.is_encoder_decoder:
        cache["enc_out"] = jnp.zeros(
            (batch, cfg.encoder_seq, cfg.d_model), cfg.compute_dtype)
    return cache


def cache_logical_axes(cfg: ModelConfig):
    axes = {}
    for i in range(len(cfg.pattern)):
        ax = _block_cache_axes(cfg, i)
        axes[f"b{i}"] = jax.tree.map(
            lambda a: ("layers",) + a,
            ax, is_leaf=lambda t: isinstance(t, tuple) and all(
                isinstance(e, (str, type(None))) for e in t),
        )
    if cfg.is_encoder_decoder:
        axes["enc_out"] = ("batch", None, "act_embed")
    return axes


def _apply_block_decode(blk, cache_i, x, positions, cfg, i: int, *, enc_out):
    mixer, ffn = cfg.pattern[i]
    h = common.rms_norm(x, blk["norm1"], cfg.norm_eps)
    if mixer in MIXERS_WITH_KV:
        local = mixer == BlockKind.ATTN_LOCAL
        y, new_cache = attention.attn_decode(blk["attn"], h, positions, cfg,
                                             cache_i, local=local)
    elif mixer == BlockKind.RGLRU:
        y, new_cache = rglru.rglru_decode_step(blk["rglru"], h, cfg, cache_i)
    elif mixer == BlockKind.MLSTM:
        y, new_cache = xlstm.mlstm_decode_step(blk["mlstm"], h, cfg, cache_i)
    elif mixer == BlockKind.SLSTM:
        y, new_cache = xlstm.slstm_decode_step(blk["slstm"], h, cfg, cache_i)
    else:
        raise ValueError(mixer)
    x = x + y
    if enc_out is not None and "cross_attn" in blk:
        h = common.rms_norm(x, blk["norm_cross"], cfg.norm_eps)
        y = attention.attn_forward(blk["cross_attn"], h, positions, cfg,
                                   causal=False, kv_x=enc_out)
        x = x + y
    if ffn == BlockKind.MLP:
        h = common.rms_norm(x, blk["norm2"], cfg.norm_eps)
        x = x + mlp.mlp_forward(blk["mlp"], h, cfg)
    elif ffn == BlockKind.MOE:
        h = common.rms_norm(x, blk["norm2"], cfg.norm_eps)
        y, _ = moe.moe_forward(blk["moe"], h, cfg)
        x = x + y
    return x, new_cache


def lm_decode_step(params, tokens, positions, cfg: ModelConfig, cache,
                   unroll: bool = False):
    """One decode step.  tokens [B,1]; positions [B,1] -> (logits, cache)."""
    x = common.embed(params["embed"], tokens, cfg)
    if cfg.is_encoder_decoder:
        pe = value_of(params["dec_pos_embed"]).astype(x.dtype)
        idx = positions[:, 0] % pe.shape[0]
        x = x + pe[idx][:, None, :]
    enc_out = cache.get("enc_out") if cfg.is_encoder_decoder else None
    x = constrain(x, ("batch", None, "act_embed"))

    def body(x, xs):
        group_params, group_cache = xs
        new_caches = {}
        for i in range(len(cfg.pattern)):
            x, nc = _apply_block_decode(
                group_params[f"b{i}"], group_cache[f"b{i}"], x, positions,
                cfg, i, enc_out=enc_out)
            new_caches[f"b{i}"] = nc
        return x, new_caches

    layer_cache = {k: v for k, v in cache.items() if k.startswith("b")}
    x, new_layer_cache = _scan_or_unroll(body, x,
                                         (params["groups"], layer_cache),
                                         unroll)
    new_cache = dict(new_layer_cache)
    if cfg.is_encoder_decoder:
        new_cache["enc_out"] = cache["enc_out"]
    return _logits(params, x, cfg), new_cache


def lm_append(params, tokens, positions, cfg: ModelConfig, cache):
    """Fold a chunk of k tokens into an existing cache (batched replay).

    tokens [B,k]; positions [B,k] absolute.  Equivalent to k sequential
    lm_decode_step calls up to softmax-reduction order (verified allclose in
    tests); one call amortizes k matmuls into chunk-parallel compute.
    """
    x = common.embed(params["embed"], tokens, cfg)
    if cfg.is_encoder_decoder:
        pe = value_of(params["dec_pos_embed"]).astype(x.dtype)
        x = x + pe[positions % pe.shape[0]]
    enc_out = cache.get("enc_out") if cfg.is_encoder_decoder else None
    x = constrain(x, ("batch", "seq", "act_embed"))

    def body(x, xs):
        group_params, group_cache = xs
        new_caches = {}
        for i in range(len(cfg.pattern)):
            blk = group_params[f"b{i}"]
            mixer, ffn = cfg.pattern[i]
            h = common.rms_norm(x, blk["norm1"], cfg.norm_eps)
            if mixer in MIXERS_WITH_KV:
                local = mixer == BlockKind.ATTN_LOCAL
                y, nc = attention.attn_append(
                    blk["attn"], h, positions, cfg, group_cache[f"b{i}"],
                    local=local)
            elif mixer == BlockKind.RGLRU:
                y, nc = rglru.rglru_block_forward(
                    blk["rglru"], h, cfg, group_cache[f"b{i}"])
            elif mixer == BlockKind.MLSTM:
                y, nc = xlstm.mlstm_block_forward(
                    blk["mlstm"], h, cfg, group_cache[f"b{i}"])
            elif mixer == BlockKind.SLSTM:
                y, nc = xlstm.slstm_block_forward(
                    blk["slstm"], h, cfg, group_cache[f"b{i}"])
            else:
                raise ValueError(mixer)
            x = x + y
            if enc_out is not None and "cross_attn" in blk:
                hc = common.rms_norm(x, blk["norm_cross"], cfg.norm_eps)
                x = x + attention.attn_forward(
                    blk["cross_attn"], hc, positions, cfg, causal=False,
                    kv_x=enc_out)
            if ffn == BlockKind.MLP:
                h2 = common.rms_norm(x, blk["norm2"], cfg.norm_eps)
                x = x + mlp.mlp_forward(blk["mlp"], h2, cfg)
            elif ffn == BlockKind.MOE:
                h2 = common.rms_norm(x, blk["norm2"], cfg.norm_eps)
                y2, _ = moe.moe_forward(blk["moe"], h2, cfg)
                x = x + y2
            new_caches[f"b{i}"] = nc
        return x, new_caches

    layer_cache = {k: v for k, v in cache.items() if k.startswith("b")}
    x, new_layer_cache = jax.lax.scan(body, x, (params["groups"], layer_cache))
    new_cache = dict(new_layer_cache)
    if cfg.is_encoder_decoder:
        new_cache["enc_out"] = cache["enc_out"]
    return _logits(params, x, cfg), new_cache


def lm_prefill(params, batch, cfg: ModelConfig, cache, unroll: bool = False):
    """Process a full prompt, producing logits and a populated cache.

    Implemented as full-sequence attention (flash) plus cache population —
    the KV writes happen layer-by-layer inside the scan.
    """
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, batch, cfg, unroll=unroll)
    x, positions = _embed_inputs(params, batch, cfg)
    if cfg.is_encoder_decoder:
        S = x.shape[1]
        pe = value_of(params["dec_pos_embed"]).astype(x.dtype)
        x = x + pe[jnp.arange(S) % pe.shape[0]][None]

    def body(x, xs):
        group_params, group_cache = xs
        new_caches = {}
        for i in range(len(cfg.pattern)):
            blk = group_params[f"b{i}"]
            mixer, ffn = cfg.pattern[i]
            h = common.rms_norm(x, blk["norm1"], cfg.norm_eps)
            if mixer in MIXERS_WITH_KV:
                local = mixer == BlockKind.ATTN_LOCAL
                y, nc = attention.prefill_into_cache(
                    blk["attn"], h, positions, cfg, group_cache[f"b{i}"],
                    local=local)
            elif mixer == BlockKind.RGLRU:
                y, nc = rglru.rglru_block_forward(
                    blk["rglru"], h, cfg, group_cache[f"b{i}"])
            elif mixer == BlockKind.MLSTM:
                y, nc = xlstm.mlstm_block_forward(
                    blk["mlstm"], h, cfg, group_cache[f"b{i}"])
            elif mixer == BlockKind.SLSTM:
                y, nc = xlstm.slstm_block_forward(
                    blk["slstm"], h, cfg, group_cache[f"b{i}"])
            else:
                raise ValueError(mixer)
            x = x + y
            if enc_out is not None and "cross_attn" in blk:
                hc = common.rms_norm(x, blk["norm_cross"], cfg.norm_eps)
                x = x + attention.attn_forward(
                    blk["cross_attn"], hc, positions, cfg, causal=False,
                    kv_x=enc_out)
            if ffn == BlockKind.MLP:
                h2 = common.rms_norm(x, blk["norm2"], cfg.norm_eps)
                x = x + mlp.mlp_forward(blk["mlp"], h2, cfg)
            elif ffn == BlockKind.MOE:
                h2 = common.rms_norm(x, blk["norm2"], cfg.norm_eps)
                y2, _ = moe.moe_forward(blk["moe"], h2, cfg)
                x = x + y2
            new_caches[f"b{i}"] = nc
        return x, new_caches

    layer_cache = {k: v for k, v in cache.items() if k.startswith("b")}
    x, new_layer_cache = _scan_or_unroll(body, x,
                                         (params["groups"], layer_cache),
                                         unroll)
    new_cache = dict(new_layer_cache)
    if cfg.is_encoder_decoder:
        new_cache["enc_out"] = enc_out
    return _logits(params, x, cfg), new_cache
