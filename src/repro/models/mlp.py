"""Dense FFN: SwiGLU (default) or plain activation MLP (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import param, value_of
from repro.sharding.rules import with_sharding_constraint_logical as constrain


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def init_mlp(key, cfg, d_ff: int = 0):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": param(ks[0], (d, ff), ("embed", "mlp")),
        "w_down": param(ks[1], (ff, d), ("mlp", "embed")),
    }
    if cfg.mlp_gated:
        p["w_gate"] = param(ks[2], (d, ff), ("embed", "mlp"))
    return p


def mlp_forward(params, x, cfg):
    dt = x.dtype
    up = x @ value_of(params["w_up"]).astype(dt)
    up = constrain(up, ("batch", "seq", "act_mlp"))
    if cfg.mlp_gated:
        gate = _act(cfg.mlp_act)(x @ value_of(params["w_gate"]).astype(dt))
        gate = constrain(gate, ("batch", "seq", "act_mlp"))
        h = up * gate
    else:
        h = _act(cfg.mlp_act)(up)
    out = h @ value_of(params["w_down"]).astype(dt)
    return constrain(out, ("batch", "seq", "act_embed"))
