from repro.models.config import ModelConfig, BlockKind, SHAPES, ShapeSpec  # noqa: F401
from repro.models import common, attention, mlp, moe, rglru, xlstm  # noqa: F401
from repro.models.transformer import (  # noqa: F401
    init_lm,
    lm_loss,
    lm_forward,
    lm_prefill,
    lm_decode_step,
    init_cache,
)
