from repro.data.pipeline import (  # noqa: F401
    DataConfig,
    SyntheticTokenDataset,
    make_train_iterator,
)
