"""Deterministic synthetic token pipeline.

Every batch is a pure function of (seed, step) — the property that makes a
training worker's optimizer state reconstructible by *replaying the batch-id
log* (MS2M applied to training: the message is the batch id, not the bytes).
Host-sharded: each data-parallel host materializes only its slice.
Double-buffered prefetch hides host->device transfer behind the step.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-chain synthetic text (has learnable structure, so loss curves
    # are meaningful in the examples)
    order: int = 1
    branching: int = 32


class SyntheticTokenDataset:
    """Deterministic batches: batch(step) is reproducible forever."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed random transition table: vocab -> `branching` successors
        self._succ = rng.integers(
            0, cfg.vocab_size, size=(cfg.vocab_size, cfg.branching), dtype=np.int32
        )

    def batch(self, step: int, *, host_id: int = 0, num_hosts: int = 1):
        cfg = self.cfg
        assert cfg.global_batch % num_hosts == 0
        local = cfg.global_batch // num_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_id])
        )
        toks = np.empty((local, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=local)
        choices = rng.integers(0, cfg.branching, size=(local, cfg.seq_len))
        for t in range(cfg.seq_len):
            toks[:, t + 1] = self._succ[toks[:, t], choices[:, t]]
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
        }


def make_train_iterator(cfg: DataConfig, *, start_step: int = 0,
                        prefetch: int = 2, host_id: int = 0,
                        num_hosts: int = 1) -> Iterator[dict]:
    """Background-threaded prefetching iterator over (step, batch)."""
    ds = SyntheticTokenDataset(cfg)
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            try:
                q.put((step, ds.batch(step, host_id=host_id,
                                      num_hosts=num_hosts)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=producer, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _Iter()
