"""Zero-downtime serving migration: KV-cache handoff with a dual-serving
window and exactly-once request completion.

The paper's migration machinery moves *fold* workers (trainer/consumer
pods) and reports control-plane downtime.  For a serving engine the right
metric is different — SHADOW's observation: what a user perceives is the
*latency* of their in-flight request, so the goal is a handoff in which
no request is ever lost, duplicated, or parked behind a stopped replica.
This module wires the slot-based serving engine into that machinery:

* **Stateful payload** — the engine's per-slot KV-cache lanes *plus* the
  admitted-request log (``serving/engine.py:state_tree``), pre-copied
  over the existing delta/codec wire path.  ``slot_aligned_chunk_bytes``
  picks the registry chunk grid so chunk boundaries never straddle a
  decode lane: a precopy round's fingerprint diff then ships only the
  lanes that actually decoded since the previous round.
* **Dual-serving window** — the ``serving_handoff`` strategy keeps the
  source decoding while the target restores and replays the mirrored
  admission log (standard MS2M catch-up); for a window both replicas are
  decoding the same requests.
* **Exactly-once completion** — both replicas finishing the same request
  is resolved by the :class:`CompletionLedger`: completions are keyed by
  request id and the first one wins; replayed finishes are counted as
  suppressed duplicates, never double-delivered.  Un-admitted queue
  entries re-route to the target through the ordinary queue switch +
  id-dedup path, and a mid-handoff fault rolls back to the still-serving
  source (PR 5 machinery) with the ledger again deduping whatever the
  dead target already finished.
* **Latency tracing** — the ledger records per-request submit/complete
  times; :func:`run_serving_experiment` drives an open-loop Poisson
  request stream and reports p50/p99/p999 (``repro.analysis.stats``),
  the headline metric of ``benchmarks/serving_handoff.py``.

The strategy registers itself here and is imported for its side effect at
the bottom of ``core/strategies.py`` — zero edits to the manager core, as
the registry demands.  This module deliberately imports only
``repro.core.strategy`` (the registry layer); the experiment harness
imports the manager lazily.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Dict, Generator, List, Optional, Tuple

import numpy as np

from repro.core.strategy import (
    LiveSyncCatchup,
    MigrationContext,
    MigrationStrategy,
    register_strategy,
)
from repro.serving.engine import Request, ServingEngine


# ---------------------------------------------------------------------------
# Exactly-once completion ledger
# ---------------------------------------------------------------------------

class CompletionLedger:
    """Request-id-keyed completion dedup: at-least-once processing plus
    first-completion-wins delivery equals exactly-once delivery.

    During the dual-serving window (and after a rollback) two replicas
    may legitimately finish the same request; the ledger delivers the
    first finish, suppresses and *counts* every replay, and records
    per-request submit→complete latency for the tail metrics.  The
    exactly-once audit is structural: every submitted id delivered
    (zero lost), the delivered set keyed by id (zero duplicates)."""

    def __init__(self, sim):
        self.sim = sim
        self.submitted: Dict[int, float] = {}          # rid -> t_submit
        self.delivered: Dict[int, Dict[str, Any]] = {}  # rid -> record
        self.duplicates: List[Tuple[int, float, str]] = []

    def submit(self, rid: int) -> None:
        self.submitted[int(rid)] = self.sim.now

    def complete(self, rid: int, by: str = "",
                 tokens: Optional[int] = None) -> bool:
        """Record a finish; returns True iff this was the first one."""
        rid = int(rid)
        if rid in self.delivered:
            self.duplicates.append((rid, self.sim.now, by))
            return False
        t0 = self.submitted.get(rid, 0.0)
        self.delivered[rid] = {"t_submit": t0, "t_complete": self.sim.now,
                               "latency": self.sim.now - t0, "by": by,
                               "n_tokens": tokens}
        return True

    def pending(self) -> List[int]:
        return sorted(set(self.submitted) - set(self.delivered))

    def latencies(self) -> List[float]:
        return [self.delivered[r]["latency"] for r in sorted(self.delivered)]

    @property
    def exactly_once(self) -> bool:
        return (not self.pending()
                and set(self.delivered) <= set(self.submitted))


# ---------------------------------------------------------------------------
# Serving workers (MS2M worker protocol over decode slots)
# ---------------------------------------------------------------------------

class HashServingWorker:
    """Slot-based serving worker without JAX: each decode slot is a lane
    of uint64 hash state, each decode round mixes one "token" into every
    active lane, and a request occupies its slot across *messages* (its
    decode budget outlives the admission message) — so a checkpoint
    genuinely carries in-flight requests, exactly what the handoff must
    preserve.  Bit-exact, order-sensitive, cheap: the wide-sweep analogue
    of :class:`~repro.serving.engine.ServingEngine`.

    A message admits one request: wait (synchronously decoding) for a
    free slot, fold the prompt into the lane, then run one decode round.
    Completions go to the shared :class:`CompletionLedger` (a reference
    fold passes ``ledger=None`` and just drops them)."""

    FNV = np.uint64(1099511628211)

    def __init__(self, num_slots: int = 8, lane_words: int = 4096,
                 ledger: Optional[CompletionLedger] = None,
                 name: str = "serving"):
        self.num_slots = num_slots
        self.lane_words = lane_words
        self.ledger = ledger
        self.name = name
        self.lanes = np.zeros((num_slots, lane_words), np.uint64)
        self.slot_req = np.full(num_slots, -1, np.int64)
        self.slot_pos = np.zeros(num_slots, np.int64)
        self.slot_budget = np.zeros(num_slots, np.int64)
        self.last_msg_id = -1
        self.n_processed = 0
        self.skip_until = -1

    # -- decode ---------------------------------------------------------------
    def _round(self) -> None:
        """One decode round: every active lane mixes one token (ascending
        slot order — deterministic), budgets tick down, exhausted slots
        complete."""
        with np.errstate(over="ignore"):
            for s in np.flatnonzero(self.slot_req >= 0):
                s = int(s)
                pos = int(self.slot_pos[s])
                x = self.lanes[s, pos % self.lane_words]
                mixed = np.uint64(
                    (x ^ np.uint64(self.slot_req[s] + pos + 1)) * self.FNV)
                self.lanes[s, (pos + 1) % self.lane_words] ^= mixed
                self.slot_pos[s] = pos + 1
                self.slot_budget[s] -= 1
                if self.slot_budget[s] <= 0:
                    self._complete(s)

    def _complete(self, s: int) -> None:
        rid = int(self.slot_req[s])
        tokens = int(self.slot_pos[s])
        self.slot_req[s] = -1
        self.slot_pos[s] = 0
        self.slot_budget[s] = 0
        if self.ledger is not None:
            self.ledger.complete(rid, by=self.name, tokens=tokens)

    # -- MS2M worker API ------------------------------------------------------
    def process(self, msg) -> None:
        p = msg.payload
        rid = int(p.get("request_id", msg.msg_id))
        prompt = list(p.get("prompt", [p.get("token", 0)]))
        budget = max(1, int(p.get("max_new_tokens", 8)))
        while True:
            idle = np.flatnonzero(self.slot_req < 0)
            if idle.size:
                s = int(idle[0])
                break
            self._round()  # no free slot: decode until one completes
        with np.errstate(over="ignore"):
            acc = np.uint64(1469598103934665603)
            for tok in prompt:
                acc = np.uint64((acc ^ np.uint64(tok)) * self.FNV)
            self.lanes[s, 0] ^= acc ^ np.uint64(rid + 1)
        self.slot_req[s] = rid
        self.slot_pos[s] = 0
        self.slot_budget[s] = budget
        self._round()
        self.last_msg_id = msg.msg_id
        self.n_processed += 1

    def state_tree(self):
        return {"lanes": self.lanes.copy(),
                "slots": {"request": self.slot_req.copy(),
                          "position": self.slot_pos.copy(),
                          "budget": self.slot_budget.copy()},
                "scalars": {"last_msg_id": np.int64(self.last_msg_id),
                            "n_processed": np.int64(self.n_processed)}}

    def load_state(self, tree) -> None:
        self.lanes = np.asarray(tree["lanes"]).copy()
        self.slot_req = np.asarray(tree["slots"]["request"]).copy()
        self.slot_pos = np.asarray(tree["slots"]["position"]).copy()
        self.slot_budget = np.asarray(tree["slots"]["budget"]).copy()
        self.last_msg_id = int(tree["scalars"]["last_msg_id"])
        self.n_processed = int(tree["scalars"]["n_processed"])

    def state_equal(self, other, exact: bool = True) -> bool:
        return bool(np.array_equal(self.lanes, other.lanes)
                    and np.array_equal(self.slot_req, other.slot_req)
                    and np.array_equal(self.slot_pos, other.slot_pos)
                    and np.array_equal(self.slot_budget, other.slot_budget)
                    and self.last_msg_id == other.last_msg_id)

    # -- handoff telemetry ----------------------------------------------------
    def slot_table(self) -> List[Dict[str, int]]:
        return [{"slot": int(s), "request_id": int(self.slot_req[s]),
                 "position": int(self.slot_pos[s]),
                 "budget": int(self.slot_budget[s])}
                for s in np.flatnonzero(self.slot_req >= 0)]

    def slot_lane_nbytes(self) -> int:
        return self.lane_words * 8

    def flush(self, max_rounds: int = 100000) -> int:
        """Decode until every admitted request completes (end-of-run
        drain of leftover in-flight slots).  Returns rounds run."""
        n = 0
        while (self.slot_req >= 0).any():
            if n >= max_rounds:
                raise RuntimeError(f"{self.name}: flush did not converge")
            self._round()
            n += 1
        return n


class ServingWorker:
    """MS2M worker adapter around the real :class:`ServingEngine`.

    ``decode_rounds=None`` keeps the engine's legacy semantics (one
    message = admission + full generation, nothing in flight between
    messages).  With ``decode_rounds=k`` the adapter streams instead:
    each message admits its request (draining the waiting queue, so a
    checkpoint never sees an un-snapshottable admission backlog) and
    then runs only ``k`` batched decode rounds — generation spans
    messages and checkpoints genuinely carry mid-generation slots.
    Completions drain into the shared ledger (or stay on the engine when
    ``ledger=None`` — the reference-fold configuration)."""

    def __init__(self, engine: ServingEngine,
                 ledger: Optional[CompletionLedger] = None,
                 decode_rounds: Optional[int] = None):
        self.engine = engine
        self.ledger = ledger
        self.decode_rounds = decode_rounds

    # -- MS2M worker API ------------------------------------------------------
    def process(self, msg) -> None:
        eng = self.engine
        if self.decode_rounds is None:
            eng.process(msg)
        else:
            p = msg.payload
            req = Request(int(p.get("request_id", msg.msg_id)),
                          list(p.get("prompt", [p.get("token", 0)])),
                          int(p.get("max_new_tokens", 8)))
            eng.submit(req)
            while eng.waiting:  # admission backlog is not checkpointable
                eng._engine_step()
            for _ in range(self.decode_rounds):
                if eng.active.any():
                    eng._engine_step()
            eng.last_msg_id = msg.msg_id
            eng.n_processed += 1
        self._drain_completions()

    def _drain_completions(self) -> None:
        if self.ledger is None:
            return  # reference folds keep engine.completions untouched
        while self.engine.completions:
            c = self.engine.completions.pop(0)
            self.ledger.complete(c.request_id, by=self.engine.name,
                                 tokens=len(c.tokens))

    def state_tree(self):
        return self.engine.state_tree()

    def load_state(self, tree) -> None:
        self.engine.load_state(tree)

    def state_equal(self, other, exact: bool = True) -> bool:
        eng = other.engine if isinstance(other, ServingWorker) else other
        return self.engine.state_equal(eng, exact=exact)

    @property
    def name(self) -> str:
        return self.engine.name

    @property
    def last_msg_id(self) -> int:
        return self.engine.last_msg_id

    @last_msg_id.setter
    def last_msg_id(self, v: int) -> None:
        self.engine.last_msg_id = v

    @property
    def n_processed(self) -> int:
        return self.engine.n_processed

    @property
    def skip_until(self) -> int:
        return self.engine.skip_until

    @skip_until.setter
    def skip_until(self, v: int) -> None:
        self.engine.skip_until = v

    # -- handoff telemetry ----------------------------------------------------
    def slot_table(self) -> List[Dict[str, int]]:
        return self.engine.slot_table()

    def slot_lane_nbytes(self) -> int:
        import jax

        g = 0
        for leaf in jax.tree.leaves(self.engine.cache):
            g = math.gcd(g, int(leaf.nbytes) // self.engine.num_slots)
        return g

    def flush(self, max_rounds: int = 100000) -> int:
        n = 0
        eng = self.engine
        while eng.active.any() or eng.waiting:
            if n >= max_rounds:
                raise RuntimeError(f"{eng.name}: flush did not converge")
            eng._engine_step()
            n += 1
        self._drain_completions()
        return n


def slot_aligned_chunk_bytes(worker) -> int:
    """Registry chunk size aligned to the worker's decode lanes: chunk
    boundaries coincide with per-slot KV-lane boundaries, so a delta
    round's fingerprint diff ships exactly the lanes that decoded since
    the previous round — never a clean lane dragged along by a straddling
    chunk."""
    n = int(worker.slot_lane_nbytes())
    if n <= 0:
        raise ValueError(f"worker {worker!r} reports no per-slot state")
    return n


# ---------------------------------------------------------------------------
# The registered strategy
# ---------------------------------------------------------------------------

@register_strategy("serving_handoff")
class ServingHandoff(MigrationStrategy):
    """Serving handoff (beyond paper, SHADOW-style): KV-cache lanes + the
    admitted-request log pre-copy in per-slot-aligned delta chunks while
    BOTH replicas decode (dual-serving window); at cutover, in-flight
    requests hand off per decode slot and a completion ledger dedupes
    replayed finishes — exactly-once completion, tail latency (not
    downtime) as the headline metric.

    The pipeline is the live MS2M shape with pre-copy always on, plus the
    serving-specific telemetry: ``dual_serving_begin`` when the target
    starts decoding alongside the source, ``slot_handoff`` with the
    source's final in-flight slot table at the pause instant.  The source
    pause returns its mid-service admission to the queue front; the
    mirror already holds a copy, and the pod-loop id-dedup plus the
    ledger's first-completion-wins rule make whichever path delivers
    first exactly-once.  Any mid-handoff fault takes the ordinary
    rollback path: the source keeps serving and the ledger suppresses
    whatever the dead target already finished.
    """

    def run(self, ctx: MigrationContext) -> Generator:
        t = ctx.api.timings
        rep = ctx.report
        disc = LiveSyncCatchup()
        sec = ctx.attach_secondary()
        try:
            # per-slot-aligned delta pre-copy: only dirty decode lanes
            # ship per round (chunk grid set by the harness)
            push = yield from ctx.transfer(
                True,
                f"{ctx.primary_queue}-srv-pre{ctx.n}",
                f"{ctx.primary_queue}-srv{ctx.n}")

            target = yield from ctx.restore_target(push, sec, replay=True)

            # -- dual-serving window: both replicas decode ------------------
            t0 = ctx.sim.now
            base_processed = target.worker.n_processed
            ctx.emit("dual_serving_begin", target=target.name,
                     checkpoint_marker=rep.checkpoint_marker)
            target.start()
            yield from disc.catchup(ctx, target)
            ctx.phase("message_replay", t0)

            # -- cutover: per-slot in-flight handoff ------------------------
            t0 = ctx.sim.now
            down0 = disc.begin_cutover(ctx)  # pause: in-flight admission
            #                                  requeues to the primary front
            slot_probe = getattr(ctx.source.worker, "slot_table", None)
            slots = slot_probe() if callable(slot_probe) else []
            ctx.emit("slot_handoff", slots=slots, n_active=len(slots))
            yield t.cutover_coord_s
            yield from ctx.wait(
                ctx.drain_condition(target, ctx.source.worker.last_msg_id))
            ctx.switch_to_primary(target)
            target.processing_ms = ctx.source.processing_ms
            yield t.route_switch_s
            rep.downtime = ctx.sim.now - down0
            ctx.phase("cutover", t0)
            ctx.emit("dual_serving_end", duration=ctx.sim.now - down0)

            yield from ctx.teardown_source()

            rep.replayed_messages = target.worker.n_processed - base_processed
            ctx.finish(target)
            return rep, target
        finally:
            ctx.cleanup()


# ---------------------------------------------------------------------------
# Experiment harness: open-loop Poisson requests + latency tracing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServingResult:
    strategy: str
    rate: float
    report: Optional[Any]            # MigrationReport | None (failed run)
    failed: bool
    failure: Optional[Dict[str, Any]]
    published: int
    delivered: int
    duplicates: int                  # suppressed replayed finishes
    lost: int                        # submitted but never delivered
    exactly_once: bool
    state_verified: Optional[bool]
    latencies: List[float]
    flushed_rounds: int
    downtime: float
    migration_time: float
    listeners_left: int              # pod on_processed listeners at end
    mirrors_left: int                # mirrors still attached to the primary

    def latency(self) -> Dict[str, Any]:
        from repro.analysis.stats import latency_summary
        return latency_summary(self.latencies)

    def row(self) -> Dict[str, Any]:
        row = {
            "strategy": self.strategy,
            "rate": self.rate,
            "failed": self.failed,
            "published": self.published,
            "delivered": self.delivered,
            "duplicates": self.duplicates,
            "lost": self.lost,
            "exactly_once": self.exactly_once,
            "state_verified": self.state_verified,
            "downtime": round(self.downtime, 3),
            "migration_time": round(self.migration_time, 3),
            "latency": self.latency(),
        }
        if self.failed and self.failure is not None:
            row.update({k: self.failure.get(k)
                        for k in ("error", "attempts", "rolled_back",
                                  "source_serving")})
        return row


def serving_reference_fold(make_ref, payloads: List[Dict[str, Any]],
                           upto: int):
    """Correctness oracle: a fresh (ledger-less) serving worker replays
    the published request log 0..upto; its state must equal the live
    worker bit-exactly (ids reassigned 0..upto, matching the broker's
    per-queue monotonic ids)."""
    from repro.broker.broker import Message

    ref = make_ref()
    for i, payload in enumerate(payloads[: upto + 1]):
        ref.process(Message(i, payload, 0.0))
    return ref


def run_serving_experiment(
    strategy: str = "serving_handoff",
    request_rate: float = 8.0,
    *,
    registry_root: str,
    processing_ms: float = 50.0,
    t_migrate: float = 10.0,
    settle_time: float = 5.0,
    seed: int = 0,
    worker: str = "hash",            # "hash" | "engine"
    num_slots: int = 8,
    lane_words: int = 4096,
    decode_rounds: Optional[int] = 1,
    max_seq: int = 128,
    prompt_tokens: Tuple[int, int] = (1, 4),
    max_new_tokens: Tuple[int, int] = (2, 12),
    burst_factor: float = 1.0,
    burst_every: int = 0,
    burst_len: int = 0,
    timings=None,
    topology=None,
    num_nodes: int = 3,
    faults=None,
    allow_failure: bool = False,
    policy=None,
    chunk_bytes: Optional[int] = None,
    verify: bool = True,
    sanitize: Optional[bool] = None,
    tiebreak_seed: Optional[int] = None,
) -> ServingResult:
    """One serving migration under an open-loop Poisson request stream.

    Mirrors ``run_migration_experiment``'s shape (boot → migrate at
    ``t_migrate`` → settle → drain), but the workload is a stream of
    generation *requests* (request id = broker message id), the worker is
    a slot-based serving worker sharing one :class:`CompletionLedger`,
    and the result carries per-request latencies plus the exactly-once
    audit.  State verification runs BEFORE the end-of-run flush (the
    reference fold replays admissions only, not the final drain)."""
    # lazy: the manager/orchestrator sit above this module in the import
    # graph (core.strategies imports us for strategy registration)
    from repro.cluster.cluster import Cluster, TimingConstants
    from repro.core.migration import MigrationManager
    from repro.core.policy import MigrationPolicy
    from repro.core.strategy import get_strategy
    from repro.core.workload import open_loop_gaps, request_stream

    if worker not in ("hash", "engine"):
        raise ValueError(f"worker must be 'hash' or 'engine' (got {worker!r})")
    pol = MigrationPolicy.resolve(policy)
    timings = timings or TimingConstants()
    timings = dataclasses.replace(timings, processing_ms=processing_ms)
    if num_nodes < 2:
        raise ValueError("run_serving_experiment needs num_nodes >= 2")

    # -- worker factories (live workers share the ledger; refs do not) ------
    engine_cfg = engine_params = None
    if worker == "engine":
        import jax

        from repro import configs
        from repro.models import transformer as T

        engine_cfg = configs.get_config("paper_consumer")
        engine_params = T.init_lm(jax.random.PRNGKey(0), engine_cfg)

    def build(ledger, name: str):
        if worker == "hash":
            return HashServingWorker(num_slots=num_slots,
                                     lane_words=lane_words,
                                     ledger=ledger, name=name)
        eng = ServingEngine(engine_cfg, engine_params, num_slots=num_slots,
                            max_seq=max_seq, name=name)
        return ServingWorker(eng, ledger=ledger, decode_rounds=decode_rounds)

    if chunk_bytes is None:
        chunk_bytes = slot_aligned_chunk_bytes(build(None, "probe"))

    cluster = Cluster(registry_root, timings=timings, num_nodes=num_nodes,
                      chunk_bytes=chunk_bytes, topology=topology,
                      faults=faults, sanitize=sanitize,
                      tiebreak_seed=tiebreak_seed)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    primary = broker.declare_queue("requests")
    ledger = CompletionLedger(sim)
    counter = itertools.count()

    def make_worker():
        return build(ledger, f"serving-{next(counter)}")

    def make_ref():
        return build(None, "reference")

    # -- open-loop request driver -------------------------------------------
    rng = np.random.default_rng(seed)
    gaps = open_loop_gaps(rng, request_rate, burst_factor=burst_factor,
                          burst_every=burst_every, burst_len=burst_len)
    reqs = request_stream(rng, prompt_tokens=prompt_tokens,
                          max_new_tokens=max_new_tokens)
    published: List[Dict[str, Any]] = []
    stop_producing = {"flag": False}

    def producer():
        while not stop_producing["flag"]:
            yield next(gaps)
            payload = next(reqs)
            msg = broker.publish("requests", payload)
            ledger.submit(msg.msg_id)
            published.append(payload)

    sim.process(producer(), name="producer")

    # -- source pod -----------------------------------------------------------
    source_worker = make_worker()
    holder: dict = {}

    def boot():
        pod = yield from api.create_pod("serving-0", "node0", source_worker,
                                        primary)
        pod.start()
        holder["pod"] = pod

    sim.process(boot(), name="boot")
    sim.run(until=t_migrate)
    source = holder["pod"]

    cutoff = None
    if get_strategy(strategy).wants_cutoff:
        from repro.core.cutoff import CutoffController

        cutoff = CutoffController(t_replay_max=pol.t_replay_max,
                                  mu_fallback=1000.0 / processing_ms,
                                  lam_fallback=request_rate)

    # -- migration: direct manager when fault-free single-attempt, else the
    # orchestrator's guarded retry loop (identical to run_migration_experiment)
    use_guard = faults is not None or pol.max_attempts > 1 or allow_failure
    report = None
    target = None
    failed = False
    failure: Optional[Dict[str, Any]] = None
    if not use_guard:
        mgr = MigrationManager(api, make_worker, "requests", cutoff=cutoff,
                               policy=pol)
        done = mgr.migrate(strategy, source, "node1")
        sim.run(stop_when=done)
        report, target = done.value
    else:
        from repro.core.orchestrator import (ClusterMigrationOrchestrator,
                                             PodMigrationSpec)

        orch = ClusterMigrationOrchestrator(
            api, make_worker, max_concurrent=1,
            cutoff_factory=(lambda: cutoff) if cutoff is not None else None,
            policy=pol)
        done = orch.migrate_fleet([PodMigrationSpec(
            pod=source, queue="requests", target_node="node1",
            strategy=strategy)])
        sim.run(stop_when=done)
        fleet = done.value
        if fleet.failures:
            failure = dict(fleet.failures[0])
            failed = True
            if not allow_failure:
                raise RuntimeError(
                    f"serving migration failed after "
                    f"{failure['attempts']} attempt(s): {failure['error']}")
        else:
            report, target = fleet.reports[0], fleet.targets[0]

    # -- settle, stop the driver, drain the backlog ---------------------------
    sim.run(until=sim.now + settle_time)
    stop_producing["flag"] = True
    sim.run(until=sim.now + 2.0)

    if target is not None:
        live_pod = target
    else:  # failed run: rollback restored the source (possibly re-created)
        live_pod = api.pods.get((failure or {}).get("source_pod")
                                or source.name)
    # bounded host-level drain (not a sim process): advance the clock until
    # the primary queue is empty and nothing is mid-service
    for _ in range(1000):
        if primary.depth() == 0 and (live_pod is None or not live_pod.busy):
            break
        sim.run(until=sim.now + 1.0)

    # -- verification (BEFORE flush), then drain in-flight slots --------------
    state_verified: Optional[bool] = None
    flushed = 0
    if live_pod is not None:
        if verify:
            ref = serving_reference_fold(make_ref, published,
                                         live_pod.worker.last_msg_id)
            state_verified = bool(ref.state_equal(live_pod.worker))
            if report is not None:
                report.state_verified = state_verified
            if failure is not None:
                failure["source_verified"] = state_verified
        flushed = live_pod.worker.flush()
    if failure is not None:
        src = live_pod
        failure["source_serving"] = bool(
            src is not None and not src.deleted and src.node.alive
            and src.serving)

    listeners_left = sum(len(p.on_processed_listeners)
                         for p in api.pods.values())
    mirrors_left = len(broker._mirrors.get("requests", []))

    return ServingResult(
        strategy=strategy,
        rate=request_rate,
        report=report,
        failed=failed,
        failure=failure,
        published=len(published),
        delivered=len(ledger.delivered),
        duplicates=len(ledger.duplicates),
        lost=len(ledger.pending()),
        exactly_once=ledger.exactly_once,
        state_verified=state_verified,
        latencies=ledger.latencies(),
        flushed_rounds=flushed,
        downtime=report.downtime if report is not None else 0.0,
        migration_time=report.migration_time if report is not None else 0.0,
        listeners_left=listeners_left,
        mirrors_left=mirrors_left,
    )
