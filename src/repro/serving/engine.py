"""Slot-based serving engine with continuous batching.

A fixed decode batch of ``num_slots`` sequences; requests admit into free
slots (chunked prefill via ``lm_append``), every engine step decodes one
token for all active slots, finished sequences free their slot.  State =
(slot KV caches, slot table) — one pytree, which makes the *whole engine*
an MS2M-migratable worker: its message log is the admitted request stream,
and replaying it from a checkpoint reproduces the engine bit-exactly
(tests/test_serving.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 16


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: List[int]


@functools.partial(jax.jit, static_argnames=("cfg",))
def _decode_all(params, cfg, cache, tokens, positions):
    logits, cache = T.lm_decode_step(params, tokens, positions, cfg, cache)
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache


class ServingEngine:
    """Continuous-batching engine over ``num_slots`` decode lanes."""

    def __init__(self, cfg: ModelConfig, params, num_slots: int = 4,
                 max_seq: int = 512, name: str = "engine"):
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.name = name
        self.cache = T.init_cache(cfg, num_slots, max_seq)
        self.positions = np.zeros(num_slots, np.int64)  # next position
        self.active = np.zeros(num_slots, bool)
        self.claimed = np.zeros(num_slots, bool)  # mid-prefill guard
        self.request_of_slot: Dict[int, int] = {}
        self.budget = np.zeros(num_slots, np.int64)
        self.generated: Dict[int, List[int]] = {}
        self.last_token = np.zeros(num_slots, np.int64)
        self.waiting: List[Request] = []
        self.completions: List[Completion] = []
        # MS2M bookkeeping
        self.last_msg_id = -1
        self.n_processed = 0
        self.skip_until = -1
        self._step_jit = functools.partial(_decode_all, self.params, self.cfg)

    # ------------------------------------------------------------------ admin
    def submit(self, req: Request):
        self.waiting.append(req)
        self._admit_waiting()

    def _admit_waiting(self):
        while self.waiting and not (self.active | self.claimed).all():
            slot = int(np.flatnonzero(~(self.active | self.claimed))[0])
            req = self.waiting.pop(0)
            self._prefill_slot(slot, req)

    def _prefill_slot(self, slot: int, req: Request):
        """Fold the prompt into the slot with forced decode steps (other
        active lanes keep generating during the admission — continuous
        batching).  The last prompt step's logits yield the first sampled
        token, exactly like a plain prefill+decode."""
        toks = req.prompt or [0]
        self.claimed[slot] = True
        sampled = 0
        for t, tok in enumerate(toks):
            next_tok = self._engine_step(forced={slot: (tok, t)})
            sampled = int(next_tok[slot])
        self.claimed[slot] = False
        self.positions[slot] = len(toks)
        self.active[slot] = True
        self.request_of_slot[slot] = req.request_id
        self.generated[req.request_id] = [sampled]
        self.last_token[slot] = sampled
        self.budget[slot] = req.max_new_tokens - 1
        if self.budget[slot] <= 0:
            self._complete(slot)

    # ------------------------------------------------------------------- step
    def _engine_step(self, forced: Optional[Dict[int, tuple]] = None):
        """One batched decode step across all slots.

        ``forced`` maps slot -> (token, position): lanes being prefilled
        consume their prompt token at its position; other active lanes
        decode their last sampled token; idle lanes re-write position 0 of
        their own lane with token 0 (harmless: they are reset on admit)."""
        forced = forced or {}
        tokens = np.zeros((self.num_slots, 1), np.int32)
        positions = np.zeros((self.num_slots, 1), np.int32)
        for s in range(self.num_slots):
            if s in forced:
                tok, pos = forced[s]
                tokens[s, 0] = tok
                positions[s, 0] = pos
            elif self.active[s]:
                tokens[s, 0] = self.last_token[s]
                positions[s, 0] = self.positions[s]
        next_tok, self.cache = self._step_jit(
            self.cache, jnp.asarray(tokens), jnp.asarray(positions))
        next_tok = np.asarray(next_tok)
        for s in range(self.num_slots):
            if s in forced:
                continue
            if not self.active[s]:
                continue
            tok = int(next_tok[s])
            rid = self.request_of_slot[s]
            self.positions[s] += 1
            self.generated[rid].append(tok)
            self.last_token[s] = tok
            self.budget[s] -= 1
            if self.budget[s] <= 0 or self.positions[s] >= self.max_seq - 1:
                self._complete(s)
        self._admit_waiting()
        return next_tok

    def _complete(self, slot: int):
        rid = self.request_of_slot.pop(slot)
        self.completions.append(Completion(rid, self.generated.pop(rid)))
        self.active[slot] = False
        self.positions[slot] = 0
        self.last_token[slot] = 0

    def step(self, n: int = 1):
        for _ in range(n):
            if self.active.any():
                self._engine_step()

    # ------------------------------------------------------- MS2M worker API
    def process(self, msg) -> None:
        """Message = one request admission + its full generation (the
        deterministic unit the MS2M log replays)."""
        p = msg.payload
        req = Request(p.get("request_id", msg.msg_id),
                      list(p.get("prompt", [p.get("token", 0)])),
                      int(p.get("max_new_tokens", 8)))
        self.submit(req)
        while req.request_id in self.generated or any(
                r.request_id == req.request_id for r in self.waiting):
            self._engine_step()
        self.last_msg_id = msg.msg_id
        self.n_processed += 1

    def state_tree(self):
        """Full checkpointable state: KV caches, the slot table, *and* the
        admitted-request log (per-slot request id + generated-so-far
        tokens), so a mid-generation checkpoint restores in-flight
        requests instead of dropping them.  The log is derived from the
        bookkeeping dicts at snapshot time — no hot-path cost.  A
        non-empty admission backlog has no array form, so checkpoints are
        only taken between admissions (the serving wrapper guarantees
        this by draining ``waiting`` before yielding control)."""
        if self.waiting:
            raise RuntimeError(
                f"{self.name}: state_tree() with {len(self.waiting)} "
                "request(s) still waiting for admission — drain the "
                "waiting queue before checkpointing")
        request = np.full(self.num_slots, -1, np.int64)
        gen_len = np.zeros(self.num_slots, np.int64)
        gen = np.zeros((self.num_slots, self.max_seq), np.int32)
        for s, rid in self.request_of_slot.items():
            toks = self.generated[rid]
            request[s] = rid
            gen_len[s] = len(toks)
            gen[s, : len(toks)] = toks
        return {
            "cache": self.cache,
            "slots": {
                "positions": self.positions.copy(),
                "active": self.active.copy(),
                "budget": self.budget.copy(),
                "last_token": self.last_token.copy(),
                "request": request,
                "gen_len": gen_len,
                "gen": gen,
            },
            "scalars": {
                "last_msg_id": np.int64(self.last_msg_id),
                "n_processed": np.int64(self.n_processed),
            },
        }

    def load_state(self, tree):
        self.cache = jax.tree.map(jnp.asarray, tree["cache"])
        slots = tree["slots"]
        self.positions = np.asarray(slots["positions"]).copy()
        self.active = np.asarray(slots["active"]).copy()
        self.budget = np.asarray(slots["budget"]).copy()
        self.last_token = np.asarray(slots["last_token"]).copy()
        self.last_msg_id = int(tree["scalars"]["last_msg_id"])
        self.n_processed = int(tree["scalars"]["n_processed"])
        self.request_of_slot = {}
        self.generated = {}
        self.waiting = []
        if "request" in slots:  # admitted-request log (older trees lack it)
            request = np.asarray(slots["request"])
            gen_len = np.asarray(slots["gen_len"])
            gen = np.asarray(slots["gen"])
            for s in np.flatnonzero(request >= 0):
                rid = int(request[s])
                self.request_of_slot[int(s)] = rid
                self.generated[rid] = [int(t)
                                       for t in gen[s, : int(gen_len[s])]]

    def state_equal(self, other, exact: bool = True) -> bool:
        if self.last_msg_id != other.last_msg_id:
            return False
        for a, b in zip(jax.tree.leaves(self.cache),
                        jax.tree.leaves(other.cache)):
            a, b = np.asarray(a), np.asarray(b)
            ok = (np.array_equal(a, b) if exact
                  else np.allclose(a, b, rtol=1e-5, atol=1e-5))
            if not ok:
                return False
        return bool(
            np.array_equal(self.positions, other.positions)
            and np.array_equal(self.active, other.active)
            and self.request_of_slot == other.request_of_slot)

    def slot_table(self) -> List[Dict[str, int]]:
        """Human-readable view of the in-flight slots (handoff telemetry)."""
        return [{"slot": s, "request_id": rid,
                 "position": int(self.positions[s]),
                 "generated": len(self.generated[rid]),
                 "budget": int(self.budget[s])}
                for s, rid in sorted(self.request_of_slot.items())]
