from repro.serving.engine import ServingEngine, Request  # noqa: F401
from repro.serving.handoff import (  # noqa: F401
    CompletionLedger,
    HashServingWorker,
    ServingHandoff,
    ServingResult,
    ServingWorker,
    run_serving_experiment,
    serving_reference_fold,
    slot_aligned_chunk_bytes,
)
