"""Logical-axis sharding rules (MaxText-style).

Model code annotates arrays with *logical* axis names; a rules table maps
logical names -> mesh axis (or None = replicated). This keeps model code
mesh-agnostic: the same model lowers on a single CPU device, a 16x16 pod,
or a 2x16x16 multi-pod mesh by swapping the rules.
"""
from repro.sharding.rules import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    logical_to_spec,
    shard_logical,
    tree_shardings,
    with_sharding_constraint_logical,
)
