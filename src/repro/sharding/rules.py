"""Logical-axis -> mesh-axis rules with best-effort divisibility fallback.

A rule maps a logical axis name to a mesh axis name (or a tuple of mesh axes,
or None).  ``logical_to_spec`` resolves a tensor's logical axes into a
``PartitionSpec`` and *drops* any assignment whose mesh-axis size does not
divide the dimension size (the "best-effort resolver").  This lets one rules
table serve all ten architectures: e.g. ``heads -> model`` applies to
codeqwen (32 heads / 16) but is silently dropped for gemma3 (8 heads), whose
config instead selects the ``seq`` attention-sharding strategy.

Dropped assignments are *recorded* (``AxisRules.dropped``) so the dry-run can
report where the baseline sharding is lossy — those become hillclimb targets.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass
class AxisRules:
    """An ordered logical-axis -> mesh-axes mapping."""

    rules: Mapping[str, MeshAxes]

    def __post_init__(self):
        self.dropped = []  # (logical_name, dim_size, mesh_axes) triples

    def get(self, name: Optional[str]) -> MeshAxes:
        if name is None:
            return None
        return self.rules.get(name, None)

    def overriding(self, **overrides: MeshAxes) -> "AxisRules":
        merged = dict(self.rules)
        merged.update(overrides)
        return AxisRules(merged)


# The production mesh axes are ("pod", "data", "model") (multi-pod) or
# ("data", "model") (single pod).  "pod" composes with "data" for batch /
# FSDP sharding; specs below name both and the resolver drops axes that are
# absent from the mesh, so the same rules serve both meshes.
DEFAULT_RULES = AxisRules(
    {
        # --- activations ---
        "batch": ("pod", "data"),
        "seq": None,  # overridden to ("model",) by the `seq` attention strategy
        "act_embed": None,
        "act_heads": "model",
        "act_kv_heads": None,
        "act_qout": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "kv_seq": "model",  # decode-time KV cache: flash-decode seq sharding
        "act_experts": "model",
        # --- params (FSDP over data; TP over model) ---
        "embed": ("pod", "data"),
        "mlp": "model",
        "qout": "model",  # fused q/k/v/o head*head_dim projections
        "kv_out": "model",
        "heads": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "rec_width": "model",  # RG-LRU / xLSTM channel dims
        "layers": None,  # scanned-layer stacking axis: never sharded
        "conv": None,
        "stats": None,
    }
)


def _mesh_axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    return size


def _present(mesh: Mesh, axes: MeshAxes) -> MeshAxes:
    """Filter out mesh axes that are not part of this mesh (e.g. 'pod')."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    kept = tuple(a for a in axes if a in mesh.axis_names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: AxisRules = DEFAULT_RULES,
    dims: Optional[Sequence[int]] = None,
) -> P:
    """Resolve logical axis names to a PartitionSpec, best-effort.

    ``dims`` (optional) enables the divisibility check; without it, rules are
    applied verbatim.  A mesh axis may be consumed by at most one dimension;
    later dims lose conflicts (first-come-first-served, like t5x).
    """
    used: set = set()
    spec = []
    for i, name in enumerate(logical_axes):
        axes = _present(mesh, rules.get(name))
        if axes is None:
            spec.append(None)
            continue
        ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
        ax_tuple = tuple(a for a in ax_tuple if a not in used)
        if not ax_tuple:
            spec.append(None)
            continue
        if dims is not None:
            size = 1
            for a in ax_tuple:
                size *= _mesh_axis_size(mesh, a)
            if size == 0 or dims[i] % size != 0:
                rules.dropped.append((name, dims[i], ax_tuple))
                spec.append(None)
                continue
        used.update(ax_tuple)
        spec.append(ax_tuple if len(ax_tuple) > 1 else ax_tuple[0])
    return P(*spec)


def shard_logical(mesh: Mesh, logical_axes, rules=DEFAULT_RULES, dims=None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, mesh, rules, dims))


def with_sharding_constraint_logical(x, logical_axes, rules=DEFAULT_RULES):
    """Apply a logical sharding constraint inside jit (mesh from context)."""
    try:
        mesh = _current_mesh()
    except RuntimeError:
        return x  # no mesh (single-device tests): no-op
    spec = logical_to_spec(logical_axes, mesh, rules, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh() -> Mesh:
    mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    # Prefer the thread-local physical mesh set by `with mesh:`.
    env_mesh = jax._src.mesh.thread_resources.env.physical_mesh  # noqa: SLF001
    if env_mesh is not None and not env_mesh.empty:
        return env_mesh
    raise RuntimeError("no mesh in context")


def tree_shardings(mesh: Mesh, tree_logical, tree_shapes=None, rules=DEFAULT_RULES):
    """Map a pytree of logical-axis tuples (+ optional shapes) to NamedShardings."""
    if tree_shapes is None:
        return jax.tree.map(
            lambda ax: shard_logical(mesh, ax, rules),
            tree_logical,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
        )
    return jax.tree.map(
        lambda ax, shp: shard_logical(mesh, ax, rules, dims=shp),
        tree_logical,
        tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )
