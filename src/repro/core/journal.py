"""Message journal: durable log enabling *exact* failure recovery.

The minimal FT flow (image restore + live-queue continuation) loses the
messages a dead worker consumed after its last checkpoint — they left the
queue but their effect died with the pod.  The journal closes that gap,
completing MS2M's recovery story:

    state(t) = fold(image_state, journal[image_marker+1 : t])

A ``JournaledQueue`` wraps a broker queue and appends every published
message to a registry-backed segment log (content-addressed, so identical
segments dedup).  ``recover()`` = pull image -> replay journal suffix ->
resume the live queue.  This is the training-fleet checkpoint/restart path
at 1000+ nodes: checkpoint interval trades registry bandwidth against
replay time via exactly Eq. 5 (cutoff.replay_time_bound).
"""
from __future__ import annotations

import json
from typing import Any, Callable, Dict, Generator, List, Optional

from repro.broker.broker import Broker, Message, MessageQueue
from repro.checkpoint.registry import Registry


class Journal:
    """Append-only message log persisted to the registry in segments."""

    def __init__(self, registry: Registry, name: str,
                 segment_size: int = 256):
        self.registry = registry
        self.name = name
        self.segment_size = segment_size
        self._buffer: List[Message] = []
        self._segments: List[str] = []  # chunk keys, in order
        self.last_id = -1

    def append(self, msg: Message):
        assert msg.msg_id == self.last_id + 1, (
            f"journal gap: {msg.msg_id} after {self.last_id}")
        self._buffer.append(msg)
        self.last_id = msg.msg_id
        if len(self._buffer) >= self.segment_size:
            self.flush()

    def flush(self):
        if not self._buffer:
            return
        blob = json.dumps(
            [(m.msg_id, m.payload, m.publish_time) for m in self._buffer]
        ).encode()
        key, _ = self.registry.store.put(blob)
        self._segments.append(key)
        self._buffer.clear()

    def replay_range(self, start_id: int, end_id: Optional[int] = None
                     ) -> List[Message]:
        """Messages with start_id <= id <= end_id (inclusive)."""
        msgs: List[Message] = []
        for key in self._segments:
            for mid, payload, t in json.loads(self.registry.store.get(key)):
                if mid >= start_id and (end_id is None or mid <= end_id):
                    msgs.append(Message(mid, payload, t))
        for m in self._buffer:
            if m.msg_id >= start_id and (end_id is None or m.msg_id <= end_id):
                msgs.append(m)
        return msgs


class JournaledQueue:
    """Publish-through wrapper: queue + journal stay in lockstep."""

    def __init__(self, broker: Broker, name: str, registry: Registry):
        self.broker = broker
        self.queue = broker.declare_queue(name)
        self.journal = Journal(registry, name)
        self.name = name

    def publish(self, payload: Any) -> Message:
        msg = self.broker.publish(self.name, payload)
        self.journal.append(msg)
        return msg


def recover_worker(api, registry: Registry, journal: Journal, tag: str,
                   make_worker: Callable[[], Any], target_node: str,
                   queue: MessageQueue, pod_name: str = "recovered"
                   ) -> Generator:
    """Cluster sub-process: restore latest image, replay the journal suffix,
    resume live consumption.  Returns the new pod; the recovered worker's
    state is the *exact* fold of the full log (tests assert equality)."""
    image_id = registry.resolve(tag)
    assert image_id is not None, f"no image tagged {tag}"
    worker = make_worker()
    # charge the pull to the node the pod recovers onto: its registry
    # link (WAN if the node is remote), its layer cache, its death abort
    meta = yield from api.pull_and_restore(image_id, worker,
                                           node_name=target_node)
    marker = int(meta.get("last_msg_id", -1))
    journal.flush()
    suffix = journal.replay_range(marker + 1)
    # replay is instantaneous in virtual time relative to service rate —
    # a real fleet replays at full step throughput (cf. batched replay)
    for m in suffix:
        if m.msg_id > worker.last_msg_id:
            worker.process(m)
    worker.skip_until = worker.last_msg_id
    pod = yield from api.create_pod(pod_name, target_node, worker, queue)
    pod.start()
    return pod
