# The paper's primary contribution: MS2M live stateful migration integrated
# with the cluster control plane, the Threshold-Based Cutoff Mechanism
# (queuing-theory bound, Eq. 5), and FCC-style registry checkpoint images —
# adapted from Kubernetes/CRIU to a JAX multi-pod fleet (see DESIGN.md §2).
from repro.core.consumer import StatefulConsumer, measure_replay_speedup  # noqa: F401
from repro.core.cutoff import (  # noqa: F401
    CutoffController,
    batched_cutoff_threshold,
    choose_adaptive_strategy,
    cutoff_threshold,
    expected_catchup_time,
    replay_time_bound,
)
from repro.core.migration import MigrationManager, MigrationReport  # noqa: F401
from repro.core.policy import MigrationEvent, MigrationPolicy  # noqa: F401
from repro.core.strategy import (  # noqa: F401
    MigrationContext,
    MigrationError,
    MigrationStrategy,
    TargetNodeLost,
    available_strategies,
    get_strategy,
    register_strategy,
    registry_entries,
)
from repro.core.orchestrator import (  # noqa: F401
    ClusterMigrationOrchestrator,
    FleetReport,
    PLACEMENT_POLICIES,
    PodMigrationSpec,
    available_placements,
    run_fleet_experiment,
)
from repro.core.workload import (  # noqa: F401
    ExperimentResult,
    HashConsumer,
    make_jax_worker_factory,
    open_loop_gaps,
    request_stream,
    run_migration_experiment,
)
