"""MS2M applied to *training* workers.

The worker's migratable state is (params, opt_state, step); the "message"
is a batch id.  Because the data pipeline is a pure function of
(seed, step) and the train step is jitted, the fold
    state_{s+1} = train_step(state_s, batch(s))
is deterministic — so a training worker migrates exactly like a serving
replica: checkpoint image + batch-id journal replay.  This is the FT story
at 1000+ nodes: preemption or straggling triggers a live migration instead
of a fleet-wide restart.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, SyntheticTokenDataset
from repro.models import transformer as T
from repro.models.common import split_params
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train import step as steplib


class TrainerWorker:
    """Processes batch-id messages; state = (params, opt, step)."""

    def __init__(self, cfg: ModelConfig, tcfg: steplib.TrainStepConfig,
                 dcfg: DataConfig, name: str = "trainer"):
        self.cfg = cfg
        self.tcfg = tcfg
        self.dcfg = dcfg
        self.name = name
        self.ds = SyntheticTokenDataset(dcfg)
        params, _ = split_params(T.init_lm(jax.random.PRNGKey(0), cfg))
        self.params = params
        self.opt_state = adamw.adamw_init(params, tcfg.opt)
        self.step = 0
        self.last_msg_id = -1
        self.n_processed = 0
        self.skip_until = -1
        self.last_loss = float("nan")
        self._fn = jax.jit(steplib.build_train_step(cfg, tcfg),
                           donate_argnums=(0, 1))

    def process(self, msg) -> None:
        batch_id = int(msg.payload.get("batch_id", msg.payload.get("token")))
        batch = jax.tree.map(jnp.asarray, self.ds.batch(batch_id))
        self.params, self.opt_state, metrics = self._fn(
            self.params, self.opt_state, batch,
            jnp.asarray(self.step, jnp.int32))
        self.step += 1
        self.last_loss = float(metrics["loss"])
        self.last_msg_id = msg.msg_id
        self.n_processed += 1

    def state_tree(self) -> Dict[str, Any]:
        # snapshot to host memory: the train step DONATES its input buffers,
        # so device arrays referenced here would be invalidated by the next
        # step (CRIU would likewise dump a point-in-time copy)
        host = lambda t: jax.tree.map(lambda x: np.array(x), t)
        return {
            "params": host(self.params),
            "opt": host(self.opt_state),
            "scalars": {
                "step": np.int64(self.step),
                "last_msg_id": np.int64(self.last_msg_id),
                "n_processed": np.int64(self.n_processed),
            },
        }

    def load_state(self, tree: Dict[str, Any]):
        self.params = jax.tree.map(jnp.asarray, tree["params"])
        self.opt_state = jax.tree.map(jnp.asarray, tree["opt"])
        self.step = int(tree["scalars"]["step"])
        self.last_msg_id = int(tree["scalars"]["last_msg_id"])
        self.n_processed = int(tree["scalars"]["n_processed"])

    def state_equal(self, other: "TrainerWorker", exact: bool = True) -> bool:
        if self.step != other.step or self.last_msg_id != other.last_msg_id:
            return False
        for a, b in zip(jax.tree.leaves(self.params),
                        jax.tree.leaves(other.params)):
            a, b = np.asarray(a), np.asarray(b)
            if exact and not np.array_equal(a, b):
                return False
            if not exact and not np.allclose(a, b, rtol=1e-5, atol=1e-6):
                return False
        return True
