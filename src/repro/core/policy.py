"""Declarative migration configuration and the structured trace stream.

``MigrationPolicy`` is the single knob surface for every migration
strategy: instead of threading ``precopy=``, ``precopy_max_rounds=``,
``batched_replay=``, ``replay_speedup=``, ``manager_kwargs={...}`` through
constructors and harnesses, callers build one policy value and hand it to
``MigrationManager`` / ``ClusterMigrationOrchestrator`` /
``run_*_experiment`` (all of which still accept the legacy kwargs and fold
them into a policy for backward compatibility).

``MigrationEvent`` is the structured trace record: every phase boundary,
pre-copy round, cutoff firing and adaptive decision is appended to
``MigrationReport.events``, and the legacy ``report.phases`` dict is now a
view derived from the event stream rather than ad-hoc bookkeeping.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class MigrationPolicy:
    """Everything a strategy may consult about *how* to migrate.

    Strategy selection stays separate (the registry name passed to
    ``migrate(...)``); the policy only parameterizes the phase primitives
    the chosen strategy composes.
    """

    # -- replay discipline ----------------------------------------------------
    batched_replay: bool = False     # target replays via the batched path
    replay_speedup: float = 1.0      # measured mu_replay / mu_target (>= 1)

    # -- iterative pre-copy transfer engine -----------------------------------
    precopy: bool = False            # opt-in for strategies with "policy" mode
    precopy_max_rounds: int = 5
    precopy_converge_ratio: float = 0.9  # stop when dirty >= ratio * previous
    precopy_min_dirty: int = 0       # stop when a round dirties <= this many

    # -- checkpoint data path -------------------------------------------------
    # delta codec for pre-copy rounds: "none" | "xor_rle" | "int8" | "auto",
    # or a {tree name: codec} dict (the registry resolves it against each
    # leaf's dtype/parent; lossy codecs are followed by a lossless
    # exact-flush push before cutover).  NOTE: the cluster migration path
    # pushes one tree named "state", so a dict here must key on "state" —
    # other keys only matter for direct multi-tree Registry pushes
    compression: Any = "none"

    # -- adaptive strategy selection (ms2m_adaptive) --------------------------
    adaptive_rho_max: float = 0.9    # lam/mu above this => live sync unstable
    t_replay_max: float = 45.0       # replay bound when no CutoffController

    # -- crash recovery (orchestrator retry loop) -----------------------------
    # a failed migration is rolled back (source serving again) and, when
    # attempts remain, re-placed by the placement policy with the failed
    # target node excluded.  max_attempts=1 == the legacy fail-once
    # behaviour
    max_attempts: int = 1
    retry_backoff_s: float = 2.0     # wait between attempts

    def __post_init__(self):
        object.__setattr__(self, "replay_speedup",
                           max(1.0, self.replay_speedup))
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.retry_backoff_s < 0:
            raise ValueError("retry_backoff_s must be >= 0")
        from repro.checkpoint.codecs import validate_compression
        validate_compression(self.compression)

    def evolve(self, **changes: Any) -> "MigrationPolicy":
        return dataclasses.replace(self, **changes)

    @staticmethod
    def resolve(policy: Optional["MigrationPolicy"] = None,
                **legacy: Any) -> "MigrationPolicy":
        """Fold legacy keyword knobs into a policy.

        ``legacy`` values of ``None`` mean "not specified" and leave the
        base policy untouched; anything else overrides it — this is the
        compat shim behind every ``**manager_kwargs``-era call site.
        """
        base = policy or MigrationPolicy()
        changes = {k: v for k, v in legacy.items() if v is not None}
        if not changes:
            return base
        unknown = set(changes) - {f.name for f in dataclasses.fields(base)}
        if unknown:
            raise TypeError(
                f"unknown migration policy knob(s): {sorted(unknown)}")
        return dataclasses.replace(base, **changes)


@dataclasses.dataclass
class MigrationEvent:
    """One structured trace record emitted during a migration."""

    t: float        # virtual time of the event
    kind: str       # "phase" | "precopy_round" | "cutoff_fired" | ...
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def row(self) -> Dict[str, Any]:
        return {"t": round(self.t, 6), "kind": self.kind, **self.data}


@dataclasses.dataclass
class MigrationReport:
    strategy: str
    t_start: float
    t_end: float = 0.0
    downtime: float = 0.0
    checkpoint_marker: int = -1
    cutoff_id: Optional[int] = None
    cutoff_fired: bool = False
    replayed_messages: int = 0
    image_id: str = ""
    image_written_bytes: int = 0
    image_deduped_bytes: int = 0
    # raw-vs-wire accounting across every push of this migration: raw is
    # the dirty bytes a codec-less transfer would move, wire is what the
    # delta codecs actually put on the link
    image_raw_bytes: int = 0
    image_wire_bytes: int = 0
    compression: str = "none"
    state_verified: Optional[bool] = None
    # which attempt (1-based) this report describes: > 1 means earlier
    # attempts failed, were rolled back and retried by the orchestrator
    attempts: int = 1
    # pre-copy telemetry: per-round raw/wire bytes / dirty-message counts
    # (index 0 = the initial full push)
    precopy_rounds: int = 0
    precopy_round_bytes: List[int] = dataclasses.field(default_factory=list)
    precopy_round_wire_bytes: List[int] = dataclasses.field(
        default_factory=list)
    precopy_round_dirty: List[int] = dataclasses.field(default_factory=list)
    # structured trace stream; ``phases`` below is derived from it
    events: List[MigrationEvent] = dataclasses.field(default_factory=list)

    @property
    def migration_time(self) -> float:
        return self.t_end - self.t_start

    @property
    def recovered(self) -> bool:
        """True when this migration succeeded only after at least one
        rolled-back attempt."""
        return self.attempts > 1

    @property
    def wire_reduction(self) -> float:
        """raw / wire bytes across all pushes (1.0 = no codec win)."""
        if self.image_wire_bytes <= 0:
            return 1.0
        return self.image_raw_bytes / self.image_wire_bytes

    def emit(self, kind: str, t: float, **data: Any) -> MigrationEvent:
        ev = MigrationEvent(t=t, kind=kind, data=data)
        self.events.append(ev)
        return ev

    @property
    def phases(self) -> Dict[str, float]:
        """Per-phase durations, aggregated from the event stream (same
        shape the old ad-hoc ``phases`` dict had)."""
        out: Dict[str, float] = {}
        for ev in self.events:
            if ev.kind == "phase":
                name = ev.data["phase"]
                out[name] = out.get(name, 0.0) + ev.data["duration"]
        return out

    def event_rows(self) -> List[Dict[str, Any]]:
        return [ev.row() for ev in self.events]
