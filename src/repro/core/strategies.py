"""The registered migration strategies (paper §III, Figs. 1-4, plus two
beyond-paper schemes), in order:

  Strategy 0  stop_and_copy     — UMS-style baseline: pause -> checkpoint ->
                                  image -> push -> pull -> restore -> switch.
                                  Downtime == the whole migration (Fig. 5).
  Strategy 1  ms2m_individual   — Fig. 2: secondary queue attached, source
                                  keeps serving; target restores from the
                                  registry image and replays the mirrored log
                                  until *synchronized*, then a short cutover.
                                  Downtime == cutover only.
  Strategy 2  ms2m_cutoff       — Fig. 3: same, plus the Threshold-Based
                                  Cutoff Mechanism: when T_accum exceeds
                                  Eq. 5's T_cutoff, the source is stopped and
                                  the remaining (bounded) log is replayed;
                                  bounded replay <= T_replay_max by
                                  construction.
  Strategy 3  ms2m_statefulset  — Fig. 4: sticky identity forces
                                  stop-before-create: checkpoint+push live,
                                  then stop source, release identity, create
                                  target, restore, replay to the *cutoff
                                  message id*, switch.
  Strategy 4  ms2m_precopy      — beyond-paper (MOSE/SHADOW-style iterative
                                  pre-copy): the IterativePrecopyTransfer
                                  engine always on, so the final replay log
                                  is bounded by ONE delta round's traffic.
                                  The same engine is a policy opt-in
                                  (``MigrationPolicy(precopy=True)``) for
                                  strategies 1-3.
  Strategy 5  ms2m_adaptive     — beyond-paper: picks strategy 1, 2 or 4 at
                                  migrate time from observed lam/mu and
                                  state-size telemetry (registry-only: the
                                  manager core is untouched).
  Strategy 6  serving_handoff   — beyond-paper (SHADOW-style): zero-downtime
                                  serving migration — KV-cache lanes + the
                                  admitted-request log pre-copy in per-slot
                                  chunks, dual-serving window, per-slot
                                  in-flight handoff with exactly-once
                                  completion.  Defined (and registered) in
                                  ``repro.serving.handoff``; imported below
                                  for its registration side effect.

Replay correctness: message ids are totally ordered per queue; the target
skips ids <= the checkpoint marker and replays the rest through the same
jitted fold the source used => bit-exact state (verified by tests and by
every benchmark run via ``verify_against_reference``).
"""
from __future__ import annotations

from typing import Generator, Optional

from repro.core.cutoff import choose_adaptive_strategy
from repro.core.strategy import (
    CatchupDiscipline,
    LiveSyncCatchup,
    MigrationContext,
    MigrationStrategy,
    StopThenReplayCatchup,
    ThresholdCutoffCatchup,
    get_strategy,
    register_strategy,
)


# ---------------------------------------------------------------------------
# Strategy 0: stop-and-copy (baseline; paper Fig. 5)
# ---------------------------------------------------------------------------

@register_strategy("stop_and_copy")
class StopAndCopy(MigrationStrategy):
    """Strategy 0 (Fig. 5): UMS-style stop-and-copy baseline — downtime
    spans the whole checkpoint/push/pull/restore pipeline (~49 s)."""

    def run(self, ctx: MigrationContext) -> Generator:
        t = ctx.api.timings
        rep = ctx.report
        down0 = ctx.sim.now
        ctx.source.pause()  # downtime starts immediately

        push = yield from ctx.transfer(
            False, "", f"{ctx.primary_queue}-sac{ctx.n}")

        target = yield from ctx.restore_target(
            push, ctx.broker.queues[ctx.primary_queue], replay=False)

        t0 = ctx.sim.now
        ctx.ensure_target(target)  # never delete the source for a dead target
        yield from ctx.api.delete_pod(ctx.source.name)
        yield t.route_switch_s
        ctx.ensure_target(target)
        target.start()
        ctx.phase("cutover", t0)

        rep.downtime = ctx.sim.now - down0
        ctx.finish(target)
        return rep, target


# ---------------------------------------------------------------------------
# Strategies 1/2/4: the live MS2M family — one pipeline, three catch-up /
# transfer configurations
# ---------------------------------------------------------------------------

@register_strategy("ms2m_individual")
class MS2MIndividual(MigrationStrategy):
    """Strategy 1 (Fig. 2): live sync via a mirrored secondary queue —
    downtime is the short cutover only (pre-copy opt-in by policy)."""

    def use_precopy(self, ctx: MigrationContext) -> bool:
        return ctx.policy.precopy

    def make_catchup(self, ctx: MigrationContext) -> CatchupDiscipline:
        return LiveSyncCatchup()

    def run(self, ctx: MigrationContext) -> Generator:
        t = ctx.api.timings
        rep = ctx.report
        # build the discipline before the mirror attaches: a misconfigured
        # one (e.g. cutoff without a controller) must fail with no
        # secondary left behind
        disc = self.make_catchup(ctx)
        sec = ctx.attach_secondary()
        # the catch-up discipline arms when accumulation starts: a cutoff
        # deadline is measured from this instant, even mid-transfer
        disc.arm(ctx)
        try:
            push = yield from ctx.transfer(
                self.use_precopy(ctx),
                f"{ctx.primary_queue}-pre{ctx.n}",
                f"{ctx.primary_queue}-ms2m{ctx.n}")

            target = yield from ctx.restore_target(push, sec, replay=True)

            # -- catch-up: target replays the mirror, source keeps serving --
            t0 = ctx.sim.now
            base_processed = target.worker.n_processed
            target.start()
            yield from disc.catchup(ctx, target)
            ctx.phase("message_replay", t0)

            # -- cutover ----------------------------------------------------
            t0 = ctx.sim.now
            down0 = disc.begin_cutover(ctx)
            yield t.cutover_coord_s
            # drain in-flight mirrored messages up to the source's final state
            yield from ctx.wait(
                ctx.drain_condition(target, ctx.source.worker.last_msg_id))
            ctx.switch_to_primary(target)
            target.processing_ms = ctx.source.processing_ms  # service rate
            yield t.route_switch_s
            rep.downtime = ctx.sim.now - down0
            ctx.phase("cutover", t0)

            yield from ctx.teardown_source()

            rep.replayed_messages = target.worker.n_processed - base_processed
            ctx.finish(target)
            return rep, target
        finally:
            ctx.cleanup()


@register_strategy("ms2m_cutoff")
class MS2MCutoff(MS2MIndividual):
    """Strategy 2 (Fig. 3, Eq. 5): live sync bounded by the Threshold-Based
    Cutoff — replay capped at T_replay_max by construction."""

    wants_cutoff = True

    def make_catchup(self, ctx: MigrationContext) -> CatchupDiscipline:
        assert ctx.cutoff is not None, "ms2m_cutoff needs a CutoffController"
        return ThresholdCutoffCatchup(ctx.cutoff.threshold())


@register_strategy("ms2m_precopy")
class MS2MPrecopy(MS2MIndividual):
    """Strategy 4 (beyond paper): iterative delta pre-copy always on —
    full push once, then fingerprint-diffed, codec-compressed delta rounds
    until the dirty set converges; the replay log is one round's traffic."""

    def use_precopy(self, ctx: MigrationContext) -> bool:
        return True


# ---------------------------------------------------------------------------
# Strategy 3: MS2M for StatefulSet pods (paper Fig. 4)
# ---------------------------------------------------------------------------

@register_strategy("ms2m_statefulset")
class MS2MStatefulSet(MigrationStrategy):
    """Strategy 3 (Fig. 4): sticky identity forces stop-before-create —
    checkpoint+push live, stop source, release identity, restore, bounded
    replay to the cutoff message id."""

    handles_identity = True

    def run(self, ctx: MigrationContext) -> Generator:
        t = ctx.api.timings
        rep = ctx.report
        identity = ctx.identity or f"sts-{ctx.source.name}"
        ctx.identity = identity  # rollback re-claims it for the source
        sec = ctx.attach_secondary()
        try:
            # with precopy, BOTH stop-phase costs of Fig. 4 shrink: the
            # final marker is late (bounded replay) and the target node's
            # layer cache is warm (near-zero pull)
            push = yield from ctx.transfer(
                ctx.policy.precopy,
                f"{ctx.primary_queue}-sts-pre{ctx.n}",
                f"{ctx.primary_queue}-sts{ctx.n}")

            # -- stop source after the checkpoint-transfer phase (Fig. 4) --
            down0 = ctx.sim.now
            ctx.source.pause()
            rep.cutoff_id = ctx.source.worker.last_msg_id  # cutoff message id
            disc = StopThenReplayCatchup(rep.cutoff_id)

            t0 = ctx.sim.now
            yield from ctx.api.delete_pod(ctx.source.name,
                                          statefulset_identity=identity)
            ctx.phase("identity_release", t0)

            target = yield from ctx.restore_target(push, sec, replay=True,
                                                   identity=identity)

            # -- replay up to the cutoff message id -------------------------
            t0 = ctx.sim.now
            base_processed = target.worker.n_processed
            target.start()
            yield from disc.catchup(ctx, target)
            ctx.phase("message_replay", t0)

            t0 = ctx.sim.now
            ctx.switch_to_primary(target)
            target.processing_ms = ctx.source.processing_ms
            yield t.route_switch_s
            rep.downtime = ctx.sim.now - down0
            ctx.phase("cutover", t0)

            rep.replayed_messages = target.worker.n_processed - base_processed
            ctx.finish(target)
            return rep, target
        finally:
            ctx.cleanup()


# ---------------------------------------------------------------------------
# Strategy 5: adaptive scheme selection (beyond paper)
# ---------------------------------------------------------------------------

@register_strategy("ms2m_adaptive")
class MS2MAdaptive(MigrationStrategy):
    """Strategy 5 (beyond paper): picks individual / cutoff / pre-copy at
    migrate time from observed lam/mu and state-size telemetry.

    The inputs are what the Migration Manager can already see:

      * lam/mu — the CutoffController's online estimates (or the arrival
        throughput observed on the primary queue when none is wired);
      * the source's state size vs. registry bandwidth — whether transfer
        time is byte-dominated (the pre-copy regime) or dominated by fixed
        control-plane costs.

    The decision math lives in ``cutoff.choose_adaptive_strategy`` (pure,
    unit-testable); this class only gathers inputs and delegates the whole
    pipeline to the chosen registered strategy — zero manager-core edits,
    which is exactly what the registry exists to prove.
    """

    wants_cutoff = True

    def choose(self, ctx: MigrationContext) -> tuple:
        lam, mu = ctx.observed_rates()
        t = ctx.api.timings
        fixed_s = (t.checkpoint_s + t.image_build_s + t.push_base_s
                   + t.pod_create_s + t.pull_base_s + t.restore_s)
        # push (source leg) + pull (target leg), each over its own
        # topology link class; identical legs keep the legacy 2x/bw form
        # so flat-preset decisions stay bit-identical to the seed
        topo = ctx.api.topology
        bw_push = topo.registry_capacity_Bps(ctx.source.node.name)
        bw_pull = topo.registry_capacity_Bps(ctx.target_node)
        nbytes = ctx.state_nbytes()
        if bw_push == bw_pull:
            wire_s = 2.0 * nbytes / bw_push
        else:
            wire_s = nbytes / bw_push + nbytes / bw_pull
        t_replay_max = (ctx.cutoff.t_replay_max if ctx.cutoff is not None
                        else ctx.policy.t_replay_max)
        return choose_adaptive_strategy(
            lam, mu, fixed_s=fixed_s, wire_s=wire_s,
            t_replay_max=t_replay_max, rho_max=ctx.policy.adaptive_rho_max)

    def run(self, ctx: MigrationContext) -> Generator:
        chosen, why = self.choose(ctx)
        ctx.emit("adaptive_choice", chosen=chosen, **why)
        if chosen == "ms2m_cutoff" and ctx.cutoff is None:
            # no controller wired: synthesize one from the observed rates so
            # the threshold discipline still has its Eq. 5 inputs
            from repro.core.cutoff import CutoffController
            lam, mu = ctx.observed_rates()
            ctx.cutoff = CutoffController(
                t_replay_max=ctx.policy.t_replay_max,
                mu_fallback=mu, lam_fallback=max(lam, 1e-9))
        delegate = get_strategy(chosen)()
        result = yield from delegate.run(ctx)
        return result


# Strategy 6 lives with the serving subsystem; importing it here registers
# it alongside the built-ins (the manager core stays untouched).
try:
    from repro.serving.handoff import ServingHandoff  # noqa: E402,F401
except ImportError:
    # repro.serving.handoff is itself mid-import (its import of the
    # registry layer runs this module via the package __init__); its
    # @register_strategy decorator runs when that import resumes.
    pass
