"""Elastic scaling of partitioned stateful services (paper §III-C).

The paper notes that stateful K8s services partition work: "a message queue
might be partitioned based on certain keys, with each partition assigned to
a specific instance", often with a dedicated queue per instance.  That
structure is what makes *elastic scaling* an MS2M problem: scaling out
moves bucket ownership, and the new owner must reconstruct each moved
bucket's state — which is, again, a fold of that bucket's message sub-log.

  scale_out:  new instance claims buckets -> bootstraps them by replaying
              the per-bucket journal -> router flips ownership.  Only the
              moved buckets pause (bounded by Eq. 5 applied per bucket);
              the rest of the fleet never stops.

``BucketedConsumer`` keeps one fold per bucket, so bucket state is exactly
separable (the property real partitioned services have by construction).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Generator, List, Optional

import numpy as np

from repro.broker.broker import Broker, Message
from repro.checkpoint.registry import Registry
from repro.core.journal import Journal


def bucket_of(key: int, num_buckets: int) -> int:
    return int(np.uint64(key * 2654435761) % np.uint64(num_buckets))


class BucketedConsumer:
    """Per-bucket fold state (drop-in worker for Pod)."""

    def __init__(self, buckets: List[int], num_buckets: int,
                 name: str = "bucketed"):
        self.num_buckets = num_buckets
        self.owned = set(buckets)
        self.digests: Dict[int, np.uint64] = {
            b: np.uint64(1469598103934665603) for b in buckets}
        self.counts: Dict[int, int] = {b: 0 for b in buckets}
        self.last_msg_id = -1
        self.n_processed = 0
        self.skip_until = -1
        self.name = name

    def process(self, msg) -> None:
        key = int(msg.payload["key"])
        b = bucket_of(key, self.num_buckets)
        if b in self.owned:
            with np.errstate(over="ignore"):
                x = np.uint64(msg.payload["token"]) ^ np.uint64(msg.msg_id + 1)
                self.digests[b] = np.uint64(
                    (self.digests[b] ^ x) * np.uint64(1099511628211))
            self.counts[b] += 1
            self.n_processed += 1
        self.last_msg_id = msg.msg_id

    # bucket state transfer ---------------------------------------------
    def export_buckets(self, buckets: List[int]) -> Dict[int, tuple]:
        return {b: (np.uint64(self.digests[b]), self.counts[b])
                for b in buckets if b in self.owned}

    def drop_buckets(self, buckets: List[int]):
        for b in buckets:
            self.owned.discard(b)
            self.digests.pop(b, None)
            self.counts.pop(b, None)

    def adopt_buckets(self, states: Dict[int, tuple]):
        for b, (digest, count) in states.items():
            self.owned.add(b)
            self.digests[b] = np.uint64(digest)
            self.counts[b] = int(count)

    def state_tree(self):
        items = sorted(self.digests.items())
        return {
            "buckets": np.asarray([b for b, _ in items], np.int64),
            "digests": np.asarray([d for _, d in items], np.uint64),
            "counts": np.asarray([self.counts[b] for b, _ in items], np.int64),
            "scalars": {"last_msg_id": np.int64(self.last_msg_id),
                        "n_processed": np.int64(self.n_processed)},
        }

    def load_state(self, tree):
        self.owned = set(int(b) for b in tree["buckets"])
        self.digests = {int(b): np.uint64(d)
                        for b, d in zip(tree["buckets"], tree["digests"])}
        self.counts = {int(b): int(c)
                       for b, c in zip(tree["buckets"], tree["counts"])}
        self.last_msg_id = int(tree["scalars"]["last_msg_id"])
        self.n_processed = int(tree["scalars"]["n_processed"])


class PartitionedService:
    """Router + N bucketed instances with dedicated queues + journals."""

    def __init__(self, cluster, name: str, num_buckets: int = 64,
                 num_instances: int = 2):
        self.cluster = cluster
        self.name = name
        self.num_buckets = num_buckets
        self.ownership: Dict[int, int] = {}  # bucket -> instance idx
        self.queues: List = []
        self.journals: List[Journal] = []
        self.pods: List = []
        self.workers: List[BucketedConsumer] = []
        self._n = num_instances
        for i in range(num_instances):
            self._add_instance_structs(i)
        for b in range(num_buckets):
            self.ownership[b] = b % num_instances

    def _add_instance_structs(self, i: int):
        q = self.cluster.broker.declare_queue(f"{self.name}.p{i}")
        self.queues.append(q)
        self.journals.append(Journal(self.cluster.registry, f"{self.name}.p{i}"))

    def boot(self) -> Generator:
        for i in range(self._n):
            buckets = [b for b, o in self.ownership.items() if o == i]
            worker = BucketedConsumer(buckets, self.num_buckets,
                                      name=f"{self.name}-{i}")
            node = f"node{i % len(self.cluster.api.nodes)}"
            pod = yield from self.cluster.api.create_pod(
                f"{self.name}-{i}", node, worker, self.queues[i],
                statefulset_identity=f"{self.name}-{i}")
            pod.start()
            self.pods.append(pod)
            self.workers.append(worker)

    # routing ---------------------------------------------------------------
    def publish(self, key: int, token: int):
        b = bucket_of(key, self.num_buckets)
        i = self.ownership[b]
        msg = self.cluster.broker.publish(f"{self.name}.p{i}",
                                          {"key": key, "token": token})
        # per-instance journals are independent logs (ids are per-queue)
        self.journals[i].append(msg)
        return msg

    # elastic scale-out -------------------------------------------------------
    def scale_out(self, target_node: str) -> Generator:
        """Add instance N: it claims ~1/(N+1) of every instance's buckets,
        bootstrapped by direct bucket-state transfer from the donors
        (per-bucket folds are separable), then the router flips ownership.
        Donors keep serving untouched buckets throughout."""
        api = self.cluster.api
        new_idx = len(self.pods)
        self._add_instance_structs(new_idx)
        # choose buckets to move (round-robin steal)
        moving: Dict[int, List[int]] = {}
        for b in range(self.num_buckets):
            if b % (new_idx + 1) == new_idx:
                donor = self.ownership[b]
                moving.setdefault(donor, []).append(b)
        worker = BucketedConsumer([], self.num_buckets,
                                  name=f"{self.name}-{new_idx}")
        pod = yield from api.create_pod(
            f"{self.name}-{new_idx}", target_node, worker,
            self.queues[new_idx],
            statefulset_identity=f"{self.name}-{new_idx}")
        t = api.timings
        for donor, buckets in moving.items():
            moved = set(buckets)
            # 1) flip the router first: new arrivals buffer in the new
            #    queue (its pod is not started yet), closing the race
            for b in buckets:
                self.ownership[b] = new_idx
            yield t.route_switch_s
            # 2) drain the donor's backlog + in-flight message for moved
            #    buckets — event-driven, not a busy-poll [SIM004]: wait on
            #    the donor's on_processed events and re-check after each,
            #    so the drain contributes zero sim events beyond the
            #    donor's own service completions
            donor_q = self.queues[donor]
            donor_pod = self.pods[donor]

            def _moved_pending() -> bool:
                if any(bucket_of(int(m.payload["key"]), self.num_buckets)
                       in moved for m in donor_q._items):
                    return True
                inflight = donor_pod.in_flight
                return (inflight is not None
                        and bucket_of(int(inflight.payload["key"]),
                                      self.num_buckets) in moved)

            while _moved_pending():
                drained = api.sim.condition(f"{self.name}:drain")

                def _on_proc(_pod, _msg, cond=drained):
                    cond.trigger()

                donor_pod.add_on_processed(_on_proc)
                try:
                    yield drained
                finally:
                    donor_pod.remove_on_processed(_on_proc)
            # 3) transfer the (separable) bucket folds
            states = self.workers[donor].export_buckets(buckets)
            self.workers[donor].drop_buckets(buckets)
            worker.adopt_buckets(states)
        pod.start()
        self.pods.append(pod)
        self.workers.append(worker)
        return pod

    # verification ------------------------------------------------------------
    def reference_fold(self, published: List[tuple]) -> Dict[int, np.uint64]:
        """Fold every published (queue_msg_id, key, token) per bucket."""
        digests = {b: np.uint64(1469598103934665603)
                   for b in range(self.num_buckets)}
        for msg_id, key, token in published:
            b = bucket_of(key, self.num_buckets)
            with np.errstate(over="ignore"):
                x = np.uint64(token) ^ np.uint64(msg_id + 1)
                digests[b] = np.uint64((digests[b] ^ x) * np.uint64(1099511628211))
        return digests
