"""Cluster-scale migration orchestration (beyond paper §III: the paper
migrates one pod at a time; real StatefulSets migrate many replicas).

The ``ClusterMigrationOrchestrator`` drives N migrations through the same
strategy registry the MigrationManager uses, three ways:

  * ``migrate_fleet``        — parallel individual-pod migrations with a
                               configurable concurrency limit (a semaphore
                               over migration processes: excess specs queue
                               and start as slots free up);
  * ``rolling_statefulset``  — one replica at a time with sticky-identity
                               handoff (ms2m_statefulset per replica), the
                               Kubernetes rolling-update discipline;
  * ``drain_node``           — evacuate every pod off a node (maintenance /
                               pre-failure drain), auto-detecting
                               StatefulSet identities and spreading targets
                               over the remaining alive nodes.

Every migration runs inside a guard process, so one failing spec (e.g. a
target node that died mid-fleet) is recorded in ``FleetReport.failures``
instead of aborting the whole fleet.  Per-pod ``MigrationReport``s are
aggregated into a ``FleetReport``; the per-queue MigrationManagers are
cached so repeated migrations of the same lineage reuse one manager (which
is exactly the scenario that used to leak ``on_processed`` callbacks — see
migration.py).  Migration behaviour is configured with one declarative
``MigrationPolicy`` (fleet-wide on the orchestrator, overridable per spec);
the legacy ``manager_kwargs`` dict is still accepted and folded in.

``run_fleet_experiment`` is the workload harness: N queues x N Poisson
producers x N consumer pods, orchestrated migration, then per-pod
verification against an independent reference fold (sets
``MigrationReport.state_verified``).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Callable, Dict, Generator, List, Optional, Union

import numpy as np

from repro.cluster.cluster import APIServer, Cluster, Node, Pod, TimingConstants
from repro.cluster.sim import Condition, Interrupt
from repro.core.cutoff import CutoffController
from repro.core.migration import MigrationManager, MigrationReport
from repro.core.policy import MigrationPolicy
from repro.core.strategy import (MigrationError, get_strategy,
                                 worker_state_nbytes)


@dataclasses.dataclass
class PodMigrationSpec:
    """One pod to move: where from is implied by the pod, where to is not.

    ``target_node=None`` defers target selection to the orchestrator's
    placement policy, resolved when the spec actually starts (so the score
    sees the link load of the migrations already in flight)."""
    pod: Pod
    queue: str                       # the pod's primary queue name
    target_node: Optional[str] = None
    strategy: str = "ms2m_individual"
    identity: Optional[str] = None   # StatefulSet identity to hand off
    policy: Optional[MigrationPolicy] = None  # overrides the fleet policy


# ---------------------------------------------------------------------------
# Placement policies (target-node selection)
# ---------------------------------------------------------------------------

def make_round_robin_placement(api: APIServer,
                               inflight: Dict[str, int]) -> Callable[
        [Pod, List[Node]], str]:
    """The legacy default: blind rotation over the candidate nodes."""
    rr = itertools.count()

    def pick(pod: Pod, candidates: List[Node]) -> str:
        return candidates[next(rr) % len(candidates)].name

    return pick


def make_topology_aware_placement(api: APIServer,
                                  inflight: Dict[str, int]) -> Callable[
        [Pod, List[Node]], str]:
    """Score candidates by (zone distance x estimated wire bytes, current
    registry-link load), cheapest first.

    The distance term counts both legs the migration's bytes ride — the
    pull from the registry to the candidate and the affinity to the
    source's zone — times the pod's state size (the wire-byte estimate).
    Ties break lexicographically on the candidate's registry-link load —
    bytes still in flight first, then active flows (distinct units:
    summing them would let one in-flight byte outweigh a whole flow) —
    then occupancy (pods already there plus ``inflight`` migrations
    targeting it), then name (deterministic)."""
    topo = api.topology

    def pick(pod: Pod, candidates: List[Node]) -> str:
        src_zone = topo.zone(pod.node.name)
        dist = {}
        for node in candidates:
            zone = topo.zone(node.name)
            dist[node.name] = (topo.zone_distance(topo.registry_zone, zone)
                               + topo.zone_distance(src_zone, zone))
        # the byte estimate scales the distance term; when every candidate
        # is equidistant it cannot change the argmin, so skip measuring
        # the state entirely
        est_bytes = (max(1, worker_state_nbytes(pod.worker))
                     if len(set(dist.values())) > 1 else 1)

        def score(node: Node):
            link = topo.registry_link(node.name)
            return (dist[node.name] * est_bytes,
                    link.queued_bytes, link.n_flows,
                    len(node.pods) + inflight.get(node.name, 0), node.name)

        return min(candidates, key=score).name

    return pick


PLACEMENT_POLICIES: Dict[str, Callable[[APIServer, Dict[str, int]],
                                       Callable]] = {
    "round_robin": make_round_robin_placement,
    "topology": make_topology_aware_placement,
}


def available_placements() -> List[str]:
    return sorted(PLACEMENT_POLICIES)


def resolve_placement(placement: Union[str, Callable, None],
                      api: APIServer,
                      inflight: Optional[Dict[str, int]] = None
                      ) -> Callable[[Pod, List[Node]], str]:
    """None -> the topology-aware default; a name -> the registered
    factory (called with the api and the orchestrator's in-flight target
    counts); a callable -> used as-is (``pick(pod, candidates) -> str``)."""
    if placement is None:
        placement = "topology"
    if callable(placement):
        return placement
    try:
        factory = PLACEMENT_POLICIES[placement]
    except KeyError:
        raise ValueError(
            f"unknown placement policy {placement!r}; "
            f"available: {available_placements()}") from None
    return factory(api, inflight if inflight is not None else {})


@dataclasses.dataclass
class FleetReport:
    """Aggregate of N per-pod MigrationReports."""
    t_start: float
    t_end: float = 0.0
    reports: List[MigrationReport] = dataclasses.field(default_factory=list)
    targets: List[Pod] = dataclasses.field(default_factory=list)
    peak_concurrency: int = 0
    # specs whose migration raised (error isolated, fleet kept going)
    failures: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    # per-link byte/flow telemetry of the topology the fleet ran over
    network: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def n_migrated(self) -> int:
        return len(self.reports)

    @property
    def n_failed(self) -> int:
        return len(self.failures)

    @property
    def span(self) -> float:
        """Wall-clock (virtual) time from first start to last completion."""
        return self.t_end - self.t_start

    @property
    def max_downtime(self) -> float:
        return max((r.downtime for r in self.reports), default=0.0)

    @property
    def total_downtime(self) -> float:
        return sum(r.downtime for r in self.reports)

    @property
    def raw_bytes_total(self) -> int:
        """Dirty bytes a codec-less transfer would have moved, fleet-wide."""
        return sum(r.image_raw_bytes for r in self.reports)

    @property
    def wire_bytes_total(self) -> int:
        """Encoded bytes the delta codecs actually put on the wire."""
        return sum(r.image_wire_bytes for r in self.reports)

    @property
    def wire_reduction(self) -> float:
        wire = self.wire_bytes_total
        return self.raw_bytes_total / wire if wire > 0 else 1.0

    @property
    def attempts(self) -> int:
        """Migration attempts fleet-wide, successes and failures included
        (== n_migrated + n_failed when no retries happened)."""
        return (sum(r.attempts for r in self.reports)
                + sum(f.get("attempts", 1) for f in self.failures))

    @property
    def n_recovered(self) -> int:
        """Migrations that completed only after >= 1 rolled-back attempt."""
        return sum(1 for r in self.reports if r.attempts > 1)

    @property
    def all_verified(self) -> Optional[bool]:
        """True/False once every report has been verified; None while any
        report is unverified (or the fleet is empty) — 'not checked' must
        not read as either success or state divergence."""
        if not self.reports or any(r.state_verified is None
                                   for r in self.reports):
            return None
        return all(r.state_verified for r in self.reports)

    def downtime_by_strategy(self) -> Dict[str, Dict[str, float]]:
        """Per-strategy downtime breakdown (a fleet can mix strategies —
        e.g. a drain moving sticky replicas via ms2m_statefulset)."""
        out: Dict[str, Dict[str, float]] = {}
        for r in self.reports:
            s = out.setdefault(r.strategy,
                               {"n": 0, "max": 0.0, "total": 0.0})
            s["n"] += 1
            s["max"] = max(s["max"], r.downtime)
            s["total"] += r.downtime
        for s in out.values():
            s["mean"] = round(s["total"] / s["n"], 3)
            s["max"] = round(s["max"], 3)
            s["total"] = round(s["total"], 3)
        return out

    def row(self) -> Dict[str, Any]:
        from repro.analysis.stats import summarize_spans

        downtime_pcts = summarize_spans([r.downtime for r in self.reports])
        return {
            "n_migrated": self.n_migrated,
            "n_failed": self.n_failed,
            "span": round(self.span, 3),
            "peak_concurrency": self.peak_concurrency,
            "max_downtime": round(self.max_downtime, 3),
            "downtime_p50": downtime_pcts["p50"],
            "downtime_p99": downtime_pcts["p99"],
            "total_downtime": round(self.total_downtime, 3),
            "raw_bytes_total": self.raw_bytes_total,
            "wire_bytes_total": self.wire_bytes_total,
            "wire_reduction": round(self.wire_reduction, 3),
            "all_verified": self.all_verified,
            "attempts": self.attempts,
            "recovered": self.n_recovered,
            "strategies": sorted({r.strategy for r in self.reports}),
            "downtime_by_strategy": self.downtime_by_strategy(),
            "failures": [dict(f) for f in self.failures],
            "network": dict(self.network),
        }


class ClusterMigrationOrchestrator:
    """Drives N migrations against one APIServer, bounded concurrency."""

    def __init__(self, api: APIServer, make_worker: Callable[[], Any], *,
                 max_concurrent: int = 4,
                 cutoff_factory: Optional[Callable[[], CutoffController]] = None,
                 policy: Optional[MigrationPolicy] = None,
                 placement: Union[str, Callable, None] = None,
                 manager_kwargs: Optional[Dict[str, Any]] = None):
        self.api = api
        self.sim = api.sim
        self.make_worker = make_worker
        self.max_concurrent = max_concurrent
        self.cutoff_factory = cutoff_factory
        # target-node selection for specs that leave target_node=None (and
        # the drain default): "topology" | "round_robin" | a callable.
        # _inflight counts migrations currently targeting each node, so
        # simultaneous placements don't all tie onto one candidate
        self._inflight: Dict[str, int] = {}
        self.placement = resolve_placement(placement, api, self._inflight)
        # legacy shim: manager_kwargs={"precopy": True, ...} folds into the
        # declarative policy
        self.policy = MigrationPolicy.resolve(policy, **(manager_kwargs or {}))
        self._managers: Dict[str, MigrationManager] = {}

    # -- managers (one per primary queue, cached across migrations) ----------
    def manager_for(self, queue: str) -> MigrationManager:
        if queue not in self._managers:
            cutoff = self.cutoff_factory() if self.cutoff_factory else None
            self._managers[queue] = MigrationManager(
                self.api, self.make_worker, queue, cutoff=cutoff,
                policy=self.policy)
        return self._managers[queue]

    def identity_of(self, pod: Pod) -> Optional[str]:
        """Reverse lookup of a pod's StatefulSet identity, if any."""
        for replica, holder in self.api.statefulsets.identities.items():
            if holder == pod.name:
                return replica
        return None

    # -- fleet driver ---------------------------------------------------------
    def migrate_fleet(self, specs: List[PodMigrationSpec],
                      max_concurrent: Optional[int] = None) -> Condition:
        """Run every spec, at most ``max_concurrent`` in flight; completion
        Condition carries the FleetReport."""
        limit = max(1, max_concurrent or self.max_concurrent)
        fleet = FleetReport(t_start=self.sim.now)
        return self.sim.process(self._drive(list(specs), limit, fleet),
                                name=f"fleet:{len(specs)}x{limit}")

    def pick_target(self, pod: Pod, exclude: Optional[set] = None) -> str:
        """Run the placement policy over the alive nodes (excluding the
        pod's own — migrating onto the source node is a no-op — and any
        ``exclude`` entries: targets that already failed this spec)."""
        exclude = exclude or set()
        candidates = [n for n in self.api.nodes.values()
                      if n.alive and n.name != pod.node.name
                      and n.name not in exclude]
        if not candidates and exclude:
            # every fresh candidate is gone: allow excluded-but-alive
            # nodes again (a flapped target that revived beats giving up)
            candidates = [n for n in self.api.nodes.values()
                          if n.alive and n.name != pod.node.name]
        if not candidates:
            raise RuntimeError(
                f"no alive target node to place {pod.name} "
                f"(source {pod.node.name})")
        return self.placement(pod, candidates)

    def _guard(self, spec: PodMigrationSpec) -> Generator:
        """One migration with failure isolation and crash recovery.

        Any exception — spec validation, a dead target node mid-fleet, an
        aborted transfer, a strategy bug — fails this spec only, never
        the fleet.  Failures that went through the rollback path
        (``MigrationError``) are retried up to ``policy.max_attempts``
        times after ``policy.retry_backoff_s``: the spec is re-placed by
        the placement policy with every failed target node excluded, and
        the source handle is refreshed when the rollback re-created the
        pod.  Validation errors never retry (they would fail identically).
        """
        policy = spec.policy or self.policy
        pod = spec.pod
        excluded: set = set()
        attempt = 0
        # "was the workload left rolled back?" — updated by every attempt
        # that actually touched the workload (raised MigrationError after
        # running rollback).  An attempt that failed before reaching the
        # strategy (e.g. no target node left to pick) does not reset it:
        # the source's serving state is whatever the last rollback left.
        rolled_back = False
        while True:
            attempt += 1
            # the failure entry's target describes the TERMINAL attempt —
            # a pick_target failure has no target at all
            target_node = None
            try:
                if pod is None or pod.deleted:
                    raise RuntimeError(
                        f"source pod for queue {spec.queue!r} is gone "
                        "(its node died?): nothing left to migrate")
                if spec.target_node is not None and attempt == 1:
                    target_node = spec.target_node
                else:
                    # placement deferred to start time (or re-placement on
                    # retry): the score sees the link load of the
                    # migrations already in flight, minus failed targets
                    target_node = self.pick_target(pod, exclude=excluded)
                self._inflight[target_node] = (
                    self._inflight.get(target_node, 0) + 1)
                try:
                    mgr = self.manager_for(spec.queue)
                    report, target = yield from mgr.migration(
                        spec.strategy, pod, target_node,
                        statefulset_identity=spec.identity,
                        policy=spec.policy)
                finally:
                    self._inflight[target_node] -= 1
                report.attempts = attempt
                return "ok", report, target
            except Interrupt:
                # kernel control flow is not a migration failure: the
                # interrupter owns recovery — re-raise before the broad
                # isolation handler can eat it [SIM001]
                raise
            except Exception as exc:  # noqa: BLE001 — isolate any failure
                retryable = isinstance(exc, MigrationError)
                if retryable:
                    ctx = exc.context
                    rolled_back = ctx.rolled_back
                    if ctx.restored_source is not None:
                        pod = ctx.restored_source  # rollback re-created it
                if target_node is not None:
                    excluded.add(target_node)
                if not retryable or attempt >= policy.max_attempts:
                    cause = exc.cause if isinstance(exc, MigrationError) \
                        else exc
                    return "failed", {
                        "pod": spec.pod.name if spec.pod else None,
                        "queue": spec.queue,
                        "target_node": target_node,
                        "strategy": spec.strategy,
                        "error": f"{type(cause).__name__}: {cause}",
                        "attempts": attempt,
                        "rolled_back": rolled_back,
                        "source_pod": (pod.name if pod is not None
                                       and not pod.deleted else None),
                    }
                if policy.retry_backoff_s > 0:
                    yield policy.retry_backoff_s

    def _drive(self, specs: List[PodMigrationSpec], limit: int,
               fleet: FleetReport) -> Generator:
        pending = deque(specs)
        active: Dict[Condition, PodMigrationSpec] = {}
        while pending or active:
            while pending and len(active) < limit:
                spec = pending.popleft()
                cond = self.sim.process(
                    self._guard(spec),
                    name=f"migration:{spec.strategy}:{spec.queue}")
                active[cond] = spec
                fleet.peak_concurrency = max(fleet.peak_concurrency,
                                             len(active))
            # snapshot the fan-out in explicit launch order [SIM003]: the
            # wakeup must not be built from a view of a dict that the
            # drain below mutates, and the arm order (-> any_of callback
            # order) must be the deterministic admission order, not
            # whatever a set/hash iteration yields
            armed = list(active.keys())
            yield self.sim.any_of(*armed)
            for cond in [c for c in armed if c.triggered]:
                active.pop(cond)
                status, *payload = cond.value
                if status == "ok":
                    report, target = payload
                    fleet.reports.append(report)
                    fleet.targets.append(target)
                else:
                    fleet.failures.append(payload[0])
        fleet.t_end = self.sim.now
        fleet.network = self.api.topology.stats()
        return fleet

    # -- rolling StatefulSet migration ---------------------------------------
    def rolling_statefulset(self, specs: List[PodMigrationSpec]) -> Condition:
        """One replica at a time (concurrency 1), sticky-identity handoff:
        replica k+1 does not start until replica k's target holds its
        identity — the Kubernetes rolling-update discipline."""
        rolled = [dataclasses.replace(
            spec, strategy="ms2m_statefulset",
            identity=spec.identity or self.identity_of(spec.pod))
            for spec in specs]
        return self.migrate_fleet(rolled, max_concurrent=1)

    # -- node drain -----------------------------------------------------------
    def drain_node(self, node_name: str, *,
                   strategy: str = "ms2m_individual",
                   target_node_for: Optional[Callable[[Pod], str]] = None,
                   max_concurrent: Optional[int] = None) -> Condition:
        """Migrate every pod off ``node_name`` (maintenance drain).  Pods
        holding a StatefulSet identity are moved with ms2m_statefulset
        regardless of ``strategy``; targets default to the orchestrator's
        placement policy (topology-aware unless configured otherwise),
        scored when each spec starts.  ``target_node_for`` pins targets
        explicitly and bypasses the policy."""
        others = [n for n in self.api.nodes.values()
                  if n.alive and n.name != node_name]
        if not others:
            raise RuntimeError(f"no alive node to drain {node_name} onto")

        specs = []
        for pod in list(self.api.nodes[node_name].pods.values()):
            identity = self.identity_of(pod)
            specs.append(PodMigrationSpec(
                pod=pod, queue=pod.queue.name,
                target_node=(target_node_for(pod) if target_node_for
                             else None),
                strategy="ms2m_statefulset" if identity else strategy,
                identity=identity))
        return self.migrate_fleet(specs, max_concurrent=max_concurrent)


# ---------------------------------------------------------------------------
# Fleet workload harness (used by tests, benchmarks and examples)
# ---------------------------------------------------------------------------

def audit_failed_spec(api: APIServer, entry: Dict[str, Any],
                      make_worker: Callable, published: List[int], *,
                      exact: bool = True, verify: bool = True):
    """Record the rollback guarantee on one failure entry, in place: is
    the source pod still serving, on an alive node, and drain-consistent
    (its state equals the reference fold of everything it processed)?
    Shared by the fleet and single-migration harnesses so the invariant
    audit cannot drift between them.  Returns the source pod (or None)."""
    from repro.core.workload import reference_fold

    src = api.pods.get(entry.get("source_pod") or "")
    entry["source_serving"] = bool(src is not None and not src.deleted
                                   and src.node.alive and src.serving)
    entry["source_node_alive"] = bool(src is not None and src.node.alive)
    if src is not None and verify:
        ref = reference_fold(make_worker, published, src.worker.last_msg_id)
        entry["source_verified"] = bool(ref.state_equal(src.worker,
                                                        exact=exact))
    else:
        entry["source_verified"] = False if src is None else None
    return src

def run_fleet_experiment(
    n_pods: int,
    strategy: str,
    message_rate: float,
    *,
    registry_root: str,
    mode: str = "parallel",          # parallel | rolling | drain
    max_concurrent: int = 4,
    processing_ms: float = 50.0,
    t_migrate: float = 10.0,
    settle_time: float = 5.0,
    seed: int = 0,
    num_nodes: int = 4,
    timings: Optional[TimingConstants] = None,
    worker_factory: Optional[Callable] = None,
    chunk_bytes: Optional[int] = None,
    policy: Optional[MigrationPolicy] = None,
    manager_kwargs: Optional[Dict[str, Any]] = None,
    t_replay_max: float = 45.0,
    topology=None,                   # preset name | NetworkTopology | factory
    placement: Union[str, Callable, None] = None,
    auto_targets: bool = False,      # let the placement policy pick targets
    faults=None,                     # FaultSchedule | list of Fault/specs
    allow_failures: bool = False,    # chaos runs: failures are data, not bugs
) -> FleetReport:
    """N queues x N Poisson producers x N consumer pods; orchestrated
    migration per ``mode``; per-pod verification against an independent
    reference fold of each queue's published log (no loss, no duplication,
    no reordering), recorded in ``MigrationReport.state_verified``.

    ``topology`` selects the network model (default: the seed-identical
    ``flat`` preset); ``auto_targets=True`` leaves each spec's target to
    the orchestrator's ``placement`` policy instead of pinning the
    reserved last node.

    ``faults`` injects a deterministic failure schedule
    (``repro.cluster.faults``).  With ``allow_failures=True`` a spec that
    exhausted its retries is data rather than an assertion failure: its
    ``FleetReport.failures`` entry gains ``source_serving`` /
    ``source_node_alive`` / ``source_verified`` fields asserting the
    rollback guarantee — the source pod is still serving and its state
    still equals the reference fold of what it processed."""
    from repro.core.workload import HashConsumer, reference_fold

    if num_nodes < 2:
        raise ValueError(
            f"run_fleet_experiment needs num_nodes >= 2 (got {num_nodes}): "
            "with a single node every source would also be its own "
            "migration target — there is nowhere to migrate to")
    timings = dataclasses.replace(timings or TimingConstants(),
                                  processing_ms=processing_ms)
    cluster = Cluster(registry_root, timings=timings, num_nodes=num_nodes,
                      chunk_bytes=chunk_bytes, topology=topology,
                      faults=faults)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    make_worker = worker_factory or (lambda: HashConsumer())
    mu = 1000.0 / processing_ms

    # In drain mode every source sits on node0 (targets round-robin over
    # the remaining nodes); otherwise sources spread over all-but-the-last
    # node and every target lands on the last node, which is reserved —
    # i.e. kept free of sources — so migration direction is deterministic.
    published: List[List[int]] = [[] for _ in range(n_pods)]
    stop_producing = {"flag": False}
    sources: List[Pod] = []
    rolling = mode == "rolling"

    for i in range(n_pods):
        qname = f"orders-{i}"
        queue = broker.declare_queue(qname)

        # per-queue arrival source (legacy draw interleave: gap, then
        # token); fleet pods have no per-message observers, so steady
        # traffic runs as fluid epochs (docs/scaling.md)
        def make_draw(i=i):
            from repro.core.workload import open_loop_gaps
            rng = np.random.default_rng(seed * 1009 + i)
            gaps = open_loop_gaps(rng, message_rate)

            def draw():
                if stop_producing["flag"]:
                    return None
                gap = next(gaps)
                return gap, {"token": int(rng.integers(0, 2048))}

            return draw

        def on_publish(msg, i=i):
            published[i].append(msg.payload["token"])

        queue.attach_source(make_draw(), on_publish=on_publish)
        src_node = "node0" if mode == "drain" else f"node{i % max(1, num_nodes - 1)}"
        identity = f"consumer-{i}" if rolling else None

        def boot(i=i, qname=qname, src_node=src_node, identity=identity):
            pod = yield from api.create_pod(
                f"consumer-{i}", src_node, make_worker(),
                broker.queues[qname], statefulset_identity=identity)
            pod.start()
            sources.append(pod)

        sim.process(boot(), name=f"boot-{i}")

    sim.run(until=t_migrate)
    assert len(sources) == n_pods
    sources.sort(key=lambda p: int(p.name.rsplit("-", 1)[-1]))

    # strategies declare their control-plane needs via the registry — any
    # scheme that wants the Eq. 5 controller (cutoff, adaptive, custom
    # registrations) gets one, with no per-name special cases here
    cutoff_factory = None
    if get_strategy(strategy).wants_cutoff:
        cutoff_factory = lambda: CutoffController(  # noqa: E731
            t_replay_max=t_replay_max, mu_fallback=mu,
            lam_fallback=message_rate)
    orch = ClusterMigrationOrchestrator(
        api, make_worker, max_concurrent=max_concurrent,
        cutoff_factory=cutoff_factory, policy=policy, placement=placement,
        manager_kwargs=manager_kwargs)

    if mode == "drain":
        done = orch.drain_node("node0", strategy=strategy,
                               max_concurrent=max_concurrent)
    else:
        specs = [PodMigrationSpec(
            pod=pod, queue=pod.queue.name,
            target_node=None if auto_targets else f"node{num_nodes - 1}",
            strategy=strategy,
            identity=f"consumer-{i}" if rolling else None)
            for i, pod in enumerate(sources)]
        done = (orch.rolling_statefulset(specs) if rolling
                else orch.migrate_fleet(specs))

    sim.run(stop_when=done)
    fleet: FleetReport = done.value
    if not allow_failures:
        assert not fleet.failures, f"fleet migration failed: {fleet.failures}"

    # settle, stop traffic, let consumers drain their queues
    sim.run(until=sim.now + settle_time)
    stop_producing["flag"] = True
    for i in range(n_pods):
        broker.queues[f"orders-{i}"].halt_source()
    sim.run(until=sim.now + 2.0)
    for i in range(n_pods):  # land lazy arrivals / fold epochs at end-of-run
        broker.queues[f"orders-{i}"].sync(sim.now)

    # -- per-pod verification: reference fold of each queue's log ------------
    by_queue = {t.queue.name: (rep, t)
                for rep, t in zip(fleet.reports, fleet.targets)}
    for i in range(n_pods):
        hit = by_queue.get(f"orders-{i}")
        if hit is None:
            continue  # failed spec: verified below against its source
        rep, target = hit
        ref = reference_fold(make_worker, published[i],
                             target.worker.last_msg_id)
        rep.state_verified = bool(ref.state_equal(target.worker))

    # -- failed specs: the rollback guarantee ---------------------------------
    # an exhausted-retries failure must have left the source pod serving
    # the primary queue with drain-consistent state (its fold of whatever
    # it processed equals the reference fold — no loss, no duplication)
    for entry in fleet.failures:
        i = int(entry["queue"].rsplit("-", 1)[-1])
        audit_failed_spec(api, entry, make_worker, published[i])
    return fleet
