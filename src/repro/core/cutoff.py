"""Threshold-Based Cutoff Mechanism (paper §III-B, Eqs. 1-5).

M/M/1 model: messages arrive Poisson(λ) and accumulate in the secondary
queue for T_accum; the target replays them at μ_target.

  N_messages = λ · T_accum                                   (Eq. 1)
  T_replay   = N / μ_target = λ · T_accum / μ_target         (Eq. 2)
  T_replay  <= T_replay_max                                  (Eq. 3,4)
  T_cutoff   = T_accum <= T_replay_max · μ_target / λ        (Eq. 5)

Beyond-paper extension (`batched_cutoff_threshold`): a JAX target replays
the log as batched prefill at μ_replay = speedup(B)·μ_target >> μ_target,
so the admissible accumulation window stretches by the measured batching
speedup — the high-λ regime where the paper's MS2M degrades collapses.

Adaptive estimators: λ̂ and μ̂ are EWMA-estimated online from observed
inter-arrival / service times (the paper assumes them known; a production
controller must measure them).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


def cutoff_threshold(t_replay_max: float, mu_target: float, lam: float) -> float:
    """Eq. 5.  λ -> 0 gives an unbounded window (cap to +inf)."""
    if lam <= 0.0:
        return math.inf
    return t_replay_max * mu_target / lam


def replay_time_bound(lam: float, t_accum: float, mu_target: float) -> float:
    """Eq. 2 — expected replay time for a given accumulation window."""
    if mu_target <= 0:
        return math.inf
    return lam * t_accum / mu_target


def expected_catchup_time(lam: float, mu: float, backlog: float) -> float:
    """Drain time of a backlog with ongoing arrivals: backlog/(μ-λ);
    infinite at or beyond saturation (the failure mode the paper reports
    for original MS2M as λ -> μ)."""
    if mu <= lam:
        return math.inf
    return backlog / (mu - lam)


def batched_cutoff_threshold(t_replay_max: float, mu_target: float,
                             lam: float, batch_speedup: float) -> float:
    """Eq. 5 with μ_replay = speedup · μ_target (batched/prefill replay)."""
    return cutoff_threshold(t_replay_max, mu_target * max(1.0, batch_speedup), lam)


def stable_for_live_migration(lam: float, mu: float, rho_max: float = 0.95) -> bool:
    """Utilization guard: live (catch-up) migration only converges for
    ρ = λ/μ < 1; above ρ_max, a controller should prefer the cutoff path."""
    return lam < rho_max * mu


def transfer_time_estimate(fixed_s: float, state_bytes: float,
                           bw_Bps: float) -> float:
    """Expected single-shot transfer time: fixed control-plane costs plus
    the wire time of one full state image."""
    return fixed_s + state_bytes / max(bw_Bps, 1.0)


def choose_adaptive_strategy(lam: float, mu: float, *, fixed_s: float,
                             wire_s: float, t_replay_max: float,
                             rho_max: float = 0.9):
    """Decision rule behind the ``ms2m_adaptive`` strategy (pure, so it is
    unit-testable without a cluster).  Returns ``(strategy_name, why)``
    where ``why`` carries the telemetry the decision read.

    The accumulation window of a live MS2M migration is at least the
    transfer time T_xfer = fixed_s + wire_s, so the backlog at restore is
    ~λ·T_xfer and catch-up drains it at (μ - λ):

      * λ >= ρ_max·μ             — live sync cannot converge (the paper's
                                   high-λ failure mode): bound it, cutoff.
      * wire_s > fixed_s         — transfer is byte-dominated: iterative
                                   pre-copy both shrinks the final pull and
                                   bounds replay to one round, pre-copy.
      * catch-up > T_replay_max  — stable but slow: enforce the Eq. 5
                                   bound, cutoff.
      * otherwise                — plain live sync is already cheap.
    """
    t_xfer = fixed_s + wire_s
    backlog = lam * t_xfer
    catchup_s = expected_catchup_time(lam, mu, backlog)
    why = {
        "lam": round(lam, 4), "mu": round(mu, 4),
        "t_transfer": round(t_xfer, 3), "wire_s": round(wire_s, 3),
        "fixed_s": round(fixed_s, 3),
        "expected_catchup_s": (None if math.isinf(catchup_s)
                               else round(catchup_s, 3)),
    }
    if not stable_for_live_migration(lam, mu, rho_max):
        return "ms2m_cutoff", dict(why, reason="unstable_for_live_sync")
    if wire_s > fixed_s:
        return "ms2m_precopy", dict(why, reason="byte_dominated_transfer")
    if catchup_s > t_replay_max:
        return "ms2m_cutoff", dict(why, reason="catchup_exceeds_replay_bound")
    return "ms2m_individual", dict(why, reason="stable_and_cheap")


@dataclasses.dataclass
class RateEstimator:
    """EWMA arrival/service rate estimator (events per second).

    The EWMA is seeded from the first *real* inter-event interval: blending
    the first observation against a fake 0.0 starting rate would bias the
    estimate low for the first several half-lives (warm-up bias), which is
    exactly the window a short migration reads it in.  ``n_obs`` counts
    completed intervals so controllers can gate on evidence, not elapsed
    span.
    """

    halflife: float = 10.0  # seconds of virtual time
    _rate: Optional[float] = None  # None until the first interval lands
    _last_t: Optional[float] = None
    _n_obs: int = 0

    def observe(self, t: float):
        if self._last_t is None:
            self._last_t = t
            return
        dt = max(t - self._last_t, 1e-9)
        self._last_t = t
        inst = 1.0 / dt
        if self._rate is None:
            self._rate = inst  # seed from the first interval, no zero bias
        else:
            alpha = 1.0 - 0.5 ** (dt / self.halflife)
            self._rate += alpha * (inst - self._rate)
        self._n_obs += 1

    @property
    def rate(self) -> float:
        return 0.0 if self._rate is None else self._rate

    @property
    def n_obs(self) -> int:
        """Completed inter-event intervals folded into the estimate."""
        return self._n_obs

    @property
    def has_estimate(self) -> bool:
        return self._rate is not None


@dataclasses.dataclass
class CutoffController:
    """Online controller: tracks λ̂/μ̂ and decides when to cut off.

    ``should_cutoff(t_accum_started, now)`` is consulted by the migration
    manager once accumulation starts; it fires when the accumulation window
    exceeds Eq. 5's bound under the current estimates.
    """

    t_replay_max: float
    mu_fallback: float
    lam_fallback: float
    batch_speedup: float = 1.0
    # use the online λ̂/μ̂ estimates for the threshold (vs operator-supplied
    # fallbacks — the paper assumes λ and μ known); estimates are always
    # *tracked* either way and reported for observability.
    use_estimates: bool = False
    # evidence gate: completed intervals each estimator must have folded
    # before its estimate is trusted.  A *count*, not an elapsed span —
    # two observations 30 s apart are one interval, not convergence.
    min_observations: int = 30
    lam_est: RateEstimator = dataclasses.field(default_factory=RateEstimator)
    mu_est: RateEstimator = dataclasses.field(default_factory=RateEstimator)

    def observe_arrival(self, t: float):
        self._first_obs = min(getattr(self, "_first_obs", t), t)
        self._last_obs = t
        self.lam_est.observe(t)

    def observe_service(self, t: float):
        self._first_obs = min(getattr(self, "_first_obs", t), t)
        self._last_obs = t
        self.mu_est.observe(t)

    def _converged(self, est: RateEstimator) -> bool:
        return est.n_obs >= self.min_observations

    @property
    def lam(self) -> float:
        # explicit is-not-None gating: a legitimately converged tiny rate
        # must be returned, not silently swallowed by float truthiness
        if (self.use_estimates and self._converged(self.lam_est)
                and self.lam_est.has_estimate):
            return self.lam_est.rate
        return self.lam_fallback

    @property
    def mu(self) -> float:
        if (self.use_estimates and self._converged(self.mu_est)
                and self.mu_est.has_estimate):
            return self.mu_est.rate
        return self.mu_fallback

    def threshold(self) -> float:
        return batched_cutoff_threshold(
            self.t_replay_max, self.mu, self.lam, self.batch_speedup)

    def should_cutoff(self, accum_started: float, now: float) -> bool:
        return (now - accum_started) >= self.threshold()
