"""End-to-end migration experiment harness (used by benchmarks, tests and
examples).

One experiment = paper evaluation §IV-B:
  producer --Poisson(λ)--> primary queue --> consumer pod (μ = 1/processing)
  at t_migrate the MigrationManager runs one strategy; we record the
  MigrationReport, then *verify* the migrated state: an independent
  reference consumer folds the full message log 0..last_msg_id from scratch
  and must match the target bit-exactly (allclose for batched replay).

Migration behaviour is configured with one declarative ``MigrationPolicy``;
the legacy ``batched_replay=`` / ``replay_speedup=`` / ``precopy=`` /
``manager_kwargs=`` knobs are still accepted and folded into the policy.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

import jax

from repro.cluster.cluster import Cluster, TimingConstants
from repro.core.consumer import StatefulConsumer
from repro.core.cutoff import CutoffController
from repro.core.migration import MigrationManager, MigrationReport
from repro.core.policy import MigrationPolicy
from repro import configs


def open_loop_gaps(rng: np.random.Generator, rate: float, *,
                   burst_factor: float = 1.0, burst_every: int = 0,
                   burst_len: int = 0) -> Iterator[float]:
    """Seeded open-loop inter-arrival generator (virtual seconds).

    The default path draws ``rng.exponential(1.0 / rate)`` per arrival —
    the exact call sequence the experiment producers always made, so
    refactoring them onto this generator is bit-identical for every
    existing seed.  ``burst_every``/``burst_len``/``burst_factor`` add a
    deterministic count-based burst pattern: within every window of
    ``burst_every`` arrivals, the first ``burst_len`` draw at
    ``rate * burst_factor`` (a flash crowd), the rest at ``rate``.
    Open-loop means arrivals never wait on service — queueing delay shows
    up in the latency tail instead of being hidden by backpressure.
    """
    if rate <= 0.0:
        raise ValueError(f"open_loop_gaps needs rate > 0 (got {rate})")
    if burst_every and not 0 < burst_len <= burst_every:
        raise ValueError("need 0 < burst_len <= burst_every for bursts")
    n = 0
    while True:
        r = rate
        if burst_every and (n % burst_every) < burst_len:
            r = rate * burst_factor
        yield float(rng.exponential(1.0 / r))
        n += 1


def modulated_open_loop_gaps(rng: np.random.Generator, rate: float,
                             rate_of_t: Callable[[float], float], *,
                             t0: float = 0.0) -> Iterator[float]:
    """Time-modulated open-loop arrivals: each gap is drawn at the
    instantaneous rate ``rate * rate_of_t(t)`` evaluated at the current
    cumulative arrival time (a stepwise-constant approximation of an
    inhomogeneous Poisson process).  Exactly one ``rng.exponential`` call
    per arrival, same as ``open_loop_gaps`` — the draw *count* is
    schedule-independent, so seeded comparisons across schedules stay
    aligned.  ``rate_of_t`` must be a pure function of time (determinism:
    the reference fold re-walks the same arrival sequence)."""
    if rate <= 0.0:
        raise ValueError(f"modulated_open_loop_gaps needs rate > 0 "
                         f"(got {rate})")
    t = t0
    while True:
        r = max(rate * float(rate_of_t(t)), 1e-9)
        gap = float(rng.exponential(1.0 / r))
        t += gap
        yield gap


def diurnal_rate(period_s: float = 120.0, depth: float = 0.5,
                 phase_s: float = 0.0) -> Callable[[float], float]:
    """Sinusoidal day/night modulation factor: ``1 + depth*sin(...)``
    with the given period.  ``depth`` < 1 keeps the rate positive."""
    if not 0.0 <= depth < 1.0:
        raise ValueError(f"diurnal depth must be in [0, 1) (got {depth})")
    two_pi = 2.0 * math.pi

    def factor(t: float) -> float:
        return 1.0 + depth * math.sin(two_pi * (t + phase_s) / period_s)

    return factor


def flash_crowd_rate(at_s: float = 30.0, duration_s: float = 20.0,
                     factor: float = 4.0) -> Callable[[float], float]:
    """Step modulation: ``factor``x the base rate inside the window
    ``[at_s, at_s + duration_s)``, 1x outside (a flash crowd)."""

    def f(t: float) -> float:
        return factor if at_s <= t < at_s + duration_s else 1.0

    return f


def make_arrival_gaps(schedule: str, rng: np.random.Generator,
                      rate: float, **kwargs: Any) -> Iterator[float]:
    """Named arrival-schedule factory (the rebalance harness and CLI
    select by name):

      * ``steady``      — exactly ``open_loop_gaps`` (bit-identical to
        every existing seeded producer);
      * ``diurnal``     — ``diurnal_rate(**kwargs)`` modulation;
      * ``flash_crowd`` — ``flash_crowd_rate(**kwargs)`` modulation.
    """
    if schedule == "steady":
        return open_loop_gaps(rng, rate, **kwargs)
    if schedule == "diurnal":
        return modulated_open_loop_gaps(rng, rate, diurnal_rate(**kwargs))
    if schedule == "flash_crowd":
        return modulated_open_loop_gaps(rng, rate,
                                        flash_crowd_rate(**kwargs))
    raise ValueError(f"unknown arrival schedule {schedule!r}; "
                     "available: ['diurnal', 'flash_crowd', 'steady']")

ARRIVAL_SCHEDULES = ("steady", "diurnal", "flash_crowd")


def request_stream(rng: np.random.Generator, *,
                   prompt_tokens: Tuple[int, int] = (1, 4),
                   max_new_tokens: Tuple[int, int] = (2, 12),
                   vocab: int = 2048) -> Iterator[Dict[str, Any]]:
    """Seeded serving-request payload stream: each item is a broker
    payload ``{"prompt": [...], "max_new_tokens": m}`` with prompt length
    and decode budget drawn uniformly from the given inclusive ranges.
    The request id is assigned downstream (the broker message id), so the
    same stream drives both the live run and the reference fold."""
    lo_p, hi_p = prompt_tokens
    lo_m, hi_m = max_new_tokens
    while True:
        n_prompt = int(rng.integers(lo_p, hi_p + 1))
        prompt = [int(t) for t in rng.integers(0, vocab, size=n_prompt)]
        yield {"prompt": prompt,
               "max_new_tokens": int(rng.integers(lo_m, hi_m + 1))}


_FNV_PRIME = 1099511628211
_U64_MASK = (1 << 64) - 1


class HashConsumer:
    """Cheap drop-in for wide sweeps: state = rolling fnv-ish hash of the
    message log.  Still an exact fold (order-sensitive), so migration
    correctness remains fully checkable without JAX compute."""

    def __init__(self):
        self.digest = np.uint64(1469598103934665603)
        self.pos = 0
        self.last_msg_id = -1
        self.n_processed = 0
        self.skip_until = -1

    def process(self, msg):
        with np.errstate(over="ignore"):
            x = np.uint64(msg.payload["token"]) ^ np.uint64(msg.msg_id + 1)
            self.digest = np.uint64(
                (self.digest ^ x) * np.uint64(1099511628211))
        self.pos += 1
        self.last_msg_id = msg.msg_id
        self.n_processed += 1

    def process_batch(self, msgs):
        """Batched fold (fluid epochs): Python-int arithmetic masked to 64
        bits is bit-identical to the per-message np.uint64 wrapping above
        and avoids the per-call errstate context at fleet scale."""
        d = int(self.digest)
        last = self.last_msg_id
        n = 0
        for m in msgs:
            mid = m.msg_id
            d = ((d ^ (m.payload["token"] ^ (mid + 1))) * _FNV_PRIME) \
                & _U64_MASK
            last = mid
            n += 1
        self.digest = np.uint64(d)
        self.pos += n
        self.last_msg_id = last
        self.n_processed += n

    def process_pairs(self, pairs):
        """Allocation-free fluid fold over ``(msg_id, payload)`` tuples —
        the arithmetic-side drain path skips Message construction when
        nothing (log, mirror, on_publish) needs the object.  Bit-identical
        to ``process_batch``/``process``."""
        d = int(self.digest)
        last = self.last_msg_id
        n = 0
        for mid, payload in pairs:
            d = ((d ^ (payload["token"] ^ (mid + 1))) * _FNV_PRIME) \
                & _U64_MASK
            last = mid
            n += 1
        self.digest = np.uint64(d)
        self.pos += n
        self.last_msg_id = last
        self.n_processed += n

    def state_tree(self):
        return {"digest": np.uint64(self.digest),
                "scalars": {"pos": np.int64(self.pos),
                            "last_msg_id": np.int64(self.last_msg_id),
                            "n_processed": np.int64(self.n_processed)}}

    def load_state(self, tree):
        self.digest = np.uint64(tree["digest"])
        self.pos = int(tree["scalars"]["pos"])
        self.last_msg_id = int(tree["scalars"]["last_msg_id"])
        self.n_processed = int(tree["scalars"]["n_processed"])

    def state_equal(self, other, exact: bool = True):
        return (self.digest == other.digest and self.pos == other.pos
                and self.last_msg_id == other.last_msg_id)


@dataclasses.dataclass
class ExperimentResult:
    report: Optional[MigrationReport]
    verified: bool
    published: int
    processed_by_target: int
    lam: float
    mu: float
    downtime: float
    migration_time: float
    # chaos runs (faults + allow_failure=True): a migration that exhausted
    # its retries has report=None and carries the rollback audit instead
    failed: bool = False
    failure: Optional[Dict[str, Any]] = None

    def row(self) -> Dict[str, Any]:
        if self.report is None:
            f = self.failure or {}
            return {
                "strategy": f.get("strategy"),
                "lam": self.lam,
                "mu": self.mu,
                "failed": True,
                "error": f.get("error"),
                "attempts": f.get("attempts"),
                "rolled_back": f.get("rolled_back"),
                "source_serving": f.get("source_serving"),
                "source_verified": f.get("source_verified"),
            }
        return {
            "strategy": self.report.strategy,
            "lam": self.lam,
            "mu": self.mu,
            "migration_time": round(self.migration_time, 3),
            "downtime": round(self.downtime, 3),
            "replayed": self.report.replayed_messages,
            "cutoff_fired": self.report.cutoff_fired,
            "verified": self.verified,
            "state_verified": self.report.state_verified,
            "attempts": self.report.attempts,
            "phases": {k: round(v, 3) for k, v in self.report.phases.items()},
            "image_written_bytes": self.report.image_written_bytes,
            "image_deduped_bytes": self.report.image_deduped_bytes,
            "image_raw_bytes": self.report.image_raw_bytes,
            "image_wire_bytes": self.report.image_wire_bytes,
            "wire_reduction": round(self.report.wire_reduction, 3),
            "compression": self.report.compression,
            "precopy_rounds": self.report.precopy_rounds,
            "precopy_round_bytes": list(self.report.precopy_round_bytes),
            "precopy_round_wire_bytes":
                list(self.report.precopy_round_wire_bytes),
            "precopy_round_dirty": list(self.report.precopy_round_dirty),
        }


def reference_fold(make_worker: Callable, tokens: List[int], upto: int):
    """Independent correctness oracle: a fresh worker folds the published
    token log 0..upto from scratch (ids reassigned 0..upto, matching the
    broker's per-queue monotonic ids)."""
    from repro.broker.broker import Message

    ref = make_worker()
    for i, tok in enumerate(tokens[: upto + 1]):
        ref.process(Message(i, {"token": tok}, 0.0))
    return ref


def make_jax_worker_factory(max_seq: int = 512):
    """Factory of real-JAX consumers sharing one params tree (weights are
    immutable infrastructure; only the cache state migrates)."""
    cfg = configs.get_config("paper_consumer")
    params = None

    def make() -> StatefulConsumer:
        nonlocal params
        if params is None:
            from repro.models import transformer as T
            params = T.init_lm(jax.random.PRNGKey(0), cfg)
        return StatefulConsumer(cfg, params, max_seq=max_seq)

    return make, cfg


def resolve_experiment_policy(
    policy: Optional[MigrationPolicy],
    batched_replay: Optional[bool],
    replay_speedup: Optional[float],
    precopy: Optional[bool],
    manager_kwargs: Optional[Dict[str, Any]],
) -> MigrationPolicy:
    """Legacy-knob compatibility: historically ``replay_speedup`` only took
    effect together with ``batched_replay=True`` (a measured batching
    speedup makes no sense for sequential replay), so the fold preserves
    that coupling before handing over one declarative policy."""
    base = MigrationPolicy.resolve(policy, **(manager_kwargs or {}))
    batched = (base.batched_replay if batched_replay is None
               else batched_replay)
    if replay_speedup is not None:
        replay_speedup = replay_speedup if batched else 1.0
    return MigrationPolicy.resolve(
        base, batched_replay=batched_replay, replay_speedup=replay_speedup,
        precopy=precopy)


def run_migration_experiment(
    strategy: str,
    message_rate: float,
    *,
    registry_root: str,
    processing_ms: float = 50.0,
    t_migrate: float = 10.0,
    t_replay_max: float = 45.0,
    seed: int = 0,
    timings: Optional[TimingConstants] = None,
    worker_factory: Optional[Callable] = None,
    settle_time: float = 5.0,
    verify: bool = True,
    chunk_bytes: Optional[int] = None,
    policy: Optional[MigrationPolicy] = None,
    topology=None,                   # preset name | NetworkTopology | factory
    num_nodes: int = 3,
    faults=None,                     # FaultSchedule | list of Fault/specs
    allow_failure: bool = False,     # exhausted retries => result, not raise
    # legacy knobs, folded into the policy (None = unset):
    batched_replay: Optional[bool] = None,
    replay_speedup: Optional[float] = None,
    precopy: Optional[bool] = None,
    manager_kwargs: Optional[Dict[str, Any]] = None,
) -> ExperimentResult:
    pol = resolve_experiment_policy(policy, batched_replay, replay_speedup,
                                    precopy, manager_kwargs)
    timings = timings or TimingConstants()
    timings = dataclasses.replace(timings, processing_ms=processing_ms)
    if num_nodes < 2:
        raise ValueError(
            f"run_migration_experiment needs num_nodes >= 2 (got "
            f"{num_nodes}): the migration target must be a different node")
    cluster = Cluster(registry_root, timings=timings, num_nodes=num_nodes,
                      chunk_bytes=chunk_bytes, topology=topology,
                      faults=faults)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    primary = broker.declare_queue("orders")

    make_worker = worker_factory or (lambda: HashConsumer())
    mu = 1000.0 / processing_ms

    # -- adaptive cutoff controller (λ̂/μ̂ EWMA-estimated online) ------------
    cutoff = CutoffController(
        t_replay_max=t_replay_max, mu_fallback=mu, lam_fallback=message_rate,
        batch_speedup=pol.replay_speedup if pol.batched_replay else 1.0)

    # -- producer: Poisson(λ), deterministic --------------------------------
    # an arrival source instead of an inline producer process: draw order
    # (gap, then token — the legacy interleave), stop semantics and arrival
    # arithmetic are identical in both execution modes (docs/scaling.md)
    rng = np.random.default_rng(seed)
    gaps = open_loop_gaps(rng, message_rate)
    published: List[int] = []
    stop_producing = {"flag": False}

    def draw():
        if stop_producing["flag"]:
            return None
        gap = next(gaps)
        return gap, {"token": int(rng.integers(0, 2048))}

    def on_publish(msg):
        published.append(msg.payload["token"])
        cutoff.observe_arrival(msg.publish_time)

    primary.attach_source(draw, on_publish=on_publish)

    # -- source pod -----------------------------------------------------------
    source_worker = make_worker()
    source_holder: dict = {}

    def boot():
        pod = yield from api.create_pod("consumer-0", "node0", source_worker,
                                        primary)
        pod.on_processed = lambda p, m: cutoff.observe_service(sim.now)
        pod.start()
        source_holder["pod"] = pod

    sim.process(boot(), name="boot")
    sim.run(until=t_migrate)
    source = source_holder["pod"]

    # -- migration -------------------------------------------------------------
    # the direct manager path is kept bit-identical for fault-free
    # single-attempt runs; fault/retry runs go through the orchestrator's
    # guarded retry loop (rollback + re-placement excluding failed targets)
    use_guard = faults is not None or pol.max_attempts > 1 or allow_failure
    if not use_guard:
        mgr = MigrationManager(api, make_worker, "orders", cutoff=cutoff,
                               policy=pol)
        done = mgr.migrate(strategy, source, "node1")
        sim.run(stop_when=done)
        report, target = done.value
    else:
        from repro.core.orchestrator import (ClusterMigrationOrchestrator,
                                             PodMigrationSpec)
        orch = ClusterMigrationOrchestrator(
            api, make_worker, max_concurrent=1,
            cutoff_factory=lambda: cutoff, policy=pol)
        done = orch.migrate_fleet([PodMigrationSpec(
            pod=source, queue="orders", target_node="node1",
            strategy=strategy)])
        sim.run(stop_when=done)
        fleet = done.value
        if fleet.failures:
            entry = fleet.failures[0]
            if not allow_failure:
                raise RuntimeError(f"migration failed after "
                                   f"{entry['attempts']} attempt(s): "
                                   f"{entry['error']}")
            sim.run(until=sim.now + settle_time)
            stop_producing["flag"] = True
            primary.halt_source()
            sim.run(until=sim.now + 2.0)
            primary.sync(sim.now)  # land any lazy arrivals <= end-of-run
            from repro.core.orchestrator import audit_failed_spec
            src = audit_failed_spec(api, entry, make_worker, published,
                                    exact=not pol.batched_replay,
                                    verify=verify)
            return ExperimentResult(
                report=None, verified=False, published=len(published),
                processed_by_target=(src.worker.n_processed if src else 0),
                lam=message_rate, mu=mu, downtime=0.0, migration_time=0.0,
                failed=True, failure=entry)
        report, target = fleet.reports[0], fleet.targets[0]

    # -- settle + stop ----------------------------------------------------------
    sim.run(until=sim.now + settle_time)
    stop_producing["flag"] = True
    primary.halt_source()
    sim.run(until=sim.now + 2.0)
    primary.sync(sim.now)  # land any lazy arrivals / fold the target's epoch

    # -- verification: reference fold of the full log --------------------------
    verified = True
    if verify:
        ref = reference_fold(make_worker, published, target.worker.last_msg_id)
        verified = ref.state_equal(target.worker, exact=not pol.batched_replay)
        report.state_verified = bool(verified)

    return ExperimentResult(
        report=report,
        verified=verified,
        published=len(published),
        processed_by_target=target.worker.n_processed,
        lam=message_rate,
        mu=mu,
        downtime=report.downtime,
        migration_time=report.migration_time,
    )
