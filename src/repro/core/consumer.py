"""The stateful consumer microservice — JAX analogue of the paper's
Spring-Boot consumer.

State = the fold of the message log over the model's decode step:
    state_{i+1} = decode(params, token_i, state_i)
which is a *pure jitted function*, so replaying the same messages from the
same checkpoint is **bit-exact** — MS2M's core premise, strengthened
(the paper's Java services are only semantically deterministic).

Replay paths:
  * ``replay_sequential`` — one decode per message (paper-faithful; its
    virtual-clock cost is the per-message service time).
  * ``replay_scan``       — the whole log in one compiled ``lax.scan``
    (beyond-paper optimization).  Mathematically identical fold => still
    bit-exact, but amortizes dispatch/pipeline overhead; the measured
    speedup feeds ``cutoff.batched_cutoff_threshold``.
"""
from __future__ import annotations

import functools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig


@functools.partial(jax.jit, static_argnames=("cfg",))
def _decode(params, cfg, cache, token, pos):
    logits, cache = T.lm_decode_step(
        params, token[None, None], pos[None, None], cfg, cache)
    return logits[0, 0], cache


@functools.partial(jax.jit, static_argnames=("cfg",))
def _append(params, cfg, cache, tokens, positions):
    _, cache = T.lm_append(params, tokens[None], positions[None], cfg, cache)
    return cache


@functools.partial(jax.jit, static_argnames=("cfg",))
def _replay_scan(params, cfg, cache, tokens, start_pos):
    """Fold a token log into the cache with one compiled scan."""

    def body(carry, tok):
        cache, pos = carry
        _, cache = T.lm_decode_step(
            params, tok[None, None], pos[None, None], cfg, cache)
        return (cache, pos + 1), None

    (cache, _), _ = jax.lax.scan(body, (cache, start_pos), tokens)
    return cache


class StatefulConsumer:
    """Holds (cache, pos, last_msg_id); processes messages one-by-one."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int = 4096,
                 name: str = "consumer"):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.name = name
        self.cache = T.init_cache(cfg, 1, max_seq)
        self.pos = 0
        self.last_msg_id = -1
        self.n_processed = 0
        self.skip_until = -1  # replay filter: ids <= this are in the image

    # -- message processing --------------------------------------------------
    def process(self, msg) -> None:
        token = jnp.asarray(msg.payload["token"], jnp.int32)
        _, self.cache = _decode(self.params, self.cfg, self.cache, token,
                                jnp.asarray(self.pos % self.max_seq, jnp.int32))
        self.pos += 1
        self.last_msg_id = msg.msg_id
        self.n_processed += 1

    # -- state snapshot / restore (the "container image" contents) -----------
    def state_tree(self) -> Dict[str, Any]:
        return {
            "cache": self.cache,
            "scalars": {
                "pos": np.int64(self.pos),
                "last_msg_id": np.int64(self.last_msg_id),
                "n_processed": np.int64(self.n_processed),
            },
        }

    def load_state(self, tree: Dict[str, Any]):
        self.cache = jax.tree.map(jnp.asarray, tree["cache"])
        self.pos = int(tree["scalars"]["pos"])
        self.last_msg_id = int(tree["scalars"]["last_msg_id"])
        self.n_processed = int(tree["scalars"]["n_processed"])

    # -- replay ---------------------------------------------------------------
    def replay_sequential(self, messages: List) -> int:
        for m in messages:
            self.process(m)
        return len(messages)

    def replay_scan(self, messages: List) -> int:
        if not messages:
            return 0
        tokens = jnp.asarray([m.payload["token"] for m in messages], jnp.int32)
        self.cache = _replay_scan(
            self.params, self.cfg, self.cache, tokens,
            jnp.asarray(self.pos % self.max_seq, jnp.int32))
        self.pos += len(messages)
        self.last_msg_id = messages[-1].msg_id
        self.n_processed += len(messages)
        return len(messages)

    def replay_chunked(self, messages: List, chunk: int = 64) -> int:
        """Chunk-parallel replay (lm_append): the beyond-paper fast path.

        Equivalent fold up to reduction order (allclose, not bit-exact);
        wall-time speedup over sequential decode feeds the extended cutoff
        threshold (cutoff.batched_cutoff_threshold)."""
        done = 0
        while len(messages) - done >= chunk:  # full chunks: one compile
            batch = messages[done: done + chunk]
            tokens = jnp.asarray([m.payload["token"] for m in batch], jnp.int32)
            positions = (self.pos + jnp.arange(chunk, dtype=jnp.int32)) % self.max_seq
            self.cache = _append(self.params, self.cfg, self.cache, tokens,
                                 positions)
            self.pos += chunk
            self.last_msg_id = batch[-1].msg_id
            self.n_processed += chunk
            done += chunk
        # partial remainder: sequential decode (already-compiled path),
        # avoiding a fresh XLA compile per distinct remainder length
        self.replay_sequential(messages[done:])
        return len(messages)

    # -- equality (migration correctness oracle) ------------------------------
    def state_equal(self, other: "StatefulConsumer", exact: bool = True) -> bool:
        a = jax.tree.leaves(self.cache)
        b = jax.tree.leaves(other.cache)
        if self.pos != other.pos or self.last_msg_id != other.last_msg_id:
            return False
        for x, y in zip(a, b):
            if exact:
                if not np.array_equal(np.asarray(x), np.asarray(y)):
                    return False
            else:
                if not np.allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5):
                    return False
        return True


def measure_replay_speedup(cfg: ModelConfig, params, n: int = 64,
                           max_seq: int = 256) -> float:
    """Measured wall-time speedup of scan-replay vs per-message decode —
    the ``batch_speedup`` factor for the extended cutoff threshold."""
    import repro.broker.broker as B

    msgs = [B.Message(i, {"token": i % cfg.vocab_size}, 0.0) for i in range(n)]
    chunk = min(64, n)
    c1 = StatefulConsumer(cfg, params, max_seq)
    c2 = StatefulConsumer(cfg, params, max_seq)
    # warmup both compiled paths
    c1.replay_sequential(msgs[:2])
    c2.replay_chunked(msgs[:chunk], chunk=chunk)
    jax.block_until_ready(jax.tree.leaves(c2.cache)[0])

    t0 = time.perf_counter()
    c1.replay_sequential(msgs)
    jax.block_until_ready(jax.tree.leaves(c1.cache)[0])
    t_seq = time.perf_counter() - t0

    c2 = StatefulConsumer(cfg, params, max_seq)
    c2.replay_chunked(msgs[:chunk], chunk=chunk)  # rebuild state; warm
    t0 = time.perf_counter()
    c2.replay_chunked(msgs, chunk=chunk)
    jax.block_until_ready(jax.tree.leaves(c2.cache)[0])
    t_chunked = time.perf_counter() - t0
    return max(1.0, t_seq / max(t_chunked, 1e-9))
