"""MS2M migration strategies (paper §III, Figs. 1-4) as cluster processes.

Four strategies, all driven by the MigrationManager through the APIServer:

  stop_and_copy      — UMS-style baseline: pause -> checkpoint -> image ->
                       push -> pull -> restore -> switch.  Downtime == the
                       whole migration (paper Fig. 5).
  ms2m_individual    — Fig. 2: secondary queue attached, source keeps
                       serving; target restores from the registry image and
                       replays the mirrored log until *synchronized*, then a
                       short cutover.  Downtime == cutover only.
  ms2m_cutoff        — Fig. 3: same, plus the Threshold-Based Cutoff
                       Mechanism: when T_accum exceeds Eq. 5's T_cutoff, the
                       source is stopped and the remaining (bounded) log is
                       replayed; bounded replay <= T_replay_max by
                       construction.
  ms2m_statefulset   — Fig. 4: sticky identity forces stop-before-create:
                       checkpoint+push live, then stop source, release
                       identity, create target, restore, replay to the
                       *cutoff message id* (source's last processed), switch.

Replay correctness: message ids are totally ordered per queue; the target
skips ids <= the checkpoint marker and replays the rest through the same
jitted fold the source used => bit-exact state (verified by tests and by
every benchmark run via ``verify_against_reference``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Generator, List, Optional

from repro.cluster.cluster import APIServer, Pod, TimingConstants
from repro.cluster.sim import Condition, Sim
from repro.core.cutoff import CutoffController


@dataclasses.dataclass
class MigrationReport:
    strategy: str
    t_start: float
    t_end: float = 0.0
    downtime: float = 0.0
    phases: Dict[str, float] = dataclasses.field(default_factory=dict)
    checkpoint_marker: int = -1
    cutoff_id: Optional[int] = None
    cutoff_fired: bool = False
    replayed_messages: int = 0
    image_id: str = ""
    image_written_bytes: int = 0
    image_deduped_bytes: int = 0
    state_verified: Optional[bool] = None

    @property
    def migration_time(self) -> float:
        return self.t_end - self.t_start


class MigrationManager:
    """The paper's Migration Manager: deployed 'on the master node', talks
    to the API server only."""

    def __init__(self, api: APIServer, make_worker: Callable[[], Any],
                 primary_queue: str,
                 cutoff: Optional[CutoffController] = None,
                 batched_replay: bool = False,
                 replay_speedup: float = 1.0):
        self.api = api
        self.sim = api.sim
        self.broker = api.broker
        self.make_worker = make_worker
        self.primary_queue = primary_queue
        self.cutoff = cutoff
        self.batched_replay = batched_replay
        self.replay_speedup = max(1.0, replay_speedup)
        self._n = 0

    # ---------------------------------------------------------------------
    def migrate(self, strategy: str, source: Pod, target_node: str,
                statefulset_identity: Optional[str] = None) -> Condition:
        gen = {
            "stop_and_copy": self._stop_and_copy,
            "ms2m_individual": self._ms2m_individual,
            "ms2m_cutoff": self._ms2m_cutoff,
            "ms2m_statefulset": self._ms2m_statefulset,
        }[strategy]
        self._n += 1
        return self.sim.process(
            gen(source, target_node, statefulset_identity),
            name=f"migration:{strategy}:{self._n}",
        )

    # -- helpers -------------------------------------------------------------
    def _phase(self, report: MigrationReport, name: str, t0: float):
        report.phases[name] = report.phases.get(name, 0.0) + (self.sim.now - t0)

    def _sync_condition(self, target_pod: Pod, source_pod: Pod,
                        secondary) -> Condition:
        """Triggered when target has replayed everything the source has
        processed and the mirror buffer is empty."""
        cond = self.sim.condition("synced")

        def check(*_):
            if (secondary.depth() == 0
                    and target_pod.worker.last_msg_id >= source_pod.worker.last_msg_id):
                cond.trigger()

        target_pod.on_processed = check
        prev = source_pod.on_processed

        def chained(pod, msg):
            if prev:
                prev(pod, msg)
            check()

        source_pod.on_processed = chained
        check()
        return cond

    def _drain_condition(self, target_pod: Pod, up_to_id: int,
                         secondary) -> Condition:
        """Triggered when target has replayed ids <= up_to_id."""
        cond = self.sim.condition("drained")

        def check(*_):
            if target_pod.worker.last_msg_id >= up_to_id or secondary.depth() == 0:
                cond.trigger()

        target_pod.on_processed = check
        check()
        return cond

    def _switch_to_primary(self, target_pod: Pod, secondary_name: str):
        self.broker.detach_secondary(self.primary_queue, secondary_name)
        target_pod.queue = self.broker.queues[self.primary_queue]
        target_pod.wake()  # unblock if it was waiting on the secondary

    # ---------------------------------------------------------------------
    # Strategy 0: stop-and-copy (baseline; paper Fig. 5)
    # ---------------------------------------------------------------------
    def _stop_and_copy(self, source: Pod, target_node: str,
                       _identity=None) -> Generator:
        t = self.api.timings
        rep = MigrationReport("stop_and_copy", self.sim.now)
        down0 = self.sim.now
        source.pause()  # downtime starts immediately

        t0 = self.sim.now
        ckpt = yield from self.api.checkpoint_pod(source)
        rep.checkpoint_marker = ckpt["last_msg_id"]
        self._phase(rep, "checkpoint", t0)

        t0 = self.sim.now
        push = yield from self.api.build_and_push_image(
            ckpt, f"sac-{self._n}")
        rep.image_id = push.image_id
        rep.image_written_bytes = push.written_bytes
        rep.image_deduped_bytes = push.deduped_bytes
        self._phase(rep, "image_build_push", t0)

        t0 = self.sim.now
        worker = self.make_worker()
        target = yield from self.api.create_pod(
            f"{source.name}-target-{self._n}", target_node, worker,
            self.broker.queues[self.primary_queue],
            processing_ms=source.processing_ms)
        yield from self.api.pull_and_restore(push.image_id, worker)
        self._phase(rep, "service_restoration", t0)

        t0 = self.sim.now
        yield from self.api.delete_pod(source.name)
        yield t.route_switch_s
        target.start()
        self._phase(rep, "cutover", t0)

        rep.downtime = self.sim.now - down0
        rep.t_end = self.sim.now
        return rep, target

    # ---------------------------------------------------------------------
    # Strategy 1: MS2M for individual pods (paper Fig. 2)
    # ---------------------------------------------------------------------
    def _ms2m_individual(self, source: Pod, target_node: str,
                         _identity=None, *, deadline: Optional[float] = None
                         ) -> Generator:
        t = self.api.timings
        strategies = "ms2m_cutoff" if deadline is not None else "ms2m_individual"
        rep = MigrationReport(strategies, self.sim.now)
        sec = self.broker.attach_secondary(self.primary_queue,
                                           f"{self.primary_queue}.sec{self._n}")
        accum_started = self.sim.now

        # Threshold-Based Cutoff (Fig. 3): when T_accum hits Eq. 5's bound,
        # the SOURCE STOPS — even mid-transfer — capping the replay log at
        # N <= λ·T_cutoff so that T_replay <= T_replay_max by construction.
        cutoff_state: dict = {"fired": False, "pause_time": None, "id": None}
        fired_cond = self.sim.condition("cutoff-fired")
        if deadline is not None:
            def _fire():
                if not cutoff_state["fired"] and not source.paused:
                    cutoff_state["fired"] = True
                    cutoff_state["pause_time"] = self.sim.now
                    source.pause()
                    cutoff_state["id"] = source.worker.last_msg_id
                    fired_cond.trigger()

            self.sim.call_at(accum_started + deadline, _fire)

        t0 = self.sim.now
        ckpt = yield from self.api.checkpoint_pod(source)  # source keeps serving
        rep.checkpoint_marker = ckpt["last_msg_id"]
        self._phase(rep, "checkpoint", t0)

        t0 = self.sim.now
        push = yield from self.api.build_and_push_image(ckpt, f"ms2m-{self._n}")
        rep.image_id = push.image_id
        rep.image_written_bytes = push.written_bytes
        rep.image_deduped_bytes = push.deduped_bytes
        self._phase(rep, "image_build_push", t0)

        t0 = self.sim.now
        worker = self.make_worker()
        worker.skip_until = rep.checkpoint_marker
        replay_ms = source.processing_ms / self.replay_speedup
        target = yield from self.api.create_pod(
            f"{source.name}-target-{self._n}", target_node, worker, sec,
            processing_ms=replay_ms)
        yield from self.api.pull_and_restore(push.image_id, worker)
        self._phase(rep, "service_restoration", t0)

        # -- catch-up: target replays the mirror while source keeps serving --
        t0 = self.sim.now
        base_processed = worker.n_processed
        target.start()
        if cutoff_state["fired"]:
            # source already stopped (deadline expired mid-transfer):
            # bounded replay to the frozen cutoff id
            yield self._drain_condition(target, cutoff_state["id"], sec)
        else:
            synced = self._sync_condition(target, source, sec)
            yield self.sim.any_of(synced, fired_cond) if deadline is not None \
                else synced
            if cutoff_state["fired"] and not synced.triggered:
                # fired mid-catch-up: bounded drain to the frozen id
                yield self._drain_condition(target, cutoff_state["id"], sec)
        self._phase(rep, "message_replay", t0)

        # -- cutover ----------------------------------------------------------
        t0 = self.sim.now
        if cutoff_state["fired"]:
            rep.cutoff_fired = True
            rep.cutoff_id = cutoff_state["id"]
            down0 = cutoff_state["pause_time"]  # downtime began at the pause
        else:
            down0 = self.sim.now
            source.pause()
        yield t.cutover_coord_s
        # drain any in-flight mirrored messages up to the source's final state
        yield self._drain_condition(target, source.worker.last_msg_id, sec)
        self._switch_to_primary(target, sec.name)
        target.processing_ms = source.processing_ms  # back to service rate
        yield t.route_switch_s
        rep.downtime = self.sim.now - down0
        self._phase(rep, "cutover", t0)

        t0 = self.sim.now
        yield from self.api.delete_pod(source.name)
        self._phase(rep, "source_teardown", t0)

        rep.replayed_messages = worker.n_processed - base_processed
        rep.t_end = self.sim.now
        return rep, target

    # ---------------------------------------------------------------------
    # Strategy 2: MS2M + Threshold-Based Cutoff (paper Fig. 3, Eq. 5)
    # ---------------------------------------------------------------------
    def _ms2m_cutoff(self, source: Pod, target_node: str,
                     _identity=None) -> Generator:
        assert self.cutoff is not None, "ms2m_cutoff needs a CutoffController"
        deadline = self.cutoff.threshold()
        result = yield from self._ms2m_individual(
            source, target_node, deadline=deadline)
        return result

    # ---------------------------------------------------------------------
    # Strategy 3: MS2M for StatefulSet pods (paper Fig. 4)
    # ---------------------------------------------------------------------
    def _ms2m_statefulset(self, source: Pod, target_node: str,
                          identity: Optional[str] = None) -> Generator:
        t = self.api.timings
        identity = identity or f"sts-{source.name}"
        rep = MigrationReport("ms2m_statefulset", self.sim.now)
        sec = self.broker.attach_secondary(self.primary_queue,
                                           f"{self.primary_queue}.sec{self._n}")

        t0 = self.sim.now
        ckpt = yield from self.api.checkpoint_pod(source)  # still serving
        rep.checkpoint_marker = ckpt["last_msg_id"]
        self._phase(rep, "checkpoint", t0)

        t0 = self.sim.now
        push = yield from self.api.build_and_push_image(ckpt, f"sts-{self._n}")
        rep.image_id = push.image_id
        rep.image_written_bytes = push.written_bytes
        rep.image_deduped_bytes = push.deduped_bytes
        self._phase(rep, "image_build_push", t0)

        # -- stop source after the checkpoint-transfer phase (Fig. 4) --------
        down0 = self.sim.now
        source.pause()
        rep.cutoff_id = source.worker.last_msg_id  # the cutoff message id

        t0 = self.sim.now
        yield from self.api.delete_pod(source.name,
                                       statefulset_identity=identity)
        self._phase(rep, "identity_release", t0)

        t0 = self.sim.now
        worker = self.make_worker()
        worker.skip_until = rep.checkpoint_marker
        replay_ms = source.processing_ms / self.replay_speedup
        target = yield from self.api.create_pod(
            f"{source.name}-target-{self._n}", target_node, worker, sec,
            statefulset_identity=identity, processing_ms=replay_ms)
        yield from self.api.pull_and_restore(push.image_id, worker)
        self._phase(rep, "service_restoration", t0)

        # -- replay up to the cutoff message id -------------------------------
        t0 = self.sim.now
        base_processed = worker.n_processed
        target.start()
        drained = self._drain_condition(target, rep.cutoff_id, sec)
        yield drained
        self._phase(rep, "message_replay", t0)

        t0 = self.sim.now
        self._switch_to_primary(target, sec.name)
        target.processing_ms = source.processing_ms
        yield t.route_switch_s
        rep.downtime = self.sim.now - down0
        self._phase(rep, "cutover", t0)

        rep.replayed_messages = worker.n_processed - base_processed
        rep.t_end = self.sim.now
        return rep, target
