"""The Migration Manager: a thin orchestration core over the strategy
registry (see ``repro.core.strategy`` / ``repro.core.strategies``).

The manager resolves a strategy name through the registry, builds a
``MigrationContext`` (control-plane handles + ``MigrationPolicy`` + the
``MigrationReport`` under construction) and runs the strategy's phase
pipeline as a sim process.  It knows nothing about individual schemes:
adding a scenario means registering a ``MigrationStrategy`` class, not
editing this file.

Configuration is one declarative ``MigrationPolicy`` value; the legacy
constructor knobs (``precopy=``, ``batched_replay=``, ...) are still
accepted and folded into a policy, so pre-registry call sites keep
working unchanged.

Migrations subscribe to pod ``on_processed`` events via removable
listeners and deregister them on completion, so repeated migrations of
the same lineage (what the ClusterMigrationOrchestrator does) never fire
stale sync checks against deleted pods.
"""
from __future__ import annotations

from typing import Any, Callable, Generator, List, Optional

from repro.cluster.cluster import APIServer, Pod
from repro.cluster.sim import Condition, Interrupt
from repro.core.cutoff import CutoffController
from repro.core.policy import MigrationEvent, MigrationPolicy, MigrationReport  # noqa: F401  (re-export)
from repro.core.strategy import (
    MigrationContext,
    MigrationError,
    drain_condition,
    get_strategy,
    listen,
    sync_condition,
    unlisten_all,
)
from repro.core import strategies as _builtin_strategies  # noqa: F401  (registers the built-ins)


class MigrationManager:
    """The paper's Migration Manager: deployed 'on the master node', talks
    to the API server only."""

    def __init__(self, api: APIServer, make_worker: Callable[[], Any],
                 primary_queue: str,
                 cutoff: Optional[CutoffController] = None,
                 policy: Optional[MigrationPolicy] = None,
                 # legacy knobs, folded into the policy (None = unset):
                 batched_replay: Optional[bool] = None,
                 replay_speedup: Optional[float] = None,
                 precopy: Optional[bool] = None,
                 precopy_max_rounds: Optional[int] = None,
                 precopy_converge_ratio: Optional[float] = None,
                 precopy_min_dirty: Optional[int] = None):
        self.api = api
        self.sim = api.sim
        self.broker = api.broker
        self.make_worker = make_worker
        self.primary_queue = primary_queue
        self.cutoff = cutoff
        self.policy = MigrationPolicy.resolve(
            policy,
            batched_replay=batched_replay,
            replay_speedup=replay_speedup,
            precopy=precopy,
            precopy_max_rounds=precopy_max_rounds,
            precopy_converge_ratio=precopy_converge_ratio,
            precopy_min_dirty=precopy_min_dirty,
        )
        self._n = 0

    # -- legacy attribute views (pre-policy call sites read these) -----------
    @property
    def batched_replay(self) -> bool:
        return self.policy.batched_replay

    @property
    def replay_speedup(self) -> float:
        return self.policy.replay_speedup

    @property
    def precopy(self) -> bool:
        return self.policy.precopy

    @property
    def precopy_max_rounds(self) -> int:
        return self.policy.precopy_max_rounds

    # ---------------------------------------------------------------------
    def migration(self, strategy: str, source: Pod, target_node: str,
                  statefulset_identity: Optional[str] = None,
                  policy: Optional[MigrationPolicy] = None) -> Generator:
        """Validate and build one migration as a raw sim generator.

        Callers that need failure isolation (the fleet orchestrator) drive
        this inside their own guarded process; everyone else uses
        ``migrate``.  Validation errors raise here, synchronously.

        Any failure inside the strategy body (an aborted transfer, a dead
        target node, a strategy bug) runs ``MigrationContext.rollback``
        — source serving again, mirror torn down, target remnants and
        half-pushed images gone — and re-raises as ``MigrationError``
        carrying the context, so a failed attempt is a no-op for the
        workload and the retry loop can pick up the restored source.
        """
        cls = get_strategy(strategy)
        if statefulset_identity is not None and not cls.handles_identity:
            # every other strategy deletes the source without releasing the
            # identity, which would leave it claimed by a dead pod forever
            raise ValueError(
                f"strategy {strategy!r} cannot hand off StatefulSet identity "
                f"{statefulset_identity!r}; use 'ms2m_statefulset'")
        # capture the migration number NOW: the generator body runs later,
        # and two concurrent migrations on the same queue would otherwise
        # both read the post-increment _n and attach the same secondary
        self._n += 1
        ctx = MigrationContext(self, source, target_node,
                               statefulset_identity,
                               policy or self.policy, strategy, self._n)
        return self._run_rolled_back(cls, ctx)

    @staticmethod
    def _run_rolled_back(cls, ctx: MigrationContext) -> Generator:
        try:
            result = yield from cls().run(ctx)
            return result
        except Interrupt:
            # kernel control flow (Interrupt subclasses Exception, so the
            # broad handler below would swallow it): the interrupter owns
            # recovery, not the rollback path [SIM001]
            raise
        except Exception as exc:  # noqa: BLE001 — every failure rolls back
            try:
                yield from ctx.rollback(exc)
            except Interrupt:
                raise  # never eat a kernel interrupt mid-rollback [SIM001]
            except Exception as rexc:  # noqa: BLE001
                # rollback itself failed (e.g. the source node died too);
                # surface the original failure, keep the rollback error
                ctx.rollback_error = rexc
            raise MigrationError(ctx, exc) from exc

    def migrate(self, strategy: str, source: Pod, target_node: str,
                statefulset_identity: Optional[str] = None,
                policy: Optional[MigrationPolicy] = None) -> Condition:
        gen = self.migration(strategy, source, target_node,
                             statefulset_identity=statefulset_identity,
                             policy=policy)
        return self.sim.process(
            gen, name=f"migration:{strategy}:{self.primary_queue}:{self._n}")

    # -- condition helpers (kept as methods: tests and external tooling use
    # them against a bare manager; strategies reach them via the context) ----
    def _listen(self, pod: Pod, fn: Callable, subs: List) -> None:
        listen(pod, fn, subs)

    @staticmethod
    def _unlisten_all(subs: List) -> None:
        unlisten_all(subs)

    def _sync_condition(self, target_pod: Pod, source_pod: Pod,
                        secondary, subs: List) -> Condition:
        return sync_condition(self.sim, target_pod, source_pod, secondary,
                              subs)

    def _drain_condition(self, target_pod: Pod, up_to_id: int,
                         secondary, subs: List) -> Condition:
        return drain_condition(self.sim, target_pod, up_to_id, secondary,
                               subs)

    def _switch_to_primary(self, target_pod: Pod, secondary_name: str):
        self.broker.detach_secondary(self.primary_queue, secondary_name)
        target_pod.queue = self.broker.queues[self.primary_queue]
        target_pod.wake()  # unblock if it was waiting on the secondary

    def _detach_if_mirrored(self, secondary_name: str):
        if self.broker.is_mirrored(self.primary_queue, secondary_name):
            self.broker.detach_secondary(self.primary_queue, secondary_name)
