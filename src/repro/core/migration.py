"""MS2M migration strategies (paper §III, Figs. 1-4) as cluster processes.

Five strategies, all driven by the MigrationManager through the APIServer:

  stop_and_copy      — UMS-style baseline: pause -> checkpoint -> image ->
                       push -> pull -> restore -> switch.  Downtime == the
                       whole migration (paper Fig. 5).
  ms2m_individual    — Fig. 2: secondary queue attached, source keeps
                       serving; target restores from the registry image and
                       replays the mirrored log until *synchronized*, then a
                       short cutover.  Downtime == cutover only.
  ms2m_cutoff        — Fig. 3: same, plus the Threshold-Based Cutoff
                       Mechanism: when T_accum exceeds Eq. 5's T_cutoff, the
                       source is stopped and the remaining (bounded) log is
                       replayed; bounded replay <= T_replay_max by
                       construction.
  ms2m_statefulset   — Fig. 4: sticky identity forces stop-before-create:
                       checkpoint+push live, then stop source, release
                       identity, create target, restore, replay to the
                       *cutoff message id* (source's last processed), switch.
  ms2m_precopy       — beyond-paper (MOSE/SHADOW-style iterative pre-copy):
                       full checkpoint+push once, then repeated
                       checkpoint→delta-push rounds while the source keeps
                       serving; each delta carries only the chunks dirtied
                       since the previous round and is prefetched onto the
                       target node, so the final restore is nearly free and
                       the replay log is bounded by ONE round's traffic
                       instead of the whole transfer.  The loop stops when
                       the inter-round dirty set converges.  The same loop
                       is available as an opt-in (``precopy=True``) for
                       ms2m_individual / ms2m_cutoff / ms2m_statefulset.

Replay correctness: message ids are totally ordered per queue; the target
skips ids <= the checkpoint marker and replays the rest through the same
jitted fold the source used => bit-exact state (verified by tests and by
every benchmark run via ``verify_against_reference``).

Migrations subscribe to pod ``on_processed`` events via removable
listeners and deregister them on completion, so repeated migrations of
the same lineage (what the ClusterMigrationOrchestrator does) never fire
stale sync checks against deleted pods.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Generator, List, Optional

from repro.cluster.cluster import APIServer, Pod, TimingConstants
from repro.cluster.sim import Condition, Sim
from repro.core.cutoff import CutoffController


@dataclasses.dataclass
class MigrationReport:
    strategy: str
    t_start: float
    t_end: float = 0.0
    downtime: float = 0.0
    phases: Dict[str, float] = dataclasses.field(default_factory=dict)
    checkpoint_marker: int = -1
    cutoff_id: Optional[int] = None
    cutoff_fired: bool = False
    replayed_messages: int = 0
    image_id: str = ""
    image_written_bytes: int = 0
    image_deduped_bytes: int = 0
    state_verified: Optional[bool] = None
    # pre-copy telemetry: per-round wire bytes / dirty-message counts
    # (index 0 = the initial full push)
    precopy_rounds: int = 0
    precopy_round_bytes: List[int] = dataclasses.field(default_factory=list)
    precopy_round_dirty: List[int] = dataclasses.field(default_factory=list)

    @property
    def migration_time(self) -> float:
        return self.t_end - self.t_start


class MigrationManager:
    """The paper's Migration Manager: deployed 'on the master node', talks
    to the API server only."""

    def __init__(self, api: APIServer, make_worker: Callable[[], Any],
                 primary_queue: str,
                 cutoff: Optional[CutoffController] = None,
                 batched_replay: bool = False,
                 replay_speedup: float = 1.0,
                 precopy: bool = False,
                 precopy_max_rounds: int = 5,
                 precopy_converge_ratio: float = 0.9,
                 precopy_min_dirty: int = 0):
        self.api = api
        self.sim = api.sim
        self.broker = api.broker
        self.make_worker = make_worker
        self.primary_queue = primary_queue
        self.cutoff = cutoff
        self.batched_replay = batched_replay
        self.replay_speedup = max(1.0, replay_speedup)
        # pre-copy opt-in for the ms2m_* strategies (ms2m_precopy always on):
        # delta rounds stop when the dirty set shrinks by less than
        # (1 - converge_ratio) or reaches min_dirty messages
        self.precopy = precopy
        self.precopy_max_rounds = precopy_max_rounds
        self.precopy_converge_ratio = precopy_converge_ratio
        self.precopy_min_dirty = precopy_min_dirty
        self._n = 0

    # ---------------------------------------------------------------------
    def migrate(self, strategy: str, source: Pod, target_node: str,
                statefulset_identity: Optional[str] = None) -> Condition:
        if statefulset_identity is not None and strategy != "ms2m_statefulset":
            # every other strategy deletes the source without releasing the
            # identity, which would leave it claimed by a dead pod forever
            raise ValueError(
                f"strategy {strategy!r} cannot hand off StatefulSet identity "
                f"{statefulset_identity!r}; use 'ms2m_statefulset'")
        gen = {
            "stop_and_copy": self._stop_and_copy,
            "ms2m_individual": self._ms2m_individual,
            "ms2m_cutoff": self._ms2m_cutoff,
            "ms2m_statefulset": self._ms2m_statefulset,
            "ms2m_precopy": self._ms2m_precopy,
        }[strategy]
        # capture the migration number NOW: the generator body runs later,
        # and two concurrent migrations on the same queue would otherwise
        # both read the post-increment _n and attach the same secondary
        self._n += 1
        n = self._n
        return self.sim.process(
            gen(source, target_node, statefulset_identity, n=n),
            name=f"migration:{strategy}:{self.primary_queue}:{n}",
        )

    # -- helpers -------------------------------------------------------------
    def _phase(self, report: MigrationReport, name: str, t0: float):
        report.phases[name] = report.phases.get(name, 0.0) + (self.sim.now - t0)

    def _listen(self, pod: Pod, fn: Callable, subs: List) -> None:
        """Subscribe ``fn`` to the pod's processed events, recording the
        subscription so the migration can deregister it on completion."""
        pod.add_on_processed(fn)
        subs.append((pod, fn))

    @staticmethod
    def _unlisten_all(subs: List) -> None:
        for pod, fn in subs:
            pod.remove_on_processed(fn)
        subs.clear()

    def _sync_condition(self, target_pod: Pod, source_pod: Pod,
                        secondary, subs: List) -> Condition:
        """Triggered when target has replayed everything the source has
        processed and the mirror buffer is empty."""
        cond = self.sim.condition("synced")

        def check(*_):
            if (secondary.depth() == 0
                    and target_pod.worker.last_msg_id >= source_pod.worker.last_msg_id):
                cond.trigger()

        self._listen(target_pod, check, subs)
        self._listen(source_pod, check, subs)
        check()
        return cond

    def _drain_condition(self, target_pod: Pod, up_to_id: int,
                         secondary, subs: List) -> Condition:
        """Triggered when target has replayed ids <= up_to_id.

        The empty-mirror short-circuit exists for ids the mirror can never
        deliver (messages consumed from the primary before the secondary
        was attached).  It may only fire when no more mirrored traffic can
        arrive for the target: the mirror is empty AND nothing is in
        flight (mid-service) at the target — a momentarily-empty mirror
        while the last mirrored message is still being folded must NOT
        trigger a premature cutover (that dropped the in-flight message's
        state update from the downtime accounting and switched routes
        before the target was caught up)."""
        cond = self.sim.condition("drained")

        def check(*_):
            if target_pod.worker.last_msg_id >= up_to_id or (
                    secondary.depth() == 0 and not target_pod.busy):
                cond.trigger()

        self._listen(target_pod, check, subs)
        check()
        return cond

    def _switch_to_primary(self, target_pod: Pod, secondary_name: str):
        self.broker.detach_secondary(self.primary_queue, secondary_name)
        target_pod.queue = self.broker.queues[self.primary_queue]
        target_pod.wake()  # unblock if it was waiting on the secondary

    def _detach_if_mirrored(self, secondary_name: str):
        """Error-path cleanup: a migration that dies before cutover must not
        leave its mirror attached (it would double-buffer every future
        publish into a queue nothing drains)."""
        if self.broker.is_mirrored(self.primary_queue, secondary_name):
            self.broker.detach_secondary(self.primary_queue, secondary_name)

    def _transfer(self, source: Pod, target_node: str, rep: MigrationReport,
                  use_precopy: bool, pre_tag: str, full_tag: str) -> Generator:
        """Checkpoint-transfer phase, pre-copy or single-shot."""
        if use_precopy:
            push, marker = yield from self._precopy_transfer(
                source, target_node, rep, pre_tag)
            rep.checkpoint_marker = marker
            rep.image_id = push.image_id
        else:
            _, push = yield from self._full_transfer(source, rep, full_tag)
        return push

    def _full_transfer(self, source: Pod, rep: MigrationReport,
                       tag: str) -> Generator:
        """Checkpoint + full image push, with phase/report accounting.
        Returns (checkpoint dict, PushReport)."""
        t0 = self.sim.now
        ckpt = yield from self.api.checkpoint_pod(source)  # source serving
        rep.checkpoint_marker = ckpt["last_msg_id"]
        self._phase(rep, "checkpoint", t0)

        t0 = self.sim.now
        push = yield from self.api.build_and_push_image(ckpt, tag)
        rep.image_id = push.image_id
        rep.image_written_bytes = push.written_bytes
        rep.image_deduped_bytes = push.deduped_bytes
        self._phase(rep, "image_build_push", t0)
        return ckpt, push

    # -- iterative pre-copy (delta checkpoint rounds) -------------------------
    def _precopy_transfer(self, source: Pod, target_node: str,
                          rep: MigrationReport, tag: str) -> Generator:
        """One full checkpoint+push, then checkpoint→delta-push rounds while
        the source keeps serving.  Every image is prefetched onto the target
        node, so the final restore pulls ~nothing; the loop stops when the
        inter-round dirty set (messages processed between two consecutive
        checkpoints) converges.  Returns (final PushReport, final marker):
        the replay log left for the target is bounded by the LAST round's
        traffic instead of the whole transfer."""
        base = source.worker.last_msg_id  # lineage may predate this migration
        ckpt, push = yield from self._full_transfer(source, rep, f"{tag}-r0")
        t0 = self.sim.now
        yield from self.api.prefetch_image(target_node, push.image_id)
        self._phase(rep, "precopy_prefetch", t0)
        rep.precopy_round_bytes.append(push.delta_bytes)
        rep.precopy_round_dirty.append(ckpt["last_msg_id"] - base)
        marker = ckpt["last_msg_id"]

        prev_dirty: Optional[int] = None
        while rep.precopy_rounds < self.precopy_max_rounds:
            # phases stay comparable across strategies: dumps are always
            # booked as "checkpoint", only delta build/push/prefetch as
            # the precopy-specific phases
            t0 = self.sim.now
            ckpt = yield from self.api.checkpoint_pod(source)
            self._phase(rep, "checkpoint", t0)
            dirty = ckpt["last_msg_id"] - marker
            if dirty <= self.precopy_min_dirty:
                # nothing dirtied since the last round (e.g. source already
                # paused by the cutoff): the previous image already holds
                # this exact state — don't pay for a bit-identical push
                break
            t0 = self.sim.now
            delta = yield from self.api.push_delta_image(
                ckpt, f"{tag}-r{rep.precopy_rounds + 1}", push.image_id)
            yield from self.api.prefetch_image(target_node, delta.image_id)
            self._phase(rep, "precopy_delta", t0)
            push = delta
            marker = ckpt["last_msg_id"]
            rep.precopy_rounds += 1
            rep.precopy_round_bytes.append(delta.delta_bytes)
            rep.precopy_round_dirty.append(dirty)
            rep.image_written_bytes += delta.written_bytes
            rep.image_deduped_bytes += delta.deduped_bytes
            if (prev_dirty is not None
                    and dirty >= prev_dirty * self.precopy_converge_ratio):
                break  # dirty set stopped shrinking: steady state reached
            prev_dirty = dirty
        return push, marker

    # ---------------------------------------------------------------------
    # Strategy 0: stop-and-copy (baseline; paper Fig. 5)
    # ---------------------------------------------------------------------
    def _stop_and_copy(self, source: Pod, target_node: str,
                       _identity=None, *, n: Optional[int] = None) -> Generator:
        n = self._n if n is None else n
        t = self.api.timings
        rep = MigrationReport("stop_and_copy", self.sim.now)
        down0 = self.sim.now
        source.pause()  # downtime starts immediately

        _, push = yield from self._full_transfer(
            source, rep, f"{self.primary_queue}-sac{n}")

        t0 = self.sim.now
        worker = self.make_worker()
        target = yield from self.api.create_pod(
            f"{source.name}-target-{n}", target_node, worker,
            self.broker.queues[self.primary_queue],
            processing_ms=source.processing_ms)
        yield from self.api.pull_and_restore(push.image_id, worker,
                                             node_name=target_node)
        self._phase(rep, "service_restoration", t0)

        t0 = self.sim.now
        yield from self.api.delete_pod(source.name)
        yield t.route_switch_s
        target.start()
        self._phase(rep, "cutover", t0)

        rep.downtime = self.sim.now - down0
        rep.t_end = self.sim.now
        return rep, target

    # ---------------------------------------------------------------------
    # Strategy 1: MS2M for individual pods (paper Fig. 2)
    # ---------------------------------------------------------------------
    def _ms2m_individual(self, source: Pod, target_node: str,
                         _identity=None, *, deadline: Optional[float] = None,
                         precopy: Optional[bool] = None,
                         strategy_name: Optional[str] = None,
                         n: Optional[int] = None) -> Generator:
        n = self._n if n is None else n
        t = self.api.timings
        use_precopy = self.precopy if precopy is None else precopy
        name = strategy_name or (
            "ms2m_cutoff" if deadline is not None else "ms2m_individual")
        rep = MigrationReport(name, self.sim.now)
        sec = self.broker.attach_secondary(self.primary_queue,
                                           f"{self.primary_queue}.sec{n}")
        accum_started = self.sim.now
        subs: List = []  # processed-event listeners, removed on completion

        # Threshold-Based Cutoff (Fig. 3): when T_accum hits Eq. 5's bound,
        # the SOURCE STOPS — even mid-transfer — capping the replay log at
        # N <= λ·T_cutoff so that T_replay <= T_replay_max by construction.
        cutoff_state: dict = {"fired": False, "pause_time": None, "id": None}
        fired_cond = self.sim.condition("cutoff-fired")
        if deadline is not None:
            def _fire():
                if (not cutoff_state["fired"] and not source.paused
                        and not source.deleted):
                    cutoff_state["fired"] = True
                    cutoff_state["pause_time"] = self.sim.now
                    source.pause()
                    cutoff_state["id"] = source.worker.last_msg_id
                    fired_cond.trigger()

            self.sim.call_at(accum_started + deadline, _fire)

        try:
            push = yield from self._transfer(
                source, target_node, rep, use_precopy,
                f"{self.primary_queue}-pre{n}",
                f"{self.primary_queue}-ms2m{n}")

            t0 = self.sim.now
            worker = self.make_worker()
            worker.skip_until = rep.checkpoint_marker
            replay_ms = source.processing_ms / self.replay_speedup
            target = yield from self.api.create_pod(
                f"{source.name}-target-{n}", target_node, worker, sec,
                processing_ms=replay_ms)
            yield from self.api.pull_and_restore(push.image_id, worker,
                                                 node_name=target_node)
            self._phase(rep, "service_restoration", t0)

            # -- catch-up: target replays the mirror, source keeps serving --
            t0 = self.sim.now
            base_processed = worker.n_processed
            target.start()
            if cutoff_state["fired"]:
                # source already stopped (deadline expired mid-transfer):
                # bounded replay to the frozen cutoff id
                yield self._drain_condition(target, cutoff_state["id"], sec,
                                            subs)
            else:
                synced = self._sync_condition(target, source, sec, subs)
                yield self.sim.any_of(synced, fired_cond) \
                    if deadline is not None else synced
                if cutoff_state["fired"] and not synced.triggered:
                    # fired mid-catch-up: bounded drain to the frozen id
                    yield self._drain_condition(target, cutoff_state["id"],
                                                sec, subs)
            self._phase(rep, "message_replay", t0)

            # -- cutover --------------------------------------------------------
            t0 = self.sim.now
            if cutoff_state["fired"]:
                rep.cutoff_fired = True
                rep.cutoff_id = cutoff_state["id"]
                down0 = cutoff_state["pause_time"]  # downtime began at pause
            else:
                down0 = self.sim.now
                source.pause()
            yield t.cutover_coord_s
            # drain in-flight mirrored messages up to the source's final state
            yield self._drain_condition(target, source.worker.last_msg_id,
                                        sec, subs)
            self._switch_to_primary(target, sec.name)
            target.processing_ms = source.processing_ms  # back to service rate
            yield t.route_switch_s
            rep.downtime = self.sim.now - down0
            self._phase(rep, "cutover", t0)

            t0 = self.sim.now
            yield from self.api.delete_pod(source.name)
            self._phase(rep, "source_teardown", t0)

            rep.replayed_messages = worker.n_processed - base_processed
            rep.t_end = self.sim.now
            return rep, target
        finally:
            # deregister sync/drain listeners: repeated migrations of the
            # same lineage must not keep firing stale checks (callback leak)
            self._unlisten_all(subs)
            self._detach_if_mirrored(sec.name)  # no-op after cutover

    # ---------------------------------------------------------------------
    # Strategy 2: MS2M + Threshold-Based Cutoff (paper Fig. 3, Eq. 5)
    # ---------------------------------------------------------------------
    def _ms2m_cutoff(self, source: Pod, target_node: str,
                     _identity=None, *, n: Optional[int] = None) -> Generator:
        assert self.cutoff is not None, "ms2m_cutoff needs a CutoffController"
        deadline = self.cutoff.threshold()
        result = yield from self._ms2m_individual(
            source, target_node, deadline=deadline, n=n)
        return result

    # ---------------------------------------------------------------------
    # Strategy 4: MS2M + iterative delta pre-copy (beyond paper)
    # ---------------------------------------------------------------------
    def _ms2m_precopy(self, source: Pod, target_node: str,
                      _identity=None, *, n: Optional[int] = None) -> Generator:
        result = yield from self._ms2m_individual(
            source, target_node, precopy=True, strategy_name="ms2m_precopy",
            n=n)
        return result

    # ---------------------------------------------------------------------
    # Strategy 3: MS2M for StatefulSet pods (paper Fig. 4)
    # ---------------------------------------------------------------------
    def _ms2m_statefulset(self, source: Pod, target_node: str,
                          identity: Optional[str] = None, *,
                          n: Optional[int] = None) -> Generator:
        n = self._n if n is None else n
        t = self.api.timings
        identity = identity or f"sts-{source.name}"
        rep = MigrationReport("ms2m_statefulset", self.sim.now)
        sec = self.broker.attach_secondary(self.primary_queue,
                                           f"{self.primary_queue}.sec{n}")
        subs: List = []

        try:
            # with precopy, BOTH stop-phase costs of Fig. 4 shrink: the
            # final marker is late (bounded replay) and the target node's
            # layer cache is warm (near-zero pull)
            push = yield from self._transfer(
                source, target_node, rep, self.precopy,
                f"{self.primary_queue}-sts-pre{n}",
                f"{self.primary_queue}-sts{n}")

            # -- stop source after the checkpoint-transfer phase (Fig. 4) ----
            down0 = self.sim.now
            source.pause()
            rep.cutoff_id = source.worker.last_msg_id  # the cutoff message id

            t0 = self.sim.now
            yield from self.api.delete_pod(source.name,
                                           statefulset_identity=identity)
            self._phase(rep, "identity_release", t0)

            t0 = self.sim.now
            worker = self.make_worker()
            worker.skip_until = rep.checkpoint_marker
            replay_ms = source.processing_ms / self.replay_speedup
            target = yield from self.api.create_pod(
                f"{source.name}-target-{n}", target_node, worker, sec,
                statefulset_identity=identity, processing_ms=replay_ms)
            yield from self.api.pull_and_restore(push.image_id, worker,
                                                 node_name=target_node)
            self._phase(rep, "service_restoration", t0)

            # -- replay up to the cutoff message id ---------------------------
            t0 = self.sim.now
            base_processed = worker.n_processed
            target.start()
            drained = self._drain_condition(target, rep.cutoff_id, sec, subs)
            yield drained
            self._phase(rep, "message_replay", t0)

            t0 = self.sim.now
            self._switch_to_primary(target, sec.name)
            target.processing_ms = source.processing_ms
            yield t.route_switch_s
            rep.downtime = self.sim.now - down0
            self._phase(rep, "cutover", t0)

            rep.replayed_messages = worker.n_processed - base_processed
            rep.t_end = self.sim.now
            return rep, target
        finally:
            self._unlisten_all(subs)
            self._detach_if_mirrored(sec.name)  # no-op after cutover
