"""Pluggable migration strategies: registry, base class and the
composable phase primitives strategies are built from.

A strategy is a registered class::

    @register_strategy("my_scheme")
    class MyScheme(MigrationStrategy):
        def run(self, ctx):            # a sim generator
            sec = ctx.attach_secondary()
            push = yield from ctx.transfer(use_precopy=False,
                                           pre_tag="t-pre", full_tag="t")
            ...

``MigrationManager.migrate("my_scheme", ...)`` resolves the name through
the registry — the manager core knows nothing about individual schemes, so
new scenarios are added without touching it.

The building blocks live here too:

  * transfer engines — ``SingleShotTransfer`` (one checkpoint + full image
    push) and ``IterativePrecopyTransfer`` (checkpoint -> delta-push rounds
    with target-node prefetch until the dirty set converges);
  * catch-up disciplines — ``LiveSyncCatchup`` (target chases the live
    source), ``ThresholdCutoffCatchup`` (live sync under the Eq. 5
    deadline, draining to a frozen id once it fires) and
    ``StopThenReplayCatchup`` (source already stopped; bounded replay to
    its last processed id);
  * cutover steps and the listener/condition helpers migrations use to
    observe pod progress without leaking callbacks.

``MigrationContext`` carries the per-migration state (source, target node,
policy, report, secondary queue, listener subscriptions) and exposes the
primitives as methods, so a strategy body reads as its phase pipeline.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Generator, List, Optional, Type

from repro.cluster.cluster import APIServer, Pod
from repro.cluster.sim import Condition, Sim
from repro.core.policy import MigrationPolicy, MigrationReport


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type["MigrationStrategy"]] = {}


class TargetNodeLost(RuntimeError):
    """The migration's target node died (crash or partition) at a point
    where the migration could never make progress again — raised instead
    of hanging forever on a catch-up condition a dead pod cannot satisfy."""


class MigrationError(RuntimeError):
    """A migration failed and its rollback ran; carries the context so
    callers (the orchestrator retry loop) can see what the rollback
    restored.  ``str()`` is the *cause*'s message, so failure reports
    read the same as before the rollback layer existed."""

    def __init__(self, context: "MigrationContext", cause: BaseException):
        super().__init__(f"{type(cause).__name__}: {cause}")
        self.context = context
        self.cause = cause


def register_strategy(name: str) -> Callable[[Type["MigrationStrategy"]],
                                             Type["MigrationStrategy"]]:
    """Class decorator adding a strategy to the global registry."""

    def deco(cls: Type["MigrationStrategy"]) -> Type["MigrationStrategy"]:
        if not issubclass(cls, MigrationStrategy):
            raise TypeError(f"{cls!r} must subclass MigrationStrategy")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_strategy(name: str) -> Type["MigrationStrategy"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown migration strategy {name!r}; "
            f"available: {available_strategies()}") from None


def available_strategies() -> List[str]:
    return sorted(_REGISTRY)


def registry_entries() -> List[Dict[str, Any]]:
    """One row per registered strategy: name, control-plane flags and the
    docstring's first paragraph.  This is the single source for the CLI's
    ``--list-strategies`` output and the README strategy table
    (``tools/check_docs.py`` regenerates and diffs the table from it, so
    the docs cannot drift from the code)."""
    rows = []
    for name in available_strategies():
        cls = _REGISTRY[name]
        doc = (cls.__doc__ or "").strip()
        summary = " ".join(line.strip()
                           for line in doc.split("\n\n")[0].splitlines())
        rows.append({"name": name,
                     "wants_cutoff": cls.wants_cutoff,
                     "handles_identity": cls.handles_identity,
                     "summary": summary})
    return rows


# ---------------------------------------------------------------------------
# Pod-observation helpers (listener bookkeeping + wait conditions)
# ---------------------------------------------------------------------------

def listen(pod: Pod, fn: Callable, subs: List) -> None:
    """Subscribe ``fn`` to the pod's processed events, recording the
    subscription so the migration can deregister it on completion."""
    pod.add_on_processed(fn)
    subs.append((pod, fn))


def unlisten_all(subs: List) -> None:
    for pod, fn in subs:
        pod.remove_on_processed(fn)
    subs.clear()


def sync_condition(sim: Sim, target_pod: Pod, source_pod: Pod,
                   secondary, subs: List) -> Condition:
    """Triggered when target has replayed everything the source has
    processed and the mirror buffer is empty."""
    cond = sim.condition("synced")

    def check(*_):
        if (secondary.depth() == 0
                and target_pod.worker.last_msg_id >= source_pod.worker.last_msg_id):
            cond.trigger()

    listen(target_pod, check, subs)
    listen(source_pod, check, subs)
    check()
    return cond


def drain_condition(sim: Sim, target_pod: Pod, up_to_id: int,
                    secondary, subs: List) -> Condition:
    """Triggered when target has replayed ids <= up_to_id.

    The empty-mirror short-circuit exists for ids the mirror can never
    deliver (messages consumed from the primary before the secondary
    was attached).  It may only fire when no more mirrored traffic can
    arrive for the target: the mirror is empty AND nothing is in
    flight (mid-service) at the target — a momentarily-empty mirror
    while the last mirrored message is still being folded must NOT
    trigger a premature cutover (that dropped the in-flight message's
    state update from the downtime accounting and switched routes
    before the target was caught up)."""
    cond = sim.condition("drained")

    def check(*_):
        if target_pod.worker.last_msg_id >= up_to_id or (
                secondary.depth() == 0 and not target_pod.busy):
            cond.trigger()

    listen(target_pod, check, subs)
    check()
    return cond


# ---------------------------------------------------------------------------
# Per-migration context: state + phase primitives
# ---------------------------------------------------------------------------

class MigrationContext:
    """Everything one migration needs: control-plane handles, the policy,
    the report under construction, and the phase primitives."""

    def __init__(self, manager, source: Pod, target_node: str,
                 identity: Optional[str], policy: MigrationPolicy,
                 strategy_name: str, n: int):
        self.manager = manager
        self.api: APIServer = manager.api
        self.sim: Sim = manager.sim
        self.broker = manager.broker
        self.make_worker = manager.make_worker
        self.primary_queue: str = manager.primary_queue
        self.cutoff = manager.cutoff
        self.policy = policy
        self.source = source
        self.target_node = target_node
        self.identity = identity
        self.n = n
        self.report = MigrationReport(strategy_name, self.sim.now)
        self.report.compression = (
            policy.compression if isinstance(policy.compression, str)
            else str(policy.compression))
        self.subs: List = []   # processed-event listeners, removed on cleanup
        self.secondary = None  # the mirror queue, once attached
        # crash-consistency state: what rollback() needs to undo a half-run
        # migration, and what the retry loop needs to pick up afterwards
        self.target: Optional[Pod] = None    # the target pod, once created
        self.pushed_images: List[str] = []   # every image this attempt pushed
        self.switched = False      # past the commit point (route switched)
        self.closed = False        # cleanup ran (disarms stray timers)
        self.rolled_back = False   # rollback restored the workload
        self.restored_source: Optional[Pod] = None
        self.rollback_error: Optional[BaseException] = None
        if self.sim.sanitizer is not None:
            # this migration now owns the source: if a previous attempt's
            # rollback armed a stale-pause watchpoint on it, disarm it —
            # pausing the source is legitimate again
            self.sim.sanitizer.unprotect_pod(source)

    # -- trace ----------------------------------------------------------------
    def emit(self, kind: str, **data: Any):
        ev = self.report.emit(kind, self.sim.now, **data)
        # fan out to control-plane listeners (fault injection phase
        # triggers, test probes) with the migration's identity attached
        self.api.notify_migration(
            kind, self.sim.now,
            {**data, "queue": self.primary_queue,
             "strategy": self.report.strategy, "n": self.n})
        return ev

    def phase(self, name: str, t0: float) -> None:
        self.emit("phase", phase=name, duration=self.sim.now - t0)

    # -- mirror / conditions --------------------------------------------------
    def attach_secondary(self):
        self.secondary = self.broker.attach_secondary(
            self.primary_queue, f"{self.primary_queue}.sec{self.n}")
        return self.secondary

    def sync_condition(self, target: Pod) -> Condition:
        return sync_condition(self.sim, target, self.source, self.secondary,
                              self.subs)

    def drain_condition(self, target: Pod, up_to_id: int) -> Condition:
        return drain_condition(self.sim, target, up_to_id, self.secondary,
                               self.subs)

    def wait(self, cond: Condition) -> Generator:
        """Block on ``cond``, racing it against target-node death.

        Catch-up and drain conditions are satisfied by the *target pod*
        making progress; a dead target node means they can never trigger,
        so a migration that yielded them bare would hang forever instead
        of failing into the rollback/retry path.  Every discipline and
        cutover wait routes through here."""
        node = self.api.nodes.get(self.target_node)
        if node is None or node.down is None:
            yield cond
            return
        if not node.alive:
            raise TargetNodeLost(f"target node {self.target_node} is down")
        if not cond.triggered:
            yield self.sim.any_of(cond, node.down)
        if not cond.triggered:
            raise TargetNodeLost(
                f"target node {self.target_node} died mid-migration")

    def ensure_target(self, target: Pod) -> None:
        """Fail fast if the target pod can no longer serve (its node died
        or it was killed): committing a cutover onto a dead target would
        silently lose the workload."""
        if target.deleted or not target.node.alive:
            raise TargetNodeLost(
                f"target pod {target.name} lost (node "
                f"{target.node.name} {'dead' if not target.node.alive else 'ok'})")

    def switch_to_primary(self, target: Pod) -> None:
        self.ensure_target(target)  # last check before the commit point
        self.broker.detach_secondary(self.primary_queue, self.secondary.name)
        target.queue = self.broker.queues[self.primary_queue]
        target.wake()  # unblock if it was waiting on the secondary
        self.switched = True

    def cleanup(self) -> None:
        """Always-run teardown: deregister listeners (repeated migrations
        of one lineage must not fire stale checks) and detach the mirror
        if the migration died before cutover (an orphan mirror would
        double-buffer every future publish into a queue nothing drains).
        Sets ``closed`` so stray timers (a cutoff deadline armed for this
        migration) can tell the migration is over and must not touch the
        source again."""
        self.closed = True
        unlisten_all(self.subs)
        if (self.secondary is not None
                and self.broker.is_mirrored(self.primary_queue,
                                            self.secondary.name)):
            self.broker.detach_secondary(self.primary_queue,
                                         self.secondary.name)

    def rollback(self, cause: BaseException) -> Generator:
        """Transactional abort: leave the workload as if this attempt had
        never started.  Steps (all idempotent):

          1. listeners deregistered, the cutoff/sync mirror torn down
             (``cleanup``);
          2. the half-built target pod deleted — releasing a StatefulSet
             identity it claimed; a pod that died with its node leaves
             only identity bookkeeping to clear;
          3. every image this attempt pushed deleted from the registry
             and orphaned chunks garbage-collected (half-pushed delta
             lineages do not leak storage);
          4. the source serving again: resumed in place when it was only
             paused, or re-created from its still-live worker object —
             re-claiming its identity — when the strategy had already
             deleted it (the stop-then-replay paths).

        Sets ``rolled_back`` when the source is provably serving again.
        A dead source node leaves it False: there is nothing to roll back
        *to* — that is the journal/heartbeat recovery path's job, not the
        migration layer's."""
        self.emit("rollback_begin", cause=f"{type(cause).__name__}: {cause}")
        self.cleanup()
        api, source = self.api, self.source
        # -- target remnants --------------------------------------------------
        tgt = self.target
        if tgt is not None and not self.switched:
            identity = (self.identity
                        if self.identity is not None
                        and api.statefulsets.identities.get(self.identity)
                        == tgt.name else None)
            if tgt.name in api.pods:
                yield from api.delete_pod(tgt.name,
                                          statefulset_identity=identity,
                                          graceful=False)
            elif identity is not None:
                # the pod died with its node; only the claim survives
                api.statefulsets.release(identity)
            self.target = None
        # -- registry garbage -------------------------------------------------
        if self.pushed_images:
            removed = sum(api.registry.delete_image(i)
                          for i in reversed(self.pushed_images))
            chunks, freed = api.registry.gc()
            self.emit("rollback_gc", images=removed, chunks=chunks,
                      bytes_freed=freed)
            self.pushed_images.clear()
        # -- source back in service -------------------------------------------
        if not source.deleted:
            if source.paused:
                source.resume()
            self.restored_source = source
            self.rolled_back = True
        elif source.node.alive and source.name not in api.pods:
            # the strategy deleted the source before the failure (the
            # stop-then-replay paths); its worker object still holds the
            # full state, so re-create the pod around it
            identity = None
            if (self.identity is not None
                    and api.statefulsets.identities.get(self.identity)
                    is None):
                identity = self.identity
            pod = yield from api.create_pod(
                source.name, source.node.name, source.worker,
                self.broker.queues[self.primary_queue],
                statefulset_identity=identity,
                processing_ms=source.processing_ms)
            pod.start()
            self.restored_source = pod
            self.rolled_back = True
        if self.rolled_back and self.sim.sanitizer is not None:
            # arm the stale-pause watchpoint: nothing owns this pod now, so
            # any later pause() is a timer that outlived its migration
            self.sim.sanitizer.protect_pod(self.restored_source)
        self.emit("rollback_end", rolled_back=self.rolled_back,
                  restored_source=(self.restored_source.name
                                   if self.restored_source else None))

    # -- transfer phase -------------------------------------------------------
    def transfer(self, use_precopy: bool, pre_tag: str,
                 full_tag: str) -> Generator:
        """Checkpoint-transfer phase via the policy-selected engine."""
        engine = (IterativePrecopyTransfer(pre_tag) if use_precopy
                  else SingleShotTransfer(full_tag))
        push = yield from engine.run(self)
        return push

    def full_transfer(self, tag: str) -> Generator:
        """Checkpoint + full image push, with phase/report accounting.
        Returns (checkpoint dict, PushReport)."""
        rep = self.report
        t0 = self.sim.now
        ckpt = yield from self.api.checkpoint_pod(self.source)  # still serving
        rep.checkpoint_marker = ckpt["last_msg_id"]
        self.phase("checkpoint", t0)

        t0 = self.sim.now
        # the image id is recorded via on_pushed BEFORE the wire transfer,
        # which can abort: a half-pushed image must still be reachable by
        # rollback's garbage collection
        push = yield from self.api.build_and_push_image(
            ckpt, tag, node_name=self.source.node.name,
            on_pushed=self.pushed_images.append)
        rep.image_id = push.image_id
        rep.image_written_bytes = push.written_bytes
        rep.image_deduped_bytes = push.deduped_bytes
        rep.image_raw_bytes += push.delta_bytes
        rep.image_wire_bytes += push.wire_bytes
        self.phase("image_build_push", t0)
        return ckpt, push

    # -- target restoration ---------------------------------------------------
    def restore_target(self, push, queue, *, replay: bool = True,
                       identity: Optional[str] = None) -> Generator:
        """Create the target pod and restore the pushed image into it.
        With ``replay`` the pod consumes at the (possibly batched) replay
        rate until cutover restores the service rate."""
        t0 = self.sim.now
        worker = self.make_worker()
        worker.skip_until = self.report.checkpoint_marker
        proc_ms = self.source.processing_ms
        if replay:
            proc_ms = proc_ms / self.policy.replay_speedup
        target = yield from self.api.create_pod(
            f"{self.source.name}-target-{self.n}", self.target_node, worker,
            queue, statefulset_identity=identity, processing_ms=proc_ms)
        self.target = target  # rollback deletes a half-restored target
        yield from self.api.pull_and_restore(push.image_id, worker,
                                             node_name=self.target_node)
        self.ensure_target(target)  # a flat-link pull ignores node death
        self.phase("service_restoration", t0)
        return target

    # -- cutover / teardown steps ---------------------------------------------
    def finish(self, target: Pod) -> None:
        self.report.t_end = self.sim.now
        self.emit("migration_end", target=target.name,
                  downtime=self.report.downtime)

    def teardown_source(self) -> Generator:
        t0 = self.sim.now
        yield from self.api.delete_pod(self.source.name)
        self.phase("source_teardown", t0)

    # -- telemetry probes (used by adaptive strategies) -----------------------
    def state_nbytes(self) -> int:
        """Approximate serialized size of the source worker's state tree —
        the wire cost of one full checkpoint image."""
        return worker_state_nbytes(self.source.worker)

    def observed_rates(self) -> tuple:
        """(lambda, mu) estimates: the CutoffController's view when one is
        wired (EWMA estimates or operator fallbacks), else a windowed
        recent-arrival-rate estimate on the primary queue and the service
        capacity implied by the pod's processing time."""
        if self.cutoff is not None:
            return self.cutoff.lam, self.cutoff.mu
        q = self.broker.queues[self.primary_queue]
        q.sync(self.sim.now)  # count lazily-drawn arrivals due by now
        lam = recent_arrival_rate(q, self.source, self.sim.now)
        mu = 1000.0 / self.source.processing_ms
        return lam, mu


def recent_arrival_rate(queue, pod, now: float, *,
                        halflife: float = 10.0,
                        max_samples: int = 256) -> float:
    """Windowed/EWMA recent arrival rate (events/s) on a queue at ``now``.

    Replaces the lifetime average ``total_published / now``, which is
    badly stale under diurnal / flash-crowd traffic (a spike an hour ago
    and a spike right now read the same) and biased low for queues whose
    source attached late (it divides by time the queue did not exist).

    Recent arrival timestamps are reconstructed from what the broker and
    consumer still hold at the decision instant — ids are dense, so the
    unconsumed backlog is exactly the *newest* arrivals — extended with
    the consumer's recent service completions when the backlog is short
    (a drained queue folds each message within one service time of its
    arrival, so completion spacing tracks arrival spacing).  The merged
    timestamps feed the same EWMA :class:`~repro.core.cutoff.RateEstimator`
    the CutoffController uses.  With fewer than two recent samples the
    estimate falls back to the lifetime average (exact for a fresh
    queue, and the legacy value when there is nothing better)."""
    from repro.core.cutoff import RateEstimator

    window_s = 6.0 * halflife
    t_min = now - window_s
    backlog = [m.publish_time for m in queue._items if m.publish_time >= t_min]
    samples = backlog
    if len(backlog) < max_samples and pod is not None \
            and getattr(pod, "keep_service_log", False):
        # completions are for *consumed* ids, backlog times for unconsumed
        # ones — disjoint messages, so merging them never double-counts
        svc = [t for t, _ in pod.service_log[-max_samples:] if t >= t_min]
        samples = sorted(svc + backlog)
    samples = samples[-max_samples:]
    if len(samples) < 2:
        return queue.total_published / now if now > 0 else 0.0
    est = RateEstimator(halflife=halflife)
    for t in samples:
        est.observe(t)
    return est.rate


def tree_nbytes(tree: Any) -> int:
    """Approximate serialized size of a state pytree."""
    if isinstance(tree, dict):
        return sum(tree_nbytes(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return sum(tree_nbytes(v) for v in tree)
    nbytes = getattr(tree, "nbytes", None)
    if nbytes is not None:
        return int(nbytes)
    if isinstance(tree, (bytes, bytearray)):
        return len(tree)
    return 8  # python scalar


def worker_state_nbytes(worker: Any) -> int:
    """State size of a worker, preferring its own ``state_nbytes()``
    (copy-free) over measuring a full ``state_tree()`` snapshot — workers
    whose snapshots copy large buffers should implement the former."""
    probe = getattr(worker, "state_nbytes", None)
    if callable(probe):
        return int(probe())
    return tree_nbytes(worker.state_tree())


# ---------------------------------------------------------------------------
# Transfer engines
# ---------------------------------------------------------------------------

class TransferEngine:
    """Moves the source's state image to where the target can restore it.
    ``run(ctx)`` returns the final PushReport (and records the checkpoint
    marker on the report)."""

    def run(self, ctx: MigrationContext) -> Generator:
        raise NotImplementedError


class SingleShotTransfer(TransferEngine):
    """One checkpoint + one full image push (the paper's scheme)."""

    def __init__(self, tag: str):
        self.tag = tag

    def run(self, ctx: MigrationContext) -> Generator:
        _, push = yield from ctx.full_transfer(self.tag)
        return push


class IterativePrecopyTransfer(TransferEngine):
    """One full checkpoint+push, then checkpoint -> delta-push rounds while
    the source keeps serving.  Every image is prefetched onto the target
    node, so the final restore pulls ~nothing; the loop stops when the
    inter-round dirty set (messages processed between two consecutive
    checkpoints) converges.  The replay log left for the target is bounded
    by the LAST round's traffic instead of the whole transfer."""

    def __init__(self, tag: str):
        self.tag = tag

    def run(self, ctx: MigrationContext) -> Generator:
        api, sim, rep, pol = ctx.api, ctx.sim, ctx.report, ctx.policy
        source, tag = ctx.source, self.tag
        base = source.worker.last_msg_id  # lineage may predate this migration
        ckpt, push = yield from ctx.full_transfer(f"{tag}-r0")
        t0 = sim.now
        yield from api.prefetch_image(ctx.target_node, push.image_id)
        ctx.phase("precopy_prefetch", t0)
        rep.precopy_round_bytes.append(push.delta_bytes)
        rep.precopy_round_wire_bytes.append(push.wire_bytes)
        rep.precopy_round_dirty.append(ckpt["last_msg_id"] - base)
        marker = ckpt["last_msg_id"]
        ctx.emit("precopy_round", round=0, bytes=push.delta_bytes,
                 wire=push.wire_bytes, dirty=ckpt["last_msg_id"] - base)

        lossy_lineage = False
        prev_dirty: Optional[int] = None
        while rep.precopy_rounds < pol.precopy_max_rounds:
            # phases stay comparable across strategies: dumps are always
            # booked as "checkpoint", only delta build/push/prefetch as
            # the precopy-specific phases
            t0 = sim.now
            ckpt = yield from api.checkpoint_pod(source)
            ctx.phase("checkpoint", t0)
            dirty = ckpt["last_msg_id"] - marker
            if dirty <= pol.precopy_min_dirty:
                # nothing dirtied since the last round (e.g. source already
                # paused by the cutoff): the previous image already holds
                # this exact state — don't pay for a bit-identical push
                break
            t0 = sim.now
            delta = yield from api.push_delta_image(
                ckpt, f"{tag}-r{rep.precopy_rounds + 1}", push.image_id,
                compression=pol.compression, node_name=source.node.name,
                on_pushed=ctx.pushed_images.append)
            yield from api.prefetch_image(ctx.target_node, delta.image_id)
            ctx.phase("precopy_delta", t0)
            push = delta
            marker = ckpt["last_msg_id"]
            lossy_lineage = lossy_lineage or delta.lossy
            rep.precopy_rounds += 1
            rep.precopy_round_bytes.append(delta.delta_bytes)
            rep.precopy_round_wire_bytes.append(delta.wire_bytes)
            rep.precopy_round_dirty.append(dirty)
            rep.image_written_bytes += delta.written_bytes
            rep.image_deduped_bytes += delta.deduped_bytes
            rep.image_raw_bytes += delta.delta_bytes
            rep.image_wire_bytes += delta.wire_bytes
            ctx.emit("precopy_round", round=rep.precopy_rounds,
                     bytes=delta.delta_bytes, wire=delta.wire_bytes,
                     dirty=dirty)
            if (prev_dirty is not None
                    and dirty >= prev_dirty * pol.precopy_converge_ratio):
                break  # dirty set stopped shrinking: steady state reached
            prev_dirty = dirty
        if lossy_lineage:
            # lossy codec rounds warm the wire cheaply, but the image that
            # is actually restored at cutover must decode bit-exactly:
            # flush the residual (truth minus the receiver's lossy
            # reconstruction) with lossless codecs only
            t0 = sim.now
            flush = yield from api.push_delta_image(
                ckpt, f"{tag}-exact", push.image_id,
                compression=pol.compression, exact=True,
                node_name=source.node.name,
                on_pushed=ctx.pushed_images.append)
            yield from api.prefetch_image(ctx.target_node, flush.image_id)
            ctx.phase("precopy_exact_flush", t0)
            push = flush
            # the flush ships the LAST dump, which (with precopy_min_dirty
            # > 0) may be ahead of the last pushed round: the marker must
            # describe the image actually restored
            marker = ckpt["last_msg_id"]
            rep.precopy_rounds += 1
            rep.precopy_round_bytes.append(flush.delta_bytes)
            rep.precopy_round_wire_bytes.append(flush.wire_bytes)
            rep.precopy_round_dirty.append(0)
            rep.image_written_bytes += flush.written_bytes
            rep.image_deduped_bytes += flush.deduped_bytes
            rep.image_raw_bytes += flush.delta_bytes
            rep.image_wire_bytes += flush.wire_bytes
            ctx.emit("precopy_exact_flush", bytes=flush.delta_bytes,
                     wire=flush.wire_bytes)
        rep.checkpoint_marker = marker
        rep.image_id = push.image_id
        return push


# ---------------------------------------------------------------------------
# Catch-up disciplines
# ---------------------------------------------------------------------------

class CatchupDiscipline:
    """How the target catches up with mirrored traffic before cutover.

    ``arm`` runs when accumulation starts (secondary attached, before the
    transfer); ``catchup`` runs after the target is restored and started;
    ``begin_cutover`` pauses the source (or reuses an earlier stop) and
    returns the instant downtime started."""

    def arm(self, ctx: MigrationContext) -> None:
        pass

    def catchup(self, ctx: MigrationContext, target: Pod) -> Generator:
        raise NotImplementedError
        yield  # pragma: no cover

    def begin_cutover(self, ctx: MigrationContext) -> float:
        ctx.source.pause()
        return ctx.sim.now


class LiveSyncCatchup(CatchupDiscipline):
    """Target replays the mirror while the source keeps serving, until it
    has seen everything the source has (paper Fig. 2)."""

    def catchup(self, ctx: MigrationContext, target: Pod) -> Generator:
        yield from ctx.wait(ctx.sync_condition(target))


class ThresholdCutoffCatchup(CatchupDiscipline):
    """Live sync under the Threshold-Based Cutoff (paper Fig. 3, Eq. 5):
    when T_accum hits the deadline, the SOURCE STOPS — even mid-transfer —
    capping the replay log at N <= lam * T_cutoff so that
    T_replay <= T_replay_max by construction."""

    def __init__(self, deadline: float):
        self.deadline = deadline
        self.state: dict = {"fired": False, "pause_time": None, "id": None}

    def arm(self, ctx: MigrationContext) -> None:
        self.fired_cond = ctx.sim.condition("cutoff-fired")
        source, state = ctx.source, self.state

        def _fire():
            if ctx.closed:
                # the migration is over; the deadline correctly disarms
                # itself (the sanitizer counts these — a *missing* guard
                # here is exactly what its stale-pause watchpoint catches)
                if ctx.sim.sanitizer is not None:
                    ctx.sim.sanitizer.note_disarmed_timer()
                return
            if (not state["fired"] and not source.paused
                    and not source.deleted):
                state["fired"] = True
                state["pause_time"] = ctx.sim.now
                source.pause()
                state["id"] = source.worker.last_msg_id
                ctx.emit("cutoff_fired", cutoff_id=state["id"],
                         deadline=self.deadline)
                self.fired_cond.trigger()

        ctx.sim.call_at(ctx.sim.now + self.deadline, _fire)

    def catchup(self, ctx: MigrationContext, target: Pod) -> Generator:
        if self.state["fired"]:
            # source already stopped (deadline expired mid-transfer):
            # bounded replay to the frozen cutoff id
            yield from ctx.wait(ctx.drain_condition(target, self.state["id"]))
            return
        synced = ctx.sync_condition(target)
        yield from ctx.wait(ctx.sim.any_of(synced, self.fired_cond))
        if self.state["fired"] and not synced.triggered:
            # fired mid-catch-up: bounded drain to the frozen id
            yield from ctx.wait(ctx.drain_condition(target, self.state["id"]))

    def begin_cutover(self, ctx: MigrationContext) -> float:
        if self.state["fired"]:
            ctx.report.cutoff_fired = True
            ctx.report.cutoff_id = self.state["id"]
            return self.state["pause_time"]  # downtime began at the pause
        ctx.source.pause()
        return ctx.sim.now


class StopThenReplayCatchup(CatchupDiscipline):
    """Source is already stopped (sticky-identity handoff, paper Fig. 4):
    bounded replay of the mirror up to the source's last processed id."""

    def __init__(self, up_to_id: int):
        self.up_to_id = up_to_id

    def catchup(self, ctx: MigrationContext, target: Pod) -> Generator:
        yield from ctx.wait(ctx.drain_condition(target, self.up_to_id))


# ---------------------------------------------------------------------------
# Strategy base class
# ---------------------------------------------------------------------------

class MigrationStrategy:
    """One migration scheme, expressed as a pipeline of phase primitives.

    Subclass, implement ``run(ctx)`` as a sim generator returning
    ``(report, target_pod)``, and register with ``@register_strategy``.
    Class attributes declare control-plane needs so harnesses and the
    manager stay scheme-agnostic:

      * ``handles_identity`` — may receive a StatefulSet identity handoff;
      * ``wants_cutoff``     — harnesses should provision a
        CutoffController (consulted via ``ctx.cutoff``).
    """

    name: str = "?"                 # set by @register_strategy
    handles_identity: bool = False
    wants_cutoff: bool = False

    def run(self, ctx: MigrationContext) -> Generator:
        raise NotImplementedError
        yield  # pragma: no cover
