from repro.checkpoint.registry import (  # noqa: F401
    ChunkStore,
    Registry,
    PushReport,
)
from repro.checkpoint.checkpointer import Checkpointer  # noqa: F401
