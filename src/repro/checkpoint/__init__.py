from repro.checkpoint.registry import (  # noqa: F401
    ChunkStore,
    Registry,
    PushReport,
)
from repro.checkpoint.checkpointer import Checkpointer  # noqa: F401
from repro.checkpoint.codecs import (  # noqa: F401
    COMPRESSION_CHOICES,
    DeltaCodec,
    get_codec,
    resolve_compression,
    validate_compression,
)
from repro.checkpoint.fingerprint import leaf_fingerprints  # noqa: F401
