"""Forensic-checkpointing analogue: content-addressed checkpoint registry.

The paper checkpoints containers with CRIU, builds OCI images with Buildah
and pushes them to an artifact registry, decoupling source and target nodes.
Our unit of state is a well-typed pytree, so the "image" is:

  * chunks: the leaf bytes, split into fixed-size segments, each stored
    once under its sha256 (content addressing = layer dedup: pushing a
    serving replica's image re-uploads *only* the KV-cache chunks — the
    weight chunks are already in the registry, exactly like a container
    image's cached base layers, cf. Ma et al. [12]).
  * manifest: pickled treedefs + per-leaf chunk lists, itself stored by
    hash; the image id is the manifest hash (immutable, verifiable —
    the "forensic" property).
  * delta manifests: ``push_delta`` references a *parent* image id; the
    wire cost of the push is only the chunks absent from the parent
    (content addressing gives chunk-level diffing for free), which is
    what makes iterative pre-copy rounds cheap — each round uploads the
    dirty set since the previous checkpoint, not the whole state.

Every push/pull returns a byte report; the cluster runtime charges
virtual-clock transfer time from those bytes.  Pulls can be told which
chunks the puller already holds (``have_chunks``) so a node that
prefetched the parent image pays only for the delta.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import pickle
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax

CHUNK_BYTES = 4 * 1024 * 1024


@dataclasses.dataclass
class PushReport:
    image_id: str
    total_bytes: int
    written_bytes: int  # after dedup (new to the registry store)
    deduped_bytes: int
    num_chunks: int
    parent_id: Optional[str] = None
    # wire bytes relative to the parent image (== total_bytes for a full
    # push): what a client holding the parent must upload
    delta_bytes: int = -1

    def __post_init__(self):
        if self.delta_bytes < 0:
            self.delta_bytes = self.total_bytes


class ChunkStore:
    """Content-addressed blob store (filesystem-backed, thread-safe)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(os.path.join(root, "chunks"), exist_ok=True)
        self._lock = threading.Lock()

    def _path(self, key: str) -> str:
        return os.path.join(self.root, "chunks", key[:2], key)

    def has(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def put(self, data: bytes) -> Tuple[str, bool]:
        """-> (key, newly_written)."""
        key = hashlib.sha256(data).hexdigest()
        path = self._path(key)
        with self._lock:
            if os.path.exists(path):
                return key, False
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, path)  # atomic
        return key, True

    def get(self, key: str) -> bytes:
        with open(self._path(key), "rb") as f:
            return f.read()


def _leaf_to_bytes(x) -> bytes:
    """Self-describing raw encoding (supports ml_dtypes like bfloat16)."""
    arr = np.asarray(x)
    header = json.dumps({"dtype": arr.dtype.name,
                         "shape": list(arr.shape)}).encode()
    return len(header).to_bytes(4, "little") + header + arr.tobytes()


def _resolve_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _leaf_from_bytes(data: bytes):
    n = int.from_bytes(data[:4], "little")
    meta = json.loads(data[4: 4 + n])
    arr = np.frombuffer(data[4 + n:], dtype=_resolve_dtype(meta["dtype"]))
    return arr.reshape(meta["shape"]).copy()


class Registry:
    """The artifact registry: named state trees -> immutable images."""

    def __init__(self, root: str, chunk_bytes: Optional[int] = None):
        self.store = ChunkStore(root)
        self.root = root
        self.chunk_bytes = chunk_bytes or CHUNK_BYTES
        os.makedirs(os.path.join(root, "manifests"), exist_ok=True)
        self._tags: Dict[str, str] = {}
        self._manifests: Dict[str, dict] = {}  # immutable => cache forever
        self._lock = threading.Lock()

    # -- push ---------------------------------------------------------------
    def _push(self, trees: Dict[str, Any], meta: Optional[dict],
              tag: Optional[str], parent: Optional[str]) -> PushReport:
        parent_keys = (set(self.image_chunks(parent))
                       if parent is not None else set())
        total = written = delta = n_chunks = 0
        manifest: Dict[str, Any] = {"trees": {}, "meta": meta or {},
                                    "parent": parent,
                                    "chunk_bytes": self.chunk_bytes}
        for name, tree in trees.items():
            leaves, treedef = jax.tree.flatten(tree)
            leaf_entries: List[dict] = []
            for leaf in leaves:
                data = _leaf_to_bytes(leaf)
                chunks = []
                for off in range(0, len(data), self.chunk_bytes):
                    seg = data[off: off + self.chunk_bytes]
                    key, new = self.store.put(seg)
                    chunks.append(key)
                    total += len(seg)
                    written += len(seg) if new else 0
                    if key not in parent_keys:
                        delta += len(seg)
                        parent_keys.add(key)  # count shared chunks once
                    n_chunks += 1
                leaf_entries.append({"chunks": chunks, "nbytes": len(data)})
            manifest["trees"][name] = {
                "treedef": pickle.dumps(treedef).hex(),
                "leaves": leaf_entries,
            }
        blob = json.dumps(manifest, sort_keys=True).encode()
        image_id = hashlib.sha256(blob).hexdigest()[:24]
        path = os.path.join(self.root, "manifests", image_id + ".json")
        if not os.path.exists(path):
            with open(path + ".tmp", "wb") as f:
                f.write(blob)
            os.replace(path + ".tmp", path)
        if tag:
            with self._lock:
                self._tags[tag] = image_id
        return PushReport(image_id, total, written, total - written, n_chunks,
                          parent_id=parent,
                          delta_bytes=delta if parent is not None else total)

    def push_image(self, trees: Dict[str, Any], meta: Optional[dict] = None,
                   tag: Optional[str] = None) -> PushReport:
        return self._push(trees, meta, tag, parent=None)

    def push_delta(self, trees: Dict[str, Any], parent_image_id: str,
                   meta: Optional[dict] = None,
                   tag: Optional[str] = None) -> PushReport:
        """Delta push: the manifest still lists *every* chunk (a delta image
        is self-contained and immutable), but the wire cost — and the
        report's ``delta_bytes`` — covers only chunks absent from the
        parent image."""
        return self._push(trees, meta, tag, parent=parent_image_id)

    # -- pull ---------------------------------------------------------------
    def _manifest(self, image_id: str) -> dict:
        """Manifests are content-addressed (immutable), so a restore's
        pull/chunk-map/meta triple parses the file once, not three times."""
        cached = self._manifests.get(image_id)
        if cached is not None:
            return cached
        path = os.path.join(self.root, "manifests", image_id + ".json")
        with open(path, "rb") as f:
            manifest = json.loads(f.read())
        with self._lock:
            self._manifests[image_id] = manifest
        return manifest

    def pull_image(self, image_id: str,
                   have_chunks: Optional[set] = None
                   ) -> Tuple[Dict[str, Any], int]:
        """-> (trees, bytes_pulled).

        With ``have_chunks`` (the puller's local chunk cache), only missing
        chunks are charged.  Accounting is per distinct chunk — each chunk
        crosses the wire at most once per pull regardless of how many
        leaves reference it — so a cold pull and a pull with an empty cache
        charge identically, and a node that prefetched the parent image
        pays only for the delta."""
        manifest = self._manifest(image_id)
        chunk_bytes = manifest.get("chunk_bytes") or self.chunk_bytes
        trees = {}
        pulled = 0
        seen = set(have_chunks or ())
        for name, spec in manifest["trees"].items():
            treedef = pickle.loads(bytes.fromhex(spec["treedef"]))
            leaves = []
            for entry in spec["leaves"]:
                data = b"".join(self.store.get(k) for k in entry["chunks"])
                off = 0
                for k in entry["chunks"]:
                    size = min(chunk_bytes, entry["nbytes"] - off)
                    if k not in seen:
                        pulled += size
                        seen.add(k)
                    off += size
                leaves.append(_leaf_from_bytes(data))
            trees[name] = jax.tree.unflatten(treedef, leaves)
        return trees, pulled

    def image_chunks(self, image_id: str) -> Dict[str, int]:
        """Chunk key -> byte size for every chunk of the image."""
        manifest = self._manifest(image_id)
        chunk_bytes = manifest.get("chunk_bytes") or self.chunk_bytes
        out: Dict[str, int] = {}
        for spec in manifest["trees"].values():
            for entry in spec["leaves"]:
                off = 0
                for k in entry["chunks"]:
                    out[k] = min(chunk_bytes, entry["nbytes"] - off)
                    off += chunk_bytes
        return out

    def image_parent(self, image_id: str) -> Optional[str]:
        return self._manifest(image_id).get("parent")

    def delta_chain(self, image_id: str) -> List[str]:
        """Forensic lineage: [image_id, parent, grandparent, ...]."""
        chain = [image_id]
        while True:
            parent = self.image_parent(chain[-1])
            if parent is None:
                return chain
            chain.append(parent)

    def image_meta(self, image_id: str) -> dict:
        return self._manifest(image_id)["meta"]

    def resolve(self, tag: str) -> Optional[str]:
        with self._lock:
            return self._tags.get(tag)

    def list_images(self) -> List[str]:
        d = os.path.join(self.root, "manifests")
        return sorted(p[:-5] for p in os.listdir(d) if p.endswith(".json"))
