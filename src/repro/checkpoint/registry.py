"""Forensic-checkpointing analogue: content-addressed checkpoint registry.

The paper checkpoints containers with CRIU, builds OCI images with Buildah
and pushes them to an artifact registry, decoupling source and target nodes.
Our unit of state is a well-typed pytree, so the "image" is:

  * chunks: the leaf raw bytes, split into fixed-size segments; each
    stored segment sits under the sha256 of its *stored* (possibly
    codec-encoded) bytes (content addressing = layer dedup: pushing a
    serving replica's image re-uploads *only* the KV-cache chunks — the
    weight chunks are already in the registry, exactly like a container
    image's cached base layers, cf. Ma et al. [12]).
  * manifest: pickled treedefs + per-leaf dtype/shape + per-chunk
    ``{key, enc, wire, raw}`` entries, itself stored by hash; the image id
    is the manifest hash (immutable, verifiable — the "forensic"
    property).
  * delta manifests: ``push_delta`` references a *parent* image id; the
    wire cost of the push is only the chunks absent from the parent
    (content addressing gives chunk-level diffing for free), which is
    what makes iterative pre-copy rounds cheap — each round uploads the
    dirty set since the previous checkpoint, not the whole state.

Two data-path optimizations ride on the delta manifests:

  * device-side fingerprints — every leaf is reduced to one 128-bit
    fingerprint per chunk *on device* (``repro.kernels.ops
    .chunk_fingerprint``; Pallas on TPU, blockwise jnp on CPU) and the
    fingerprints are recorded in the manifest.  A delta push compares
    them against the parent's: chunks with equal fingerprints reuse the
    parent's chunk entry without being serialized, encoded or sha-hashed
    on host — dirty detection costs a device reduction plus a tiny host
    compare instead of a full host re-hash of every leaf per round.
  * delta codecs — dirty chunks are run through a per-leaf codec
    (``repro.checkpoint.codecs``: ``none`` / ``xor_rle`` / ``int8``)
    before storage, so the wire carries the *encoded* bytes.  Parent-
    relative codecs record the image (``pim``) they encoded against;
    pulls invert the codec chain back to raw bytes.

Every push/pull returns a byte report distinguishing raw payload bytes
from wire (encoded) bytes; the cluster runtime charges virtual-clock
transfer time from the wire bytes plus a configurable codec/fingerprint
compute cost.  Pulls can be told which chunks the puller already holds
(``have_chunks``) so a node that prefetched the parent image pays only
for the delta.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import numpy as np

import jax

from repro.checkpoint import codecs as _codecs
from repro.checkpoint import fingerprint as _fingerprint
from repro.checkpoint.fingerprint import leaf_fingerprints

CHUNK_BYTES = 4 * 1024 * 1024

CompressionSpec = Union[str, Dict[str, str]]


@dataclasses.dataclass
class PushReport:
    image_id: str
    total_bytes: int    # raw payload bytes across all chunks
    written_bytes: int  # encoded bytes newly written to the store (dedup'd)
    deduped_bytes: int  # raw bytes the store already held (saved vs cold)
    num_chunks: int
    parent_id: Optional[str] = None
    # raw bytes of chunks absent from the parent image (== total_bytes for
    # a full push): the dirty set a client holding the parent must move
    delta_bytes: int = -1
    # encoded bytes of that dirty set: what actually crosses the wire
    wire_bytes: int = -1
    codec: str = "none"          # the compression spec this push ran with
    lossy: bool = False          # any chunk used a lossy codec
    enc_raw_bytes: int = 0       # raw bytes fed through a codec encoder
    fp_bytes: int = 0            # raw bytes fingerprinted on device
    fp_clean_chunks: int = 0     # chunks proven clean by fingerprint alone

    def __post_init__(self):
        if self.delta_bytes < 0:
            self.delta_bytes = self.total_bytes
        if self.wire_bytes < 0:
            self.wire_bytes = self.delta_bytes


class ChunkStore:
    """Content-addressed blob store (filesystem-backed, thread-safe)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(os.path.join(root, "chunks"), exist_ok=True)
        self._lock = threading.Lock()

    def _path(self, key: str) -> str:
        return os.path.join(self.root, "chunks", key[:2], key)

    def has(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def put(self, data: bytes) -> Tuple[str, bool]:
        """-> (key, newly_written)."""
        key = hashlib.sha256(data).hexdigest()
        path = self._path(key)
        with self._lock:
            if os.path.exists(path):
                return key, False
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, path)  # atomic
        return key, True

    def get(self, key: str) -> bytes:
        with open(self._path(key), "rb") as f:
            return f.read()


def _resolve_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _leaf_meta(leaf) -> Tuple[str, Tuple[int, ...], int]:
    """(dtype name, shape, nbytes) without forcing a device->host copy."""
    if isinstance(leaf, (jax.Array, np.ndarray)):
        return leaf.dtype.name, tuple(leaf.shape), int(leaf.nbytes)
    arr = np.asarray(leaf)
    return arr.dtype.name, tuple(arr.shape), int(arr.nbytes)


def _leaf_raw(leaf) -> bytes:
    """C-order raw bytes of the leaf (device->host transfer happens here,
    and only for leaves with at least one dirty chunk)."""
    return np.asarray(leaf).tobytes()


class Registry:
    """The artifact registry: named state trees -> immutable images."""

    def __init__(self, root: str, chunk_bytes: Optional[int] = None):
        self.store = ChunkStore(root)
        self.root = root
        self.chunk_bytes = chunk_bytes or CHUNK_BYTES
        os.makedirs(os.path.join(root, "manifests"), exist_ok=True)
        self._tags: Dict[str, str] = {}
        self._manifests: Dict[str, dict] = {}  # immutable => cache forever
        self._lock = threading.Lock()

    # -- push ---------------------------------------------------------------
    def _parent_leaf(self, parent_manifest: Optional[dict], name: str,
                     i: int, dtype: str, shape, nbytes: int
                     ) -> Optional[dict]:
        """The parent's matching leaf entry, iff its chunk grid is
        compatible (same chunk_bytes + dtype/shape/nbytes => same chunk
        count/sizes)."""
        if (parent_manifest is None
                or parent_manifest.get("chunk_bytes") != self.chunk_bytes):
            return None
        spec = parent_manifest["trees"].get(name)
        if spec is None or i >= len(spec["leaves"]):
            return None
        ent = spec["leaves"][i]
        if (ent["dtype"] != dtype or tuple(ent["shape"]) != tuple(shape)
                or ent["nbytes"] != nbytes):
            return None
        return ent

    def _fused_leaf(self, leaf, codec_name: str, dtype: str, nbytes: int,
                    parent: Optional[str], name: str, i: int, n: int,
                    memo: Dict[tuple, bytes]
                    ) -> Optional[_codecs.FusedLeafEncoding]:
        """A fused fingerprint+encode pass for this leaf, or None when the
        device path doesn't apply and the legacy two-pass flow (device
        fingerprints, host codecs) runs instead.  Outputs are bit/byte-
        identical either way; only the number of reads over the state
        differs."""
        if codec_name not in ("xor_rle", "int8") or not nbytes:
            return None
        if _codecs.codec_backend() != "kernel":
            return None
        if not _fingerprint.supports_chunk_bytes(self.chunk_bytes):
            return None
        if codec_name == "int8" and dtype != "float32":
            # the int8 kernel bitcasts the u32 word view straight to f32;
            # other float dtypes take the host quantizer's astype path
            return None
        arr = _fingerprint.normalize_leaf(leaf)
        if arr is None or arr.size == 0:
            return None
        parent_buf = b"".join(
            self._chunk_raw(parent, name, i, c, memo=memo)
            for c in range(n))
        return _codecs.FusedLeafEncoding(arr, parent_buf, codec_name,
                                         _resolve_dtype(dtype),
                                         self.chunk_bytes)

    def _push(self, trees: Dict[str, Any], meta: Optional[dict],
              tag: Optional[str], parent: Optional[str], *,
              compression: CompressionSpec = "none",
              lossy_ok: bool = False,
              fingerprints: bool = True) -> PushReport:
        _codecs.validate_compression(compression)
        parent_manifest = self._manifest(parent) if parent else None
        parent_keys = (set(self.image_chunks(parent))
                       if parent is not None else set())
        cb = self.chunk_bytes
        total = written = written_raw = delta = wire = n_chunks = 0
        enc_raw = fp_bytes = fp_clean = 0
        parent_raw_memo: Dict[tuple, bytes] = {}
        lossy = False
        manifest: Dict[str, Any] = {"version": 2, "trees": {},
                                    "meta": meta or {}, "parent": parent,
                                    "chunk_bytes": cb}
        for name, tree in trees.items():
            leaves, treedef = jax.tree.flatten(tree)
            leaf_entries: List[dict] = []
            for i, leaf in enumerate(leaves):
                dtype, shape, nbytes = _leaf_meta(leaf)
                n = -(-nbytes // cb) if nbytes else 0
                pleaf = self._parent_leaf(parent_manifest, name, i,
                                          dtype, shape, nbytes)
                codec_name = _codecs.resolve_compression(
                    compression, name, _resolve_dtype(dtype),
                    pleaf is not None, lossy_ok, chunk_bytes=cb)
                fenc = (self._fused_leaf(leaf, codec_name, dtype, nbytes,
                                         parent, name, i, n,
                                         parent_raw_memo)
                        if fingerprints else None)
                if fenc is not None:
                    fps = fenc.fps
                else:
                    fps = (leaf_fingerprints(leaf, cb)
                           if fingerprints else None)
                if fps is not None:
                    fp_bytes += nbytes
                fp_list = (None if fps is None
                           else [[int(w) for w in row] for row in fps])
                pfps = pleaf.get("fps") if pleaf is not None else None
                clean = [False] * n
                if fp_list is not None and pfps is not None and len(pfps) == n:
                    # a null parent fingerprint marks a lossily-encoded
                    # chunk (its decode differs from what was pushed):
                    # never treat it as clean
                    clean = [fp_list[c] is not None
                             and fp_list[c] == pfps[c] for c in range(n)]

                total += nbytes
                n_chunks += n
                chunks: List[dict] = []
                if all(clean) and n:
                    # device fingerprints prove the whole leaf untouched:
                    # reuse the parent's entries without serializing it
                    fp_clean += n
                    chunks = [dict(ch) for ch in pleaf["chunks"]]
                else:
                    # in fused mode the leaf was already read (and
                    # encoded) on device; serialization happens lazily
                    # only for incompressible raw-fallback chunks
                    data = (b"" if fenc is not None
                            else _leaf_raw(leaf) if nbytes else b"")
                    codec = _codecs.get_codec(codec_name)
                    for c in range(n):
                        seg_len = min(cb, nbytes - c * cb)
                        if clean[c]:
                            fp_clean += 1
                            chunks.append(dict(pleaf["chunks"][c]))
                            continue
                        entry = {"raw": seg_len}
                        if codec_name == "none":
                            blob = data[c * cb: (c + 1) * cb]
                        else:
                            if fenc is not None:
                                blob = fenc.blob(c)
                            else:
                                parent_raw = self._chunk_raw(
                                    parent, name, i, c,
                                    memo=parent_raw_memo)
                                blob = codec.encode(
                                    data[c * cb: (c + 1) * cb],
                                    parent_raw, _resolve_dtype(dtype))
                            enc_raw += seg_len
                            if len(blob) >= seg_len:
                                # incompressible: store raw
                                blob = (fenc.raw_seg(c) if fenc is not None
                                        else data[c * cb: (c + 1) * cb])
                            else:
                                entry["enc"] = codec_name
                                entry["pim"] = parent
                                if not codec.lossless:
                                    lossy = True
                                    # the image decodes to the *lossy*
                                    # reconstruction: the pushed leaf's
                                    # fingerprint would misrepresent it
                                    if fp_list is not None:
                                        fp_list[c] = None
                        key, new = self.store.put(blob)
                        entry["key"] = key
                        entry["wire"] = len(blob)
                        if new:
                            written += len(blob)
                            written_raw += seg_len
                        if key not in parent_keys:
                            delta += seg_len
                            wire += len(blob)
                            parent_keys.add(key)  # count shared chunks once
                        chunks.append(entry)
                leaf_entries.append({"dtype": dtype, "shape": list(shape),
                                     "nbytes": nbytes, "chunks": chunks,
                                     "fps": fp_list})
            manifest["trees"][name] = {
                "treedef": pickle.dumps(treedef).hex(),
                "leaves": leaf_entries,
            }
        blob = json.dumps(manifest, sort_keys=True).encode()
        image_id = hashlib.sha256(blob).hexdigest()[:24]
        path = os.path.join(self.root, "manifests", image_id + ".json")
        if not os.path.exists(path):
            with open(path + ".tmp", "wb") as f:
                f.write(blob)
            os.replace(path + ".tmp", path)
        if tag:
            with self._lock:
                self._tags[tag] = image_id
        spec_str = (json.dumps(compression, sort_keys=True)
                    if isinstance(compression, dict) else compression)
        # dedup savings stay in RAW units (total is raw; written is
        # encoded): raw bytes whose chunks the store already held
        return PushReport(image_id, total, written, total - written_raw,
                          n_chunks,
                          parent_id=parent,
                          delta_bytes=delta if parent is not None else total,
                          wire_bytes=wire if parent is not None else total,
                          codec=spec_str, lossy=lossy, enc_raw_bytes=enc_raw,
                          fp_bytes=fp_bytes, fp_clean_chunks=fp_clean)

    def push_image(self, trees: Dict[str, Any], meta: Optional[dict] = None,
                   tag: Optional[str] = None, *,
                   fingerprints: bool = True) -> PushReport:
        return self._push(trees, meta, tag, parent=None,
                          fingerprints=fingerprints)

    def push_delta(self, trees: Dict[str, Any], parent_image_id: str,
                   meta: Optional[dict] = None,
                   tag: Optional[str] = None, *,
                   compression: CompressionSpec = "none",
                   exact: bool = False,
                   fingerprints: bool = True) -> PushReport:
        """Delta push: the manifest still lists *every* chunk, but the wire
        cost — and the report's ``delta_bytes``/``wire_bytes`` — covers
        only chunks absent from the parent image.  ``compression`` selects
        the per-leaf delta codec; ``exact=True`` restricts the choice to
        lossless codecs (the pre-copy engine's final flush).

        Immutability caveat: with ``compression="none"`` the image is
        fully self-contained, but a codec-encoded chunk decodes against
        the image it was encoded against (its ``pim`` entry) — the delta
        image pins its parent lineage, so GC/export must keep the chain
        reachable (``delta_chain``)."""
        return self._push(trees, meta, tag, parent=parent_image_id,
                          compression=compression, lossy_ok=not exact,
                          fingerprints=fingerprints)

    # -- pull ---------------------------------------------------------------
    def _manifest(self, image_id: str) -> dict:
        """Manifests are content-addressed (immutable), so a restore's
        pull/chunk-map/meta triple parses the file once, not three times."""
        cached = self._manifests.get(image_id)
        if cached is not None:
            return cached
        path = os.path.join(self.root, "manifests", image_id + ".json")
        with open(path, "rb") as f:
            manifest = json.loads(f.read())
        if manifest.get("version") != 2:
            raise ValueError(
                f"image {image_id} has manifest version "
                f"{manifest.get('version', 1)}; this registry reads "
                f"version 2 (re-push the state with the current code)")
        with self._lock:
            self._manifests[image_id] = manifest
        return manifest

    def _chunk_raw(self, image_id: str, name: str, li: int, ci: int,
                   charge: Optional[Callable[[str, int], None]] = None,
                   memo: Optional[Dict[tuple, bytes]] = None) -> bytes:
        """Raw bytes of one chunk, inverting the codec chain (an encoded
        chunk decodes against the image it was encoded against, ``pim``).
        ``charge`` is called once per touched chunk for wire accounting;
        ``memo`` (scoped to one push/pull) keeps repeated walks over a
        shared parent chain linear instead of O(chain^2)."""
        mkey = (image_id, name, li, ci)
        if memo is not None and mkey in memo:
            return memo[mkey]
        ent = self._manifest(image_id)["trees"][name]["leaves"][li]
        dtype, ent = ent["dtype"], ent["chunks"][ci]
        blob = self.store.get(ent["key"])
        if charge is not None:
            charge(ent["key"], ent["wire"])
        enc = ent.get("enc", "none")
        if enc == "none":
            raw = blob
        else:
            parent_raw = self._chunk_raw(ent["pim"], name, li, ci, charge,
                                         memo)
            raw = _codecs.get_codec(enc).decode(blob, parent_raw,
                                                _resolve_dtype(dtype))
        if memo is not None:
            memo[mkey] = raw
        return raw

    def pull_image(self, image_id: str,
                   have_chunks: Optional[set] = None
                   ) -> Tuple[Dict[str, Any], int]:
        """-> (trees, wire_bytes_pulled).

        With ``have_chunks`` (the puller's local chunk cache), only missing
        chunks are charged.  Accounting is per distinct chunk — each chunk
        crosses the wire at most once per pull regardless of how many
        leaves reference it — and covers the decode chain too: a delta
        chunk whose codec parents were never prefetched pays for them."""
        manifest = self._manifest(image_id)
        trees = {}
        pulled = 0
        seen = set(have_chunks or ())
        memo: Dict[tuple, bytes] = {}

        def charge(key: str, wire: int):
            nonlocal pulled
            if key not in seen:
                pulled += wire
                seen.add(key)

        for name, spec in manifest["trees"].items():
            treedef = pickle.loads(bytes.fromhex(spec["treedef"]))
            leaves = []
            for li, entry in enumerate(spec["leaves"]):
                data = b"".join(
                    self._chunk_raw(image_id, name, li, ci, charge, memo)
                    for ci in range(len(entry["chunks"])))
                arr = np.frombuffer(data, dtype=_resolve_dtype(entry["dtype"]))
                leaves.append(arr.reshape(entry["shape"]).copy())
            trees[name] = jax.tree.unflatten(treedef, leaves)
        return trees, pulled

    def image_chunks(self, image_id: str) -> Dict[str, int]:
        """Chunk key -> stored (wire) byte size for every chunk of the
        image."""
        manifest = self._manifest(image_id)
        out: Dict[str, int] = {}
        for spec in manifest["trees"].values():
            for entry in spec["leaves"]:
                for ch in entry["chunks"]:
                    out[ch["key"]] = ch["wire"]
        return out

    def image_parent(self, image_id: str) -> Optional[str]:
        return self._manifest(image_id).get("parent")

    def delta_chain(self, image_id: str) -> List[str]:
        """Forensic lineage: [image_id, parent, grandparent, ...]."""
        chain = [image_id]
        while True:
            parent = self.image_parent(chain[-1])
            if parent is None:
                return chain
            chain.append(parent)

    def image_meta(self, image_id: str) -> dict:
        return self._manifest(image_id)["meta"]

    def resolve(self, tag: str) -> Optional[str]:
        with self._lock:
            return self._tags.get(tag)

    def list_images(self) -> List[str]:
        d = os.path.join(self.root, "manifests")
        return sorted(p[:-5] for p in os.listdir(d) if p.endswith(".json"))

    # -- deletion / garbage collection ----------------------------------------
    def delete_image(self, image_id: str) -> bool:
        """Remove an image's manifest (and any tags resolving to it).
        Chunks are shared content-addressed blobs — reclaim orphans with
        :meth:`gc` afterwards.  Returns True if the manifest existed.

        A codec-encoded delta image decodes against its parent chain, so
        deleting a parent that *other* images still reference breaks
        them; callers must only delete whole lineages they own (the
        migration rollback deletes exactly the images one failed attempt
        pushed, newest first)."""
        path = os.path.join(self.root, "manifests", image_id + ".json")
        with self._lock:
            self._manifests.pop(image_id, None)
            for tag in [t for t, i in self._tags.items() if i == image_id]:
                del self._tags[tag]
        if os.path.exists(path):
            os.remove(path)
            return True
        return False

    def gc(self) -> Tuple[int, int]:
        """Mark-and-sweep chunk collection: delete every stored chunk no
        remaining manifest references (the storage of half-pushed images
        a rollback deleted).  Returns (chunks_deleted, bytes_freed)."""
        live: set = set()
        for image_id in self.list_images():
            live.update(self.image_chunks(image_id))
        chunks_root = os.path.join(self.root, "chunks")
        deleted = freed = 0
        for sub in sorted(os.listdir(chunks_root)):
            subdir = os.path.join(chunks_root, sub)
            if not os.path.isdir(subdir):
                continue
            for key in sorted(os.listdir(subdir)):
                if key.endswith(".tmp") or key in live:
                    continue
                path = os.path.join(subdir, key)
                freed += os.path.getsize(path)
                os.remove(path)
                deleted += 1
        return deleted, freed
