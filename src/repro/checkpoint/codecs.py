"""Pluggable delta codecs for registry chunks.

A codec transforms one chunk's *wire/stored* representation; the registry
records the codec per chunk in the manifest so pulls can invert it.  Two
families:

  * ``none``     — identity (the only choice for parentless chunks).
  * ``xor_rle``  — XOR against the parent image's chunk at the same
    position, then byte-level run-length coding of the zero runs.
    Lossless.  Near-static chunks (weight layers, cold cache regions)
    collapse to a few bytes; a chunk with a small dirty stripe costs the
    stripe, not the chunk.
  * ``int8``     — blockwise int8 quantization of the float delta
    ``chunk - decode(parent chunk)``, reusing the error-feedback quantizer
    from ``optim/compression.py``.  LOSSY per round: the quantization
    error is *not* dropped but carried forward, because the next round's
    delta is computed against the receiver's lossy reconstruction (the
    decoded parent chain) — exactly the EF21-style y-tracking trick.  The
    pre-copy transfer engine finishes a lossy lineage with one lossless
    "exact flush" push, so the image actually restored at cutover — and
    therefore the replayed state — stays bit-exact.

Codec choice is per leaf: ``resolve_compression`` maps the
``MigrationPolicy.compression`` knob (a codec name, ``"auto"``, or a
``{tree name: codec}`` dict) to a concrete codec given the leaf's dtype,
whether a compatible parent chunk exists, and whether a lossy encoding is
acceptable for this push.
"""
from __future__ import annotations

import os
from typing import Dict, Optional, Union

import numpy as np

COMPRESSION_CHOICES = ("none", "xor_rle", "int8", "auto")

# Encoder backend for parent-relative codecs.  "kernel" (the default)
# lets the registry fuse fingerprinting and encoding into one device pass
# (repro.kernels.codec) when the leaf/chunk grid qualifies; "host" forces
# the two-pass host codecs below — the differential suite runs both and
# asserts byte-identical images.
_BACKEND_ENV = "REPRO_CODEC_BACKEND"


def codec_backend() -> str:
    backend = os.environ.get(_BACKEND_ENV, "kernel")
    if backend not in ("kernel", "host"):
        raise ValueError(
            f"{_BACKEND_ENV}={backend!r}; choices: ('kernel', 'host')")
    return backend

_RAW_FLAG = b"\x00"   # xor_rle fallback: raw literal chunk follows
_RLE_FLAG = b"\x01"   # xor_rle: run-length stream follows

_FLOAT_KINDS = ("f",)  # np dtype kinds the int8 codec quantizes


def _rle_encode(x: np.ndarray) -> bytes:
    """Byte-level RLE of a mostly-zero uint8 vector.

    Stream of ``(u32 zero_run, u32 lit_len, lit bytes)`` tokens; built
    from the nonzero index set with numpy, so near-static chunks encode in
    O(dirty) not O(chunk).
    """
    nz = np.flatnonzero(x)
    out = []
    if nz.size == 0:
        return b""
    # group nonzero indices into literal segments, absorbing zero gaps
    # shorter than the 8-byte token header (splitting there costs more)
    breaks = np.flatnonzero(np.diff(nz) > 16) + 1
    starts = np.concatenate(([0], breaks))
    ends = np.concatenate((breaks, [nz.size]))
    pos = 0
    for s, e in zip(starts, ends):
        lo, hi = int(nz[s]), int(nz[e - 1]) + 1
        out.append(int(lo - pos).to_bytes(4, "little"))
        out.append(int(hi - lo).to_bytes(4, "little"))
        out.append(x[lo:hi].tobytes())
        pos = hi
    return b"".join(out)


def _rle_decode(blob: bytes, n: int) -> np.ndarray:
    out = np.zeros(n, dtype=np.uint8)
    pos = off = 0
    view = memoryview(blob)
    while off < len(view):
        zrun = int.from_bytes(view[off: off + 4], "little")
        lit = int.from_bytes(view[off + 4: off + 8], "little")
        off += 8
        pos += zrun
        out[pos: pos + lit] = np.frombuffer(view[off: off + lit], np.uint8)
        pos += lit
        off += lit
    return out


class DeltaCodec:
    name: str = "?"
    lossless: bool = True

    def encode(self, raw: bytes, parent_raw: Optional[bytes],
               dtype: np.dtype) -> bytes:
        raise NotImplementedError

    def decode(self, blob: bytes, parent_raw: Optional[bytes],
               dtype: np.dtype) -> bytes:
        raise NotImplementedError


class NoneCodec(DeltaCodec):
    name = "none"

    def encode(self, raw, parent_raw, dtype):
        return raw

    def decode(self, blob, parent_raw, dtype):
        return blob


class XorRleCodec(DeltaCodec):
    name = "xor_rle"

    def encode(self, raw, parent_raw, dtype):
        assert parent_raw is not None and len(parent_raw) == len(raw)
        x = np.frombuffer(raw, np.uint8) ^ np.frombuffer(parent_raw, np.uint8)
        rle = _rle_encode(x)
        if len(rle) + 1 >= len(raw):  # incompressible: never exceed raw+1
            return _RAW_FLAG + raw
        return _RLE_FLAG + rle

    def decode(self, blob, parent_raw, dtype):
        if blob[:1] == _RAW_FLAG:
            return blob[1:]
        assert parent_raw is not None
        x = _rle_decode(blob[1:], len(parent_raw))
        return (x ^ np.frombuffer(parent_raw, np.uint8)).tobytes()


class Int8DeltaCodec(DeltaCodec):
    """Blockwise-int8 quantized float delta vs the decoded parent chunk
    (see module docstring for the error-feedback/exact-flush contract)."""

    name = "int8"
    lossless = False

    def encode(self, raw, parent_raw, dtype):
        from repro.optim.compression import _quant

        assert parent_raw is not None and len(parent_raw) == len(raw)
        cur = np.frombuffer(raw, dtype).astype(np.float32)
        par = np.frombuffer(parent_raw, dtype).astype(np.float32)
        q, scale, _, pad = _quant(cur - par)
        q, scale = np.asarray(q), np.asarray(scale)
        header = (int(pad).to_bytes(4, "little")
                  + int(q.size).to_bytes(4, "little"))
        return header + q.tobytes() + scale.tobytes()

    def decode(self, blob, parent_raw, dtype):
        from repro.optim.compression import BLOCK, _dequant

        assert parent_raw is not None
        pad = int.from_bytes(blob[:4], "little")
        nq = int.from_bytes(blob[4:8], "little")
        q = np.frombuffer(blob[8: 8 + nq], np.int8).reshape(-1, BLOCK)
        scale = np.frombuffer(blob[8 + nq:], np.float32).reshape(-1, 1)
        par = np.frombuffer(parent_raw, dtype).astype(np.float32)
        delta = np.asarray(_dequant(q, scale, (par.size,), pad))
        return (par + delta).astype(dtype).tobytes()


CODECS: Dict[str, DeltaCodec] = {
    c.name: c for c in (NoneCodec(), XorRleCodec(), Int8DeltaCodec())
}


def get_codec(name: str) -> DeltaCodec:
    codec = CODECS.get(name)
    if codec is None:
        raise ValueError(
            f"unknown codec {name!r}; concrete codecs: {tuple(CODECS)} "
            "(specs like 'auto' must go through resolve_compression first)")
    return codec


def validate_compression(spec: Union[str, Dict[str, str]]) -> None:
    specs = spec.values() if isinstance(spec, dict) else (spec,)
    for s in specs:
        if s not in COMPRESSION_CHOICES:
            raise ValueError(
                f"unknown compression codec {s!r}; "
                f"choices: {COMPRESSION_CHOICES}")


def resolve_compression(spec: Union[str, Dict[str, str]], tree_name: str,
                        dtype: np.dtype, has_parent_chunk: bool,
                        lossy_ok: bool, chunk_bytes: int = 0) -> str:
    """Pick the concrete codec for one leaf's chunks.

    Note the cluster migration path pushes a single tree named
    ``"state"``; dict specs keyed by other tree names only take effect
    for direct multi-tree ``Registry`` pushes.
    """
    if isinstance(spec, dict):
        spec = spec.get(tree_name, "none")
    # re-check the *resolved* entry: a caller that skipped
    # validate_compression (or a dict naming an unknown codec for this
    # very tree) must fail here with ValueError, not silently map to a
    # fallback codec or KeyError later at push time
    validate_compression(spec)
    if spec == "none" or not has_parent_chunk:
        return "none"
    if spec == "int8":
        # the lossy quantizer only applies to float leaves on non-final
        # pushes, and needs chunk boundaries on the dtype's element grid
        # (an unaligned chunk_bytes would split an element across chunks);
        # everything else falls back to the lossless delta codec
        dt = np.dtype(dtype)
        if (lossy_ok and dt.kind in _FLOAT_KINDS
                and chunk_bytes > 0 and chunk_bytes % dt.itemsize == 0):
            return "int8"
        return "xor_rle"
    return "xor_rle"  # "xor_rle" and "auto"


class FusedLeafEncoding:
    """One fused device pass over a leaf: chunk fingerprints + the codec
    arithmetic for *every* chunk, via the Pallas codec kernels
    (``repro.kernels.codec`` through the ``kernels/ops.py`` dispatch).

    The registry uses this in place of the fingerprint-then-host-encode
    two-pass flow when the leaf qualifies (see ``Registry._fused_leaf``):
    dirty detection and encoding share a single read of the state, which
    is the device-side analogue of the paper's cheap pre-copy rounds.
    ``fps`` is bit-identical to ``leaf_fingerprints``; ``blob(c)`` is
    byte-identical to the matching host codec's ``encode`` for chunk
    ``c`` — the differential suite (tests/test_codec_kernels.py) pins
    both claims against the host oracle.

    The variable-length RLE pass and blob assembly stay on host: they are
    O(dirty bytes) and data-dependent, the wrong shape for a vector unit.
    ``raw_seg`` serializes the leaf lazily — only incompressible chunks
    (raw fallback) ever pay for it.
    """

    def __init__(self, leaf, parent_buf: bytes, codec_name: str,
                 dtype: np.dtype, chunk_bytes: int):
        from repro.kernels import ops

        assert codec_name in ("xor_rle", "int8"), codec_name
        self.codec_name = codec_name
        self._leaf = leaf
        self._dtype = np.dtype(dtype)
        self._cb = chunk_bytes
        self._nbytes = len(parent_buf)
        self._raw: Optional[bytes] = None
        self._xor = self._q = self._scale = None
        if codec_name == "xor_rle":
            fps, xor = ops.fused_xor_fingerprint(leaf, parent_buf,
                                                 chunk_bytes)
            self._xor = np.asarray(xor)          # [C, R, 128] u32
        else:
            fps, q, scale = ops.fused_int8_fingerprint(leaf, parent_buf,
                                                       chunk_bytes)
            self._q = np.asarray(q)              # [C, NB, 256] i32
            self._scale = np.asarray(scale)      # [C, NB] f32
        self.fps = np.asarray(fps)               # [C, 4] u32

    def _seg_len(self, c: int) -> int:
        return min(self._cb, self._nbytes - c * self._cb)

    def raw_seg(self, c: int) -> bytes:
        """Raw bytes of chunk ``c`` (lazy leaf serialization, memoized)."""
        if self._raw is None:
            self._raw = np.asarray(self._leaf).tobytes()
        return self._raw[c * self._cb: c * self._cb + self._cb]

    def blob(self, c: int) -> bytes:
        """The encoded blob for chunk ``c`` — byte-identical to
        ``get_codec(self.codec_name).encode(seg, parent_seg, dtype)``."""
        seg_len = self._seg_len(c)
        if self.codec_name == "xor_rle":
            # kernel word layout zero-pads the tail chunk; the pad XORs to
            # zero (both sides padded), so trimming to seg_len restores
            # exactly the host codec's XOR vector
            x = np.frombuffer(self._xor[c].tobytes()[:seg_len], np.uint8)
            rle = _rle_encode(x)
            if len(rle) + 1 >= seg_len:
                return _RAW_FLAG + self.raw_seg(c)
            return _RLE_FLAG + rle
        from repro.optim.compression import BLOCK

        n_elems = seg_len // self._dtype.itemsize
        nblk = -(-n_elems // BLOCK)
        pad = nblk * BLOCK - n_elems
        q = self._q[c, :nblk].astype(np.int8)
        scale = self._scale[c, :nblk].reshape(-1, 1)
        header = (int(pad).to_bytes(4, "little")
                  + int(q.size).to_bytes(4, "little"))
        return header + q.tobytes() + scale.tobytes()
