"""Checkpoint-side adapter for device-side chunk fingerprinting.

The registry asks for one fingerprint per raw-byte chunk of a leaf; the
heavy lifting (bit reinterpretation + the fused weighted-reduction pass)
happens in ``repro.kernels`` — Pallas on TPU, the blockwise jnp lowering on
CPU — so a JAX-resident leaf is fingerprinted without ever serializing it
to host memory.  The adapter only normalizes leaves (python scalars,
zero-size arrays, unsupported chunk grids) and returns host numpy for the
manifest.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

import jax

from repro.kernels.fingerprint import FP_WORDS, LANES


def supports_chunk_bytes(chunk_bytes: int) -> bool:
    """The kernel's lane layout needs chunks on a 512-byte word grid."""
    return chunk_bytes >= 4 * LANES and chunk_bytes % (4 * LANES) == 0


def normalize_leaf(leaf):
    """The array the device kernels would consume for this leaf, or None
    when the leaf can't be fingerprinted/encoded on device (python
    objects, complex dtypes, odd itemsizes — the registry then falls back
    to host hashing and host codecs)."""
    if isinstance(leaf, jax.Array):
        return leaf
    leaf = np.asarray(leaf)
    if (leaf.dtype == object or leaf.dtype.kind == "c"
            or leaf.dtype.itemsize not in (1, 2, 4, 8)):
        return None
    return leaf


def leaf_fingerprints(leaf, chunk_bytes: int) -> Optional[np.ndarray]:
    """-> ``[n_chunks, FP_WORDS]`` uint32 fingerprints of the leaf's raw
    bytes on the registry's chunk grid, or None when the grid is
    unsupported (the registry then falls back to host hashing)."""
    from repro.kernels import ops

    if not supports_chunk_bytes(chunk_bytes):
        return None
    leaf = normalize_leaf(leaf)
    if leaf is None:
        return None
    if leaf.size == 0:
        return np.zeros((0, FP_WORDS), np.uint32)
    return np.asarray(ops.chunk_fingerprint(leaf, chunk_bytes))
