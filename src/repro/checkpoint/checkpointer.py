"""Async periodic checkpointing + restart-with-journal-replay.

The FT story (1000+ nodes): every worker pushes an image every
``interval_steps``; on failure the controller restores latest image and
replays the message/batch journal since — i.e. recovery *is* MS2M's replay
path, so checkpoint frequency trades registry bandwidth against replay time
via exactly the paper's Eq. 5 (see core/cutoff.py:replay_time_bound).
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional, Tuple

import jax

from repro.checkpoint.registry import PushReport, Registry


class Checkpointer:
    def __init__(self, registry: Registry, name: str,
                 interval_steps: int = 100):
        self.registry = registry
        self.name = name
        self.interval_steps = interval_steps
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix=f"ckpt-{name}")
        self._latest: Optional[Tuple[int, str]] = None
        self._lock = threading.Lock()
        self._pending: Optional[Future] = None

    def maybe_save(self, step: int, trees: Dict[str, Any],
                   meta: Optional[dict] = None) -> Optional[Future]:
        if step % self.interval_steps != 0:
            return None
        return self.save(step, trees, meta)

    def save(self, step: int, trees: Dict[str, Any],
             meta: Optional[dict] = None, block: bool = False):
        # snapshot to host memory synchronously (cheap), push async
        host_trees = jax.tree.map(
            lambda x: jax.device_get(x) if hasattr(x, "device") or hasattr(x, "devices") else x,
            trees)
        meta = dict(meta or {})
        meta["step"] = step
        meta["worker"] = self.name

        def _push() -> PushReport:
            report = self.registry.push_image(
                host_trees, meta, tag=f"{self.name}:latest")
            with self._lock:
                if self._latest is None or step >= self._latest[0]:
                    self._latest = (step, report.image_id)
            return report

        fut = self._pool.submit(_push)
        self._pending = fut
        if block:
            return fut.result()
        return fut

    def wait(self):
        if self._pending is not None:
            self._pending.result()

    def latest(self) -> Optional[Tuple[int, str]]:
        with self._lock:
            return self._latest

    def restore_latest(self) -> Optional[Tuple[int, Dict[str, Any]]]:
        latest = self.latest()
        if latest is None:
            return None
        step, image_id = latest
        trees, _ = self.registry.pull_image(image_id)
        return step, trees
