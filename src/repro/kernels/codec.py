"""Pallas TPU kernels for fused chunk fingerprint + delta encoding.

The checkpoint data path used to take *two* passes over each pre-copy
round's state: one device pass fingerprinting every chunk
(``kernels/fingerprint.py``) and, for the dirty set, a host pass feeding
the delta codecs in ``checkpoint/codecs.py``.  The kernels here fuse
dirty-detection and encoding into a single read of the state: one grid
step streams a chunk block through VMEM and emits

  * the chunk's fingerprint lanes (identical construction — and bit-exact
    results — to ``fingerprint._fp_kernel``), and
  * the codec's arithmetic core:
      - ``xor``  — the XOR of the chunk against its parent-image chunk
        (the run-length pass over that mostly-zero vector stays on host:
        it is O(dirty bytes) and variable-length, the wrong shape for a
        vector unit);
      - ``int8`` — blockwise symmetric int8 quantization of the float
        delta vs the decoded parent, exactly ``optim/compression._quant``:
        256-element blocks, ``scale = max(|delta|)/127`` clamped to 1e-12,
        round-half-even, clip to ±127.

Bit-exactness contract (the whole point of this module):

  * fingerprints equal ``ops.chunk_fingerprint`` exactly — same word
    layout, same uint32 arithmetic; trailing zero-row padding added for
    the int8 pair layout contributes ``weight * 0`` to every lane, so the
    padded and unpadded layouts agree;
  * the XOR output is exact by construction, so the host RLE pass over it
    yields bytes identical to ``XorRleCodec.encode``;
  * the quantizer emits the same ``(q, scale)`` as the host oracle: both
    are the same IEEE-754 f32 expression graph (sub, abs, max, div,
    round, clip), and max is order-insensitive, so the blockwise kernel,
    the jnp lowering and interpret mode agree bit-for-bit.  ``q`` leaves
    the kernel as int32 (TPU-friendly store) and is narrowed to int8 on
    host — values are already clipped to ±127.

Layouts mirror ``fingerprint.chunked_words``: ``[n_chunks, rows, 128]``
uint32 words on the registry's raw-byte chunk grid.  The int8 kernel
additionally needs an even row count per chunk (one 256-float quant block
spans two 128-word rows); ``pair_rows`` zero-pads one row when needed,
matching the host quantizer's zero-padding of the tail block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams
from repro.kernels.fingerprint import (
    LANES,
    _fit_rows,
    _row_weights,
    fingerprint_lanes_ref,
)

QBLOCK = 256                 # quant block length == optim.compression.BLOCK
_QROWS = QBLOCK // LANES     # word rows per quant block (= 2)


def pair_rows(words):
    """Zero-pad ``[C, R, 128]`` words to an even row count per chunk.

    Zero rows contribute ``weight * 0`` to every fingerprint lane and a
    zero delta to the tail quant block — exactly the host codec's
    zero-padding — so fingerprints and quantizer outputs are unchanged.
    """
    C, R, L = words.shape
    if R % _QROWS:
        words = jnp.pad(words, ((0, 0), (0, _QROWS - R % _QROWS), (0, 0)))
    return words


# ---------------------------------------------------------------------------
# fused fingerprint + XOR
# ---------------------------------------------------------------------------

def _xor_fp_kernel(cur_ref, par_ref, fp_ref, xor_ref, acc_ref, *,
                   block_rows: int, n_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cur = cur_ref[0]
    xor_ref[0] = cur ^ par_ref[0]
    row0 = (j * block_rows).astype(jnp.uint32)
    weighted = cur * _row_weights(row0, block_rows)
    acc_ref[0] = acc_ref[0] + jnp.sum(weighted, axis=0, dtype=jnp.uint32)

    @pl.when(j == n_blocks - 1)
    def _done():
        fp_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def xor_fp_lanes(cur_words, par_words, *, block_rows: int = 256,
                 interpret: bool = False):
    """Fused pass: ``[C, R, 128]`` u32 x2 -> (fp lanes ``[C, 128]``,
    xor words ``[C, R, 128]``)."""
    C, R, L = cur_words.shape
    assert L == LANES and par_words.shape == cur_words.shape
    block_rows = _fit_rows(R, block_rows)
    nb = R // block_rows
    spec = pl.BlockSpec((1, block_rows, LANES), lambda c, j: (c, j, 0))
    lanes, xor = pl.pallas_call(
        functools.partial(_xor_fp_kernel, block_rows=block_rows,
                          n_blocks=nb),
        grid=(C, nb),
        in_specs=[spec, spec],
        out_specs=[pl.BlockSpec((1, LANES), lambda c, j: (c, 0)), spec],
        out_shape=[jax.ShapeDtypeStruct((C, LANES), jnp.uint32),
                   jax.ShapeDtypeStruct((C, R, LANES), jnp.uint32)],
        scratch_shapes=[pltpu.VMEM((1, LANES), jnp.uint32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(cur_words, par_words)
    return lanes, xor


def xor_fp_ref(cur_words, par_words):
    """Blockwise jnp formulation (CPU lowering of the fused kernel)."""
    return fingerprint_lanes_ref(cur_words), cur_words ^ par_words


# ---------------------------------------------------------------------------
# fused fingerprint + blockwise int8 quantization
# ---------------------------------------------------------------------------

def _quant_blocks(delta_blocks):
    """``optim.compression._quant`` core on ``[NB, 256]`` f32 blocks ->
    (q int32 ``[NB, 256]``, scale f32 ``[NB]``).  The scale uses the
    same jit-stable reciprocal-multiply expression as the host quantizer
    (see ``optim.compression._INV127``) so eager host, interpret and
    compiled kernels agree bit-exactly."""
    from repro.optim.compression import _INV127

    scale = jnp.max(jnp.abs(delta_blocks), axis=1, keepdims=True) * _INV127
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(delta_blocks / scale), -127, 127)
    return q.astype(jnp.int32), scale[:, 0].astype(jnp.float32)


def _int8_fp_kernel(cur_ref, par_ref, fp_ref, q_ref, scale_ref, acc_ref, *,
                    block_rows: int, n_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cur = cur_ref[0]
    # quantize the float view; fingerprint the raw word view of the same
    # VMEM block — the fusion that saves the second pass over the state
    delta = (jax.lax.bitcast_convert_type(cur, jnp.float32)
             - jax.lax.bitcast_convert_type(par_ref[0], jnp.float32))
    q, scale = _quant_blocks(delta.reshape(block_rows // _QROWS, QBLOCK))
    q_ref[0] = q
    scale_ref[0] = scale
    row0 = (j * block_rows).astype(jnp.uint32)
    weighted = cur * _row_weights(row0, block_rows)
    acc_ref[0] = acc_ref[0] + jnp.sum(weighted, axis=0, dtype=jnp.uint32)

    @pl.when(j == n_blocks - 1)
    def _done():
        fp_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def int8_fp_lanes(cur_words, par_words, *, block_rows: int = 256,
                  interpret: bool = False):
    """Fused pass: ``[C, R, 128]`` u32 x2 (R even) -> (fp lanes
    ``[C, 128]``, q int32 ``[C, R//2, 256]``, scale f32 ``[C, R//2]``)."""
    C, R, L = cur_words.shape
    assert L == LANES and R % _QROWS == 0, cur_words.shape
    assert par_words.shape == cur_words.shape
    block_rows = _fit_rows(R, block_rows)
    if block_rows % _QROWS:  # quant blocks may not straddle grid steps
        block_rows *= _QROWS
    nb = R // block_rows
    nblk = block_rows // _QROWS
    spec = pl.BlockSpec((1, block_rows, LANES), lambda c, j: (c, j, 0))
    lanes, q, scale = pl.pallas_call(
        functools.partial(_int8_fp_kernel, block_rows=block_rows,
                          n_blocks=nb),
        grid=(C, nb),
        in_specs=[spec, spec],
        out_specs=[
            pl.BlockSpec((1, LANES), lambda c, j: (c, 0)),
            pl.BlockSpec((1, nblk, QBLOCK), lambda c, j: (c, j, 0)),
            pl.BlockSpec((1, nblk), lambda c, j: (c, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((C, R // _QROWS, QBLOCK), jnp.int32),
            jax.ShapeDtypeStruct((C, R // _QROWS), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, LANES), jnp.uint32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(cur_words, par_words)
    return lanes, q, scale


def int8_fp_ref(cur_words, par_words):
    """Blockwise jnp formulation (CPU lowering of the fused kernel)."""
    C, R, L = cur_words.shape
    assert R % _QROWS == 0, cur_words.shape
    delta = (jax.lax.bitcast_convert_type(cur_words, jnp.float32)
             - jax.lax.bitcast_convert_type(par_words, jnp.float32))
    q, scale = _quant_blocks(delta.reshape(C * R // _QROWS, QBLOCK))
    return (fingerprint_lanes_ref(cur_words),
            q.reshape(C, R // _QROWS, QBLOCK),
            scale.reshape(C, R // _QROWS))
