"""Pallas TPU kernel for the RG-LRU recurrence (recurrentgemma / griffin).

The recurrence is elementwise over the width dim (no matmul): it is purely
memory-bound, so the kernel's job is to stream x/gates through VMEM once,
keeping the hidden state resident in VMEM scratch across sequence chunks.

Grid: (batch, width_blocks, seq_chunks); seq is the innermost arbitrary dim.
Within a chunk the timestep loop is a ``fori_loop`` over VPU-width rows —
the same structure as the reference recurrentgemma Pallas kernel.

Validated in interpret mode against ``ref.naive_rglru``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams


def _rglru_kernel(
    x_ref, ga_ref, gx_ref, a_ref,  # [1, T, Wb], [1, T, Wb], [1, T, Wb], [1, Wb]
    h0_ref,  # [1, Wb] initial state (chunk 0 only)
    out_ref,  # [1, T, Wb]
    hlast_ref,  # [1, Wb]
    h_scratch,  # VMEM [1, Wb] f32
    *, c: float, chunk: int, n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scratch[...] = h0_ref[...].astype(jnp.float32)

    log_a = jax.nn.log_sigmoid(a_ref[0].astype(jnp.float32))  # [Wb]
    r = jax.nn.sigmoid(ga_ref[0].astype(jnp.float32))  # [T, Wb]
    i = jax.nn.sigmoid(gx_ref[0].astype(jnp.float32))
    log_at = c * r * log_a[None, :]
    a_t = jnp.exp(log_at)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_at), 1e-12))
    gated = beta * (i * x_ref[0].astype(jnp.float32))

    def step(t, h):
        h = a_t[t] * h + gated[t]
        out_ref[0, t, :] = h.astype(out_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scratch[0, :])
    h_scratch[0, :] = h

    @pl.when(ic == n_chunks - 1)
    def _finish():
        hlast_ref[...] = h_scratch[...]


@functools.partial(
    jax.jit, static_argnames=("c", "block_w", "chunk", "interpret")
)
def rglru(x, a_param, gate_a, gate_x, h0=None, *, c: float = 8.0,
          block_w: int = 512, chunk: int = 256, interpret: bool = False):
    """x/gates [B,S,W]; a_param [W]; h0 [B,W] -> (h_seq [B,S,W], h_last [B,W])."""
    B, S, W = x.shape
    block_w = min(block_w, W)
    chunk = min(chunk, S)
    assert W % block_w == 0 and S % chunk == 0, (W, block_w, S, chunk)
    nw, nc = W // block_w, S // chunk
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    a2d = jnp.broadcast_to(a_param[None, :], (B, W))

    out, hlast = pl.pallas_call(
        functools.partial(_rglru_kernel, c=c, chunk=chunk, n_chunks=nc),
        grid=(B, nw, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda b, w, s: (b, s, w)),
            pl.BlockSpec((1, chunk, block_w), lambda b, w, s: (b, s, w)),
            pl.BlockSpec((1, chunk, block_w), lambda b, w, s: (b, s, w)),
            pl.BlockSpec((1, block_w), lambda b, w, s: (b, w)),
            pl.BlockSpec((1, block_w), lambda b, w, s: (b, w)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda b, w, s: (b, s, w)),
            pl.BlockSpec((1, block_w), lambda b, w, s: (b, w)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), x.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, gate_a, gate_x, a2d, h0)
    return out, hlast
