"""Pallas TPU flash attention (prefill/train) with causal + local-window
masking and GQA, tiled for VMEM.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks); the kv-block axis is the
innermost ("arbitrary") dimension, accumulating the online softmax in VMEM
scratch (acc/m/l).  Block shapes are MXU-aligned (multiples of 128 on the
contracting/lane dims; head_dim in {64,128,256} for all ten archs).

Causal/local skipping: kv blocks strictly above the causal diagonal (or
outside the window band) contribute nothing; their compute is skipped with
``@pl.when``, so the kernel does ~S*W work for local attention and ~S^2/2
for causal — the quantity the roofline compute term credits.

Validated in interpret mode against ``ref.naive_attention``
(tests/test_kernels.py sweeps shapes x dtypes x window settings).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

from repro.kernels import ref as _ref

NEG_INF = _ref.NEG_INF


def _attn_kernel(
    q_ref, k_ref, v_ref,  # [1, 1, bq, D], [1, 1, bk, D] x2
    o_ref,  # [1, 1, bq, D]
    acc_ref, m_ref, l_ref,  # VMEM scratch: [bq, D] f32, [bq, 128], [bq, 128]
    *, causal: bool, window: int, block_q: int, block_k: int, sm_scale: float,
    kv_steps: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # Static-shape mask bounds: a kv block participates unless fully masked.
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]  # [bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=-1)
        m_ref[:, 0] = m_new
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    # Skip kv blocks that are fully masked (beyond causal diagonal or
    # outside the local window band).
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + block_q - 1
    if window > 0:
        run &= k_start + block_k - 1 > q_start - window

    @pl.when(run)
    def _():
        _compute()

    @pl.when(ik == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q, k, v, *, causal: bool = True, window: int = 0,
    block_q: int = 512, block_k: int = 512, interpret: bool = False,
):
    """q [B,Sq,H,D]; k/v [B,Sk,Hkv,D] -> [B,Sq,H,D].  GQA via index_map."""
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    sm_scale = float(1.0 / (D ** 0.5))

    # layout: heads as a grid axis; blocks [1,1,bq,D] so the lane dim is D.
    qt = q.transpose(0, 2, 1, 3)  # [B,H,Sq,D]
    kt = k.transpose(0, 2, 1, 3)  # [B,Hkv,Sk,D]
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _attn_kernel,
        causal=causal, window=window, block_q=block_q, block_k=block_k,
        sm_scale=sm_scale, kv_steps=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
