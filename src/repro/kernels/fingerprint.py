"""Pallas TPU kernel for device-side chunk fingerprinting.

The checkpoint registry content-addresses chunks by sha256 of their bytes,
which forces every pre-copy round to serialize each leaf to host memory and
re-hash it — even when almost nothing changed.  This kernel reduces each
registry chunk to a 128-bit fingerprint *on device* in one fused streaming
pass, so dirty detection between consecutive checkpoints becomes a
fingerprint comparison: only chunks whose fingerprint changed are
serialized, encoded and hashed on host.

Construction (all arithmetic uint32, wrap-around mod 2^32, so the Pallas
kernel, the blockwise jnp lowering and interpret mode agree bit-exactly):

  * a leaf's raw bytes are reinterpreted as uint32 words and laid out as
    ``[n_chunks, rows, 128]`` (128 = TPU lane width; rows stream through
    VMEM in blocks);
  * stage 1 (the kernel): per chunk, each lane accumulates a weighted sum
    over rows, ``lane[j] = sum_r mix32(r) * w[r, j]`` — weights depend on
    the intra-chunk row index only, so equal content yields equal
    fingerprints regardless of chunk position (matching content
    addressing), while any positional move *within* a chunk changes it;
  * stage 2 (negligible, shared jnp): the 128 lanes collapse to
    ``FP_WORDS`` words under four independently seeded weightings.

A fingerprint collision would silently drop a dirty chunk, so the collapse
keeps 4 x 32 bits; every migration path additionally verifies the restored
state against a reference fold.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

LANES = 128          # TPU lane width; stage-1 fingerprint width
FP_WORDS = 4         # final fingerprint words per chunk (4 x u32 = 128 bit)
_GOLD = 0x9E3779B1   # 2^32 / golden ratio (Weyl constant)
_COLLAPSE_SEEDS = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F)


def _mix32(x):
    """murmur3-style uint32 finalizer (elementwise, VPU-friendly)."""
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def _row_weights(row0, block_rows: int):
    """Per-row odd weights for absolute rows [row0, row0 + block_rows)."""
    r = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, LANES), 0)
    r = r + jnp.uint32(1) + row0
    return _mix32(r * jnp.uint32(_GOLD)) | jnp.uint32(1)


def _fp_kernel(w_ref, out_ref, acc_ref, *, block_rows: int, n_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    row0 = (j * block_rows).astype(jnp.uint32)
    weighted = w_ref[0] * _row_weights(row0, block_rows)
    acc_ref[0] = acc_ref[0] + jnp.sum(weighted, axis=0, dtype=jnp.uint32)

    @pl.when(j == n_blocks - 1)
    def _done():
        out_ref[...] = acc_ref[...]


def _fit_rows(rows: int, want: int) -> int:
    b = max(min(want, rows), 1)
    while rows % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fingerprint_lanes(words, *, block_rows: int = 256,
                      interpret: bool = False):
    """Stage 1 on Pallas: ``[C, R, 128]`` uint32 -> ``[C, 128]`` uint32."""
    C, R, L = words.shape
    assert L == LANES, words.shape
    block_rows = _fit_rows(R, block_rows)
    nb = R // block_rows
    return pl.pallas_call(
        functools.partial(_fp_kernel, block_rows=block_rows, n_blocks=nb),
        grid=(C, nb),
        in_specs=[pl.BlockSpec((1, block_rows, LANES),
                               lambda c, j: (c, j, 0))],
        out_specs=pl.BlockSpec((1, LANES), lambda c, j: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((C, LANES), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((1, LANES), jnp.uint32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(words)


def fingerprint_lanes_ref(words):
    """Stage 1, blockwise jnp formulation (CPU lowering of the kernel)."""
    C, R, L = words.shape
    assert L == LANES, words.shape
    r = jnp.arange(R, dtype=jnp.uint32) + jnp.uint32(1)
    w = _mix32(r * jnp.uint32(_GOLD)) | jnp.uint32(1)
    return jnp.sum(words * w[None, :, None], axis=1, dtype=jnp.uint32)


def collapse_lanes(lanes):
    """Stage 2 (shared): ``[C, 128]`` uint32 -> ``[C, FP_WORDS]`` uint32."""
    j = jnp.arange(LANES, dtype=jnp.uint32) + jnp.uint32(1)
    w = jnp.stack([_mix32(j * jnp.uint32(s)) | jnp.uint32(1)
                   for s in _COLLAPSE_SEEDS])          # [FP_WORDS, 128]
    return jnp.sum(lanes[:, None, :] * w[None, :, :], axis=-1,
                   dtype=jnp.uint32)


# ---------------------------------------------------------------------------
# Byte-layout helpers: raw array bits -> the kernel's [C, R, 128] layout
# ---------------------------------------------------------------------------

def as_u32_words(x):
    """Bit-reinterpret an array as a flat uint32 word vector (device-side
    for jax arrays; zero-pads the tail to a 4-byte boundary)."""
    import numpy as np

    if not isinstance(x, jax.Array):
        # numpy leaves go through a host byte view: jnp.asarray would
        # silently downcast 64-bit dtypes (x64 disabled) and desync the
        # fingerprint chunk grid from the registry's raw-byte grid
        b = np.ascontiguousarray(np.asarray(x)).reshape(-1).view(np.uint8)
        pad = (-b.size) % 4
        if pad:
            b = np.concatenate([b, np.zeros(pad, np.uint8)])
        return jnp.asarray(b.view(np.uint32))
    x = x.reshape(-1)
    if x.dtype == jnp.bool_:
        x = x.astype(jnp.uint8)
    isz = x.dtype.itemsize
    if isz == 4:
        return jax.lax.bitcast_convert_type(x, jnp.uint32)
    if isz == 8:
        return jax.lax.bitcast_convert_type(x, jnp.uint32).reshape(-1)
    group = 4 // isz  # 2-byte or 1-byte elements: group into one word
    pad = (-x.size) % group
    if pad:
        x = jnp.pad(x, (0, pad))
    narrow = jnp.uint16 if isz == 2 else jnp.uint8
    x = jax.lax.bitcast_convert_type(x, narrow).reshape(-1, group)
    return jax.lax.bitcast_convert_type(x, jnp.uint32)


def chunked_words(x, chunk_bytes: int):
    """-> uint32 words of ``x`` arranged ``[n_chunks, rows, 128]``, chunk
    boundaries aligned with the registry's raw-byte chunk grid (requires
    ``chunk_bytes`` to be a positive multiple of 512)."""
    assert chunk_bytes >= 4 * LANES and chunk_bytes % (4 * LANES) == 0, \
        chunk_bytes
    words = as_u32_words(x)
    wpc = chunk_bytes // 4
    n = words.size
    if n <= wpc:
        # single-chunk leaf: pad only to the lane grid, not the full chunk
        wpc = max(LANES, ((n + LANES - 1) // LANES) * LANES)
    n_chunks = max(1, -(-n // wpc))
    pad = n_chunks * wpc - n
    if pad:
        words = jnp.pad(words, (0, pad))
    return words.reshape(n_chunks, wpc // LANES, LANES)
