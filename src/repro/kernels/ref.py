"""Pure-jnp oracles for every Pallas kernel, plus blockwise ("flash") jnp
implementations used as the lowering path on non-TPU backends.

Three tiers per op:
  * ``naive_*``      — simplest possible semantics; ground truth in tests.
  * ``blockwise_*``  — lax.scan online-softmax/linear-scan formulations whose
                       HLO working set matches the TPU kernel's VMEM tiling
                       (so the CPU dry-run's memory roofline term is honest).
  * the Pallas kernel (sibling modules) — the TPU target, validated in
                       interpret mode against ``naive_*``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# attention oracles
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, *, causal=True, window=0, q_pos=None, k_pos=None):
    """Full-materialization GQA attention.  q [B,Sq,H,D]; k/v [B,Sk,Hkv,D].

    ``window`` > 0 limits keys to (q_pos - window, q_pos].  ``q_pos``/``k_pos``
    default to arange (prefill); decode passes explicit positions.
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    if q_pos is None:
        q_pos = jnp.arange(Sq)
    if k_pos is None:
        k_pos = jnp.arange(Sk)
    qg = q.reshape(B, Sq, Hkv, g, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window and window > 0:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def blockwise_attention(
    q, k, v, *, causal=True, window=0, block_k: int = 1024,
    q_pos=None, k_pos=None,
):
    """Online-softmax attention, scanning KV in blocks (flash formulation).

    Never materializes [Sq, Sk]; the per-step working set is [.., Sq, block_k],
    mirroring the Pallas kernel's VMEM tile.  Used for train/prefill lowering
    on CPU and as a second oracle for the kernel.
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    if q_pos is None:
        q_pos = jnp.arange(Sq)
    if k_pos is None:
        k_pos = jnp.arange(Sk)
    block_k = min(block_k, Sk)
    pad = (-Sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-10**9)
    nb = (Sk + pad) // block_k
    qg = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, g, D)

    def body(carry, start):
        acc, m, l = carry
        kb = jax.lax.dynamic_slice_in_dim(k, start, block_k, axis=1).astype(jnp.float32)
        vb = jax.lax.dynamic_slice_in_dim(v, start, block_k, axis=1).astype(jnp.float32)
        kp = jax.lax.dynamic_slice_in_dim(k_pos, start, block_k, axis=0)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb)  # [B,Hkv,g,Sq,bk]
        mask = jnp.ones((Sq, block_k), bool)
        if causal:
            mask &= q_pos[:, None] >= kp[None, :]
        if window and window > 0:
            mask &= q_pos[:, None] - kp[None, :] < window
        mask &= kp[None, :] > -(10**8)  # padding
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vb)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Hkv, g, Sq, D), jnp.float32)
    m0 = jnp.full((B, Hkv, g, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), jnp.arange(nb) * block_k
    )
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D).astype(q.dtype)


def banded_local_attention(q, k, v, *, window: int, q_pos=None):
    """Local (sliding-window) attention with FLOPs linear in S.

    Queries are chunked by ``window``; chunk i attends to key chunks {i-1, i}
    with exact masking, so compute is B*H*S*2W*D (vs S^2 for full attention).
    Requires Sq == Sk == S and S % window == 0 (callers pad).
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    W = window
    assert S % W == 0, (S, W)
    nc = S // W
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    if q_pos is None:
        q_pos = jnp.arange(S)
    qc = (q.astype(jnp.float32) * scale).reshape(B, nc, W, Hkv, g, D)
    kc = k.astype(jnp.float32).reshape(B, nc, W, Hkv, D)
    vc = v.astype(jnp.float32).reshape(B, nc, W, Hkv, D)
    # previous chunk (chunk -1 is zeros, masked out by position)
    kprev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kc], axis=2)  # [B,nc,2W,Hkv,D]
    v2 = jnp.concatenate([vprev, vc], axis=2)
    qp = q_pos.reshape(nc, W)
    kp_self = q_pos.reshape(nc, W)
    kp_prev = jnp.concatenate([jnp.full((1, W), -(10**9)), kp_self[:-1]], axis=0)
    kp = jnp.concatenate([kp_prev, kp_self], axis=1)  # [nc, 2W]
    s = jnp.einsum("bcqhgd,bckhd->bchgqk", qc, k2)
    mask = (qp[:, :, None] >= kp[:, None, :]) & (qp[:, :, None] - kp[:, None, :] < W)
    s = jnp.where(mask[None, :, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bchgqk,bckhd->bcqhgd", p, v2)
    return o.reshape(B, S, H, D).astype(q.dtype)


def chunk_attention(q, k_cache, v_cache, *, q_pos, k_pos, window: int = 0):
    """Multi-token append attention over a populated KV cache.

    q [B,k,H,D] (a chunk of k new tokens already written into the cache);
    caches [B,S,Hkv,D]; q_pos [B,k]; k_pos [B,S] (slot positions, -1 empty).
    Causality/window masking is positional, so ring-buffer caches work.
    The batched-replay fast path of MS2M (core/consumer.replay_chunked).
    """
    B, K, H, D = q.shape
    Hkv = k_cache.shape[2]
    g = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qg = (q.astype(jnp.float32) * scale).reshape(B, K, Hkv, g, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache.astype(jnp.float32))
    valid = (k_pos[:, None, :] >= 0) & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window and window > 0:
        valid &= q_pos[:, :, None] - k_pos[:, None, :] < window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, K, H, D).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, q_pos, k_pos):
    """Single-token attention over a (possibly seq-sharded) KV cache.

    q [B,1,H,D]; caches [B,S,Hkv,D]; q_pos [B] current position; k_pos [B,S]
    cache slot positions (-1 = empty).  Softmax reductions over the sharded S
    axis lower to flash-decode-style partial reductions + psum under SPMD.
    """
    B, _, H, D = q.shape
    Hkv = k_cache.shape[2]
    g = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    # contract in the cache's native dtype with fp32 MXU accumulation —
    # materializing an fp32 copy of the cache would triple decode HBM
    # traffic (EXPERIMENTS.md §Perf C3)
    qg = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32)
    valid = (k_pos >= 0) & (k_pos <= q_pos[:, None])  # [B,S]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# RG-LRU (griffin / recurrentgemma) oracle
# ---------------------------------------------------------------------------

def naive_rglru(x, a_param, gate_a, gate_x, h0=None, *, c: float = 8.0):
    """Real-Gated Linear Recurrent Unit (arXiv:2402.19427 eq. 1-4).

    x, gate_a, gate_x: [B,S,W];  a_param: [W] (raw; a = sigmoid(a_param)).
      r_t = sigmoid(gate_a_t);  i_t = sigmoid(gate_x_t)
      a_t = a^(c*r_t)           (log-space: exp(c*r_t*log_sigmoid(a_param)))
      h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * (i_t * x_t)
    Returns (h_seq [B,S,W], h_last [B,W]).
    """
    B, S, W = x.shape
    log_a = jax.nn.log_sigmoid(a_param.astype(jnp.float32))  # [W]
    r = jax.nn.sigmoid(gate_a.astype(jnp.float32))
    i = jax.nn.sigmoid(gate_x.astype(jnp.float32))
    log_at = c * r * log_a[None, None, :]  # [B,S,W]
    a_t = jnp.exp(log_at)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_at), 1e-12))
    gated = beta * (i * x.astype(jnp.float32))
    h = jnp.zeros((B, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        h = a_t[:, t] * h + gated[:, t]
        return h, h

    h_last, hs = jax.lax.scan(step, h, jnp.arange(S))
    return hs.transpose(1, 0, 2).astype(x.dtype), h_last


def blockwise_rglru(x, a_param, gate_a, gate_x, h0=None, *, c: float = 8.0,
                    block: int = 256):
    """Chunked associative formulation: within a chunk, prefix products of a_t
    give h_t = A_t*h_in + sum_j (A_t/A_j)*g_j computed as one einsum; chunks
    chain through a lax.scan.  Matches the Pallas kernel's grid structure."""
    B, S, W = x.shape
    assert S % block == 0 or S < block
    blk = min(block, S)
    pad = (-S) % blk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        gate_a = jnp.pad(gate_a, ((0, 0), (0, pad), (0, 0)))
        gate_x = jnp.pad(gate_x, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nb = Sp // blk
    log_a = jax.nn.log_sigmoid(a_param.astype(jnp.float32))
    r = jax.nn.sigmoid(gate_a.astype(jnp.float32))
    i = jax.nn.sigmoid(gate_x.astype(jnp.float32))
    log_at = c * r * log_a[None, None, :]
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_at), 1e-12)) * (
        i * x.astype(jnp.float32)
    )
    log_at = log_at.reshape(B, nb, blk, W)
    gated = gated.reshape(B, nb, blk, W)
    h = jnp.zeros((B, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def chunk(h, inputs):
        la, g = inputs  # [B,blk,W]
        cum = jnp.cumsum(la, axis=1)  # log prefix products A_t
        # h_t = exp(cum_t) * h + sum_{j<=t} exp(cum_t - cum_j) * g_j
        # stable: factor exp(cum_t) * sum_j exp(-cum_j) g_j can overflow;
        # use pairwise differences via triangular mask in log space.
        t_idx = jnp.arange(blk)
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # [B,t,j,W]
        tri = (t_idx[:, None] >= t_idx[None, :])[None, :, :, None]
        # a_t <= 1 so diff = cum_t - cum_j <= 0 for t >= j: exp is safe.
        w = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
        hs = jnp.exp(cum) * h[:, None, :] + jnp.einsum("btjw,bjw->btw", w, g)
        return hs[:, -1, :], hs

    h_last, hs = jax.lax.scan(chunk, h, (log_at.transpose(1, 0, 2, 3), gated.transpose(1, 0, 2, 3)))
    hs = hs.transpose(1, 0, 2, 3).reshape(B, Sp, W)[:, :S]
    return hs.astype(x.dtype), h_last


def rglru_decode_step(h, x, a_param, gate_a, gate_x, *, c: float = 8.0):
    """One-token RG-LRU update.  h [B,W]; x/gates [B,W]."""
    log_a = jax.nn.log_sigmoid(a_param.astype(jnp.float32))
    r = jax.nn.sigmoid(gate_a.astype(jnp.float32))
    i = jax.nn.sigmoid(gate_x.astype(jnp.float32))
    log_at = c * r * log_a[None, :]
    a_t = jnp.exp(log_at)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_at), 1e-12))
    h_new = a_t * h.astype(jnp.float32) + beta * (i * x.astype(jnp.float32))
    return h_new


# ---------------------------------------------------------------------------
# mLSTM (xLSTM) oracle
# ---------------------------------------------------------------------------

def naive_mlstm(q, k, v, i_gate, f_gate, state=None):
    """Matrix-LSTM (arXiv:2405.04517 §2.3), stabilized recurrent form.

    q,k,v: [B,S,H,D]; i_gate,f_gate: [B,S,H] (pre-activations).
      C_t = f_t C_{t-1} + i_t v_t k_t^T ;  n_t = f_t n_{t-1} + i_t k_t
      h_t = C_t q_t / max(|n_t^T q_t|, 1)
    with the m_t log-stabilizer from the paper.  Returns (h [B,S,H,D], state).
    """
    B, S, H, D = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    q = q.astype(jnp.float32) * scale
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))  # [B,S,H]
    logi = i_gate.astype(jnp.float32)
    if state is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), NEG_INF, jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, t):
        C, n, m = carry
        m_new = jnp.maximum(logf[:, t] + m, logi[:, t])
        fe = jnp.exp(logf[:, t] + m - m_new)  # [B,H]
        ie = jnp.exp(logi[:, t] - m_new)
        C = fe[..., None, None] * C + ie[..., None, None] * (
            v[:, t][..., :, None] * k[:, t][..., None, :]
        )  # C[b,h,dv,dk]
        n = fe[..., None] * n + ie[..., None] * k[:, t]
        num = jnp.einsum("bhvk,bhk->bhv", C, q[:, t])
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, q[:, t]))
        den = jnp.maximum(den, jnp.exp(-m_new))  # paper's stabilized max(|n q|, exp(-m))
        h = num / den[..., None]
        return (C, n, m_new), h

    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), jnp.arange(S))
    return hs.transpose(1, 0, 2, 3).astype(q.dtype), (C, n, m)


def mlstm_decode_step(state, q, k, v, i_gate, f_gate):
    """One-token mLSTM update. q/k/v [B,H,D]; gates [B,H]."""
    C, n, m = state
    D = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    q = q.astype(jnp.float32) * scale
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    logi = i_gate.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, logi)
    fe = jnp.exp(logf + m - m_new)
    ie = jnp.exp(logi - m_new)
    C = fe[..., None, None] * C + ie[..., None, None] * (v[..., :, None] * k[..., None, :])
    n = fe[..., None] * n + ie[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), jnp.exp(-m_new))
    return (C, n, m_new), (num / den[..., None])


def naive_slstm(x_i, x_f, x_z, x_o, r_i, r_f, r_z, r_o, state=None):
    """Scalar-LSTM with exponential gating + block-diagonal (per-head)
    recurrent mixing, as in arXiv:2405.04517 §2.2.

    x_* : [B,S,W] input pre-activations; r_* : [H, hb, hb] per-head
    recurrent weights applied to h_{t-1} (W = H*hb).  Returns (h_seq,
    state).  sLSTM is inherently sequential — no parallel form; per-head
    independence is what the Pallas kernel parallelizes over.
    """
    B, S, W = x_i.shape
    H, hb = r_i.shape[0], r_i.shape[1]
    assert H * hb == W, (H, hb, W)

    def rec(h, r):  # [B,W] x [H,hb,hb] -> [B,W]
        return jnp.einsum("bhi,hij->bhj", h.reshape(B, H, hb),
                          r.astype(jnp.float32)).reshape(B, W)

    if state is None:
        c0 = jnp.zeros((B, W), jnp.float32)
        n0 = jnp.ones((B, W), jnp.float32)
        h0 = jnp.zeros((B, W), jnp.float32)
        m0 = jnp.zeros((B, W), jnp.float32)
    else:
        c0, n0, h0, m0 = state

    def step(carry, t):
        c, n, h, m = carry
        zi = x_i[:, t].astype(jnp.float32) + rec(h, r_i)
        zf = x_f[:, t].astype(jnp.float32) + rec(h, r_f)
        zz = x_z[:, t].astype(jnp.float32) + rec(h, r_z)
        zo = x_o[:, t].astype(jnp.float32) + rec(h, r_o)
        m_new = jnp.maximum(zf + m, zi)
        ie = jnp.exp(zi - m_new)
        fe = jnp.exp(zf + m - m_new)
        c = fe * c + ie * jnp.tanh(zz)
        n = fe * n + ie
        h = jax.nn.sigmoid(zo) * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    (c, n, h, m), hs = jax.lax.scan(step, (c0, n0, h0, m0), jnp.arange(S))
    return hs.transpose(1, 0, 2).astype(x_i.dtype), (c, n, h, m)
