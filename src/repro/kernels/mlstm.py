"""Pallas TPU kernel for the mLSTM: chunkwise-parallel form (TFLA-style).

The recurrent form (ref.naive_mlstm) is a strict scan — VPU-bound, O(D^2)
elementwise work per step.  The chunkwise form turns a T-step chunk into
MXU matmuls:

  intra-chunk:  S[t,j] = (q_t.k_j/sqrt(D)) * exp(b_t - b_j + logi_j - m_t)
                for j <= t   (one [T,T] masked matmul + one [T,T]x[T,D])
  inter-chunk:  exp(b_t + m_in - m_t) * (q_t @ C_in)   ([T,D]x[D,D])
  state update: C_out = exp(F + m_in - m_out) C_in
                + sum_j exp(F - b_j + logi_j - m_out) v_j k_j^T ([D,T]x[T,D])

with b = inclusive cumsum(logf), F = b[-1]; the running stabilizer
m_t = max(b_t + m_in, max_{j<=t}(b_t - b_j + logi_j)) is *identical* to the
sequential form's, so the kernel matches ref.naive_mlstm to float tolerance.

Grid: (batch, heads, chunks); chunks is the arbitrary dim carrying
(C [D,D], n [D], m [1]) in VMEM scratch.

Validated in interpret mode against ``ref.naive_mlstm``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

from repro.kernels.ref import NEG_INF


def _mlstm_kernel(
    q_ref, k_ref, v_ref,  # [1, 1, T, D]
    i_ref, f_ref,  # [1, 1, T, 128] (gate pre-activations, lane-padded)
    h_ref,  # out [1, 1, T, D]
    c_ref, n_ref, m_ref,  # VMEM scratch: [D, D] f32, [1, D] f32, [1, 128] f32
    *, chunk: int, n_chunks: int, sm_scale: float,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)

    T = chunk
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # [T, D]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    logi = i_ref[0, 0, :, 0].astype(jnp.float32)  # [T]
    logf = jax.nn.log_sigmoid(f_ref[0, 0, :, 0].astype(jnp.float32))

    b = jnp.cumsum(logf)  # inclusive [T]
    F = b[T - 1]
    m_in = m_ref[0, 0]

    # stabilizer: m_t = max(b_t + m_in, max_{j<=t}(b_t - b_j + logi_j))
    tri = (jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (T, T), 1))
    intra_log = b[:, None] - b[None, :] + logi[None, :]  # [T,T] (t,j)
    intra_log = jnp.where(tri, intra_log, NEG_INF)
    m_t = jnp.maximum(b + m_in, jnp.max(intra_log, axis=1))  # [T]

    # intra attention matrix
    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [T,T]
    S = qk * jnp.exp(intra_log - m_t[:, None])
    S = jnp.where(tri, S, 0.0)

    inter_scale = jnp.exp(b + m_in - m_t)  # [T]
    # C is [Dv, Dk]; q contracts with the k-axis: qc[t, dv] = sum_dk q C^T
    qc = jax.lax.dot_general(q, c_ref[...], (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [T,Dv]
    num = jax.lax.dot_general(S, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32) \
        + inter_scale[:, None] * qc
    qn = jax.lax.dot_general(q, n_ref[0][:, None], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)[:, 0]  # [T]
    den = jnp.sum(S, axis=1) + inter_scale * qn
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
    h_ref[0, 0] = (num / den[:, None]).astype(h_ref.dtype)

    # state update
    m_out = jnp.maximum(F + m_in, jnp.max(F - b + logi))
    w = jnp.exp(F - b + logi - m_out)  # [T]
    kv = jax.lax.dot_general(v * w[:, None], k, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Dv,Dk]
    c_ref[...] = jnp.exp(F + m_in - m_out) * c_ref[...] + kv
    n_ref[0] = jnp.exp(F + m_in - m_out) * n_ref[0] + jnp.sum(
        w[:, None] * k, axis=0)
    m_ref[0, 0] = m_out


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm(q, k, v, i_gate, f_gate, *, chunk: int = 128,
          interpret: bool = False):
    """q/k/v [B,S,H,D]; i_gate/f_gate [B,S,H] -> h [B,S,H,D].

    C[b,h] is [Dv,Dk]: rows index v-dims, cols index k-dims, matching
    ref.naive_mlstm's C[b,h,dv,dk].
    """
    B, S, H, D = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    sm_scale = float(1.0 / (D ** 0.5))
    qt = q.transpose(0, 2, 1, 3)  # [B,H,S,D]
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    # gates [B,S,H] -> [B,H,S,128] (lane-pad so the trailing dim is tiled)
    ig = jnp.broadcast_to(i_gate.transpose(0, 2, 1)[..., None],
                          (B, H, S, 128))
    fg = jnp.broadcast_to(f_gate.transpose(0, 2, 1)[..., None],
                          (B, H, S, 128))

    out = pl.pallas_call(
        functools.partial(_mlstm_kernel, chunk=chunk, n_chunks=nc,
                          sm_scale=sm_scale),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, 128), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, 128), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((D, D), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
            pltpu.VMEM((1, 128), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qt, kt, vt, ig, fg)
    return out.transpose(0, 2, 1, 3)
