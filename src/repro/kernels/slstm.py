"""Pallas TPU kernel for the sLSTM recurrence (xLSTM §2.2).

sLSTM is a true recurrence (h_{t-1} feeds the gates), so time is
sequential; the exploitable parallelism is the *block-diagonal per-head*
structure: head h's state never mixes with head h'.  Grid:
(batch, heads, seq_chunks) — heads are an embarrassingly parallel grid dim,
seq chunks are the arbitrary dim carrying (c, n, h, m) in VMEM scratch;
each timestep does a [1,hb]x[hb,hb] MXU matvec per gate.

Validated in interpret mode against ``ref.naive_slstm``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams


def _slstm_kernel(
    xi_ref, xf_ref, xz_ref, xo_ref,  # [1, T, 1, hb]
    ri_ref, rf_ref, rz_ref, ro_ref,  # [1, hb, hb]
    h_out_ref,  # [1, T, 1, hb]
    c_ref, n_ref, h_ref, m_ref,  # VMEM scratch [1, hb] f32
    *, chunk: int, n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.ones_like(n_ref)
        h_ref[...] = jnp.zeros_like(h_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    ri = ri_ref[0].astype(jnp.float32)  # [hb, hb]
    rf = rf_ref[0].astype(jnp.float32)
    rz = rz_ref[0].astype(jnp.float32)
    ro = ro_ref[0].astype(jnp.float32)

    def step(t, carry):
        c, n, h, m = carry

        def z(x_ref, r):
            return (x_ref[0, t, 0].astype(jnp.float32)
                    + jnp.dot(h[0], r, preferred_element_type=jnp.float32))

        zi = z(xi_ref, ri)[None, :]
        zf = z(xf_ref, rf)[None, :]
        zz = z(xz_ref, rz)[None, :]
        zo = z(xo_ref, ro)[None, :]
        m_new = jnp.maximum(zf + m, zi)
        ie = jnp.exp(zi - m_new)
        fe = jnp.exp(zf + m - m_new)
        c = fe * c + ie * jnp.tanh(zz)
        n = fe * n + ie
        h = jax.nn.sigmoid(zo) * c / jnp.maximum(n, 1e-6)
        h_out_ref[0, t, 0, :] = h[0].astype(h_out_ref.dtype)
        return (c, n, h, m_new)

    c, n, h, m = jax.lax.fori_loop(
        0, chunk, step, (c_ref[...], n_ref[...], h_ref[...], m_ref[...]))
    c_ref[...], n_ref[...], h_ref[...], m_ref[...] = c, n, h, m


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def slstm(x_i, x_f, x_z, x_o, r_i, r_f, r_z, r_o, *, chunk: int = 128,
          interpret: bool = False):
    """x_* [B,S,W]; r_* [H,hb,hb] -> h_seq [B,S,W] (fresh state)."""
    B, S, W = x_i.shape
    H, hb = r_i.shape[0], r_i.shape[1]
    assert H * hb == W
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    xs = [x.reshape(B, S, H, hb) for x in (x_i, x_f, x_z, x_o)]

    out = pl.pallas_call(
        functools.partial(_slstm_kernel, chunk=chunk, n_chunks=nc),
        grid=(B, H, nc),
        in_specs=[
            *[pl.BlockSpec((1, chunk, 1, hb), lambda b, h, c: (b, c, h, 0))
              for _ in range(4)],
            *[pl.BlockSpec((1, hb, hb), lambda b, h, c: (h, 0, 0))
              for _ in range(4)],
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, hb), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hb), x_i.dtype),
        scratch_shapes=[pltpu.VMEM((1, hb), jnp.float32) for _ in range(4)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*xs, r_i, r_f, r_z, r_o)
    return out.reshape(B, S, W)
