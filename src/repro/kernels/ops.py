"""Backend-dispatching jit'd wrappers around the Pallas kernels.

Models call these entry points only.  On TPU the Pallas kernels run; on CPU
(this container, incl. the 512-virtual-device dry-run) the blockwise jnp
formulations lower instead — chosen so the dry-run HLO's FLOP/byte profile
mirrors the kernel's tiling rather than a naive O(S^2)-materializing graph.

Set ``REPRO_FORCE_PALLAS_INTERPRET=1`` to route through the Pallas kernels in
interpret mode (slow; used by the kernel-equivalence tests).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import rglru as _rg


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_forced() -> bool:
    return os.environ.get("REPRO_FORCE_PALLAS_INTERPRET", "0") == "1"


def _fit_block(size: int, want: int) -> int:
    b = max(min(want, size), 1)
    while size % b:
        b //= 2
    return b


def attention(q, k, v, *, causal=True, window=0, block_k=1024):
    """Train/prefill attention.  q [B,S,H,D]; k/v [B,S,Hkv,D]."""
    if _on_tpu():
        return _fa.flash_attention(
            q, k, v, causal=causal, window=window,
            block_q=_fit_block(q.shape[1], 512),
            block_k=_fit_block(k.shape[1], 512))
    if _interpret_forced():
        Sq, Sk = q.shape[1], k.shape[1]
        bq = max(min(512, Sq), 1)
        bk = max(min(512, Sk), 1)
        while Sq % bq:
            bq //= 2
        while Sk % bk:
            bk //= 2
        return _fa.flash_attention(
            q, k, v, causal=causal, window=window,
            block_q=bq, block_k=bk, interpret=True,
        )
    if window and window > 0 and q.shape[1] == k.shape[1] and q.shape[1] % window == 0:
        return ref.banded_local_attention(q, k, v, window=window)
    return ref.blockwise_attention(q, k, v, causal=causal, window=window,
                                   block_k=block_k)


def decode_attention(q, k_cache, v_cache, q_pos, k_pos):
    """Single-token attention over KV cache. q [B,1,H,D]."""
    if _on_tpu():
        return _da.decode_attention(q, k_cache, v_cache, q_pos, k_pos)
    if _interpret_forced():
        S = k_cache.shape[1]
        bk = max(min(512, S), 1)
        while S % bk:
            bk //= 2
        return _da.decode_attention(q, k_cache, v_cache, q_pos, k_pos,
                                    block_k=bk, interpret=True)
    return ref.decode_attention(q, k_cache, v_cache, q_pos=q_pos, k_pos=k_pos)


def rglru_scan(x, a_param, gate_a, gate_x, h0=None, *, c: float = 8.0):
    """RG-LRU over a sequence. Returns (h_seq, h_last)."""
    if _on_tpu():
        W, S = x.shape[2], x.shape[1]
        bw = 512 if W % 512 == 0 else W
        ch = 256
        while S % ch:
            ch //= 2
        return _rg.rglru(x, a_param, gate_a, gate_x, h0, c=c, block_w=bw, chunk=ch)
    if _interpret_forced():
        W, S = x.shape[2], x.shape[1]
        ch = min(64, S)
        while S % ch:
            ch //= 2
        return _rg.rglru(x, a_param, gate_a, gate_x, h0, c=c, block_w=W,
                         chunk=ch, interpret=True)
    return ref.blockwise_rglru(x, a_param, gate_a, gate_x, h0, c=c)


def slstm_scan(x_i, x_f, x_z, x_o, r_i, r_f, r_z, r_o, state=None):
    """sLSTM over a sequence.  TPU (fresh state): per-head-parallel Pallas
    kernel; portable / state-threaded path: the lax.scan recurrence."""
    from repro.kernels import slstm as _sl

    if state is None and (_on_tpu() or _interpret_forced()):
        S = x_i.shape[1]
        ch = _fit_block(S, 128)
        h = _sl.slstm(x_i, x_f, x_z, x_o, r_i, r_f, r_z, r_o, chunk=ch,
                      interpret=not _on_tpu())
        return h, None
    return ref.naive_slstm(x_i, x_f, x_z, x_o, r_i, r_f, r_z, r_o, state)


def chunk_fingerprint(x, chunk_bytes: int):
    """Device-side chunk fingerprints of an array's raw bits.

    Returns ``[n_chunks, 4]`` uint32, one 128-bit fingerprint per
    ``chunk_bytes``-sized chunk of the flattened array (boundaries aligned
    with the checkpoint registry's raw-byte chunk grid).  Pre-copy dirty
    detection compares these instead of re-hashing full host buffers.
    """
    from repro.kernels import fingerprint as _fp

    words = _fp.chunked_words(x, chunk_bytes)
    if _on_tpu():
        lanes = _fp.fingerprint_lanes(words)
    elif _interpret_forced():
        lanes = _fp.fingerprint_lanes(words, interpret=True)
    else:
        lanes = _fp.fingerprint_lanes_ref(words)
    return _fp.collapse_lanes(lanes)


def _codec_words(cur, parent_u8, chunk_bytes: int, pair: bool):
    """Both sides of a fused codec pass in the kernel's word layout."""
    import numpy as np

    from repro.kernels import codec as _ck
    from repro.kernels import fingerprint as _fp

    words = _fp.chunked_words(cur, chunk_bytes)
    pwords = _fp.chunked_words(np.frombuffer(parent_u8, np.uint8),
                               chunk_bytes)
    assert pwords.shape == words.shape, (words.shape, pwords.shape)
    if pair:
        words, pwords = _ck.pair_rows(words), _ck.pair_rows(pwords)
    return words, pwords


def fused_xor_fingerprint(cur, parent_raw: bytes, chunk_bytes: int):
    """One fused pass over ``cur``: chunk fingerprints + XOR vs parent.

    Returns ``(fps [C, 4] u32, xor_words [C, R, 128] u32)``.  The
    fingerprints are bit-identical to ``chunk_fingerprint(cur, ...)``;
    the XOR words feed the host RLE pass of the ``xor_rle`` codec, whose
    output is byte-identical to the host codec's.
    """
    from repro.kernels import codec as _ck
    from repro.kernels import fingerprint as _fp

    words, pwords = _codec_words(cur, parent_raw, chunk_bytes, pair=False)
    if _on_tpu():
        lanes, xor = _ck.xor_fp_lanes(words, pwords)
    elif _interpret_forced():
        lanes, xor = _ck.xor_fp_lanes(words, pwords, interpret=True)
    else:
        lanes, xor = _ck.xor_fp_ref(words, pwords)
    return _fp.collapse_lanes(lanes), xor


def fused_int8_fingerprint(cur, parent_raw: bytes, chunk_bytes: int):
    """One fused pass over ``cur``: chunk fingerprints + blockwise int8
    quantization of the f32 delta vs the decoded parent.

    Returns ``(fps [C, 4] u32, q int32 [C, NB, 256], scale f32 [C, NB])``
    with ``NB`` quant blocks per (zero-padded) chunk; ``q``/``scale``
    match ``optim.compression._quant`` on each chunk's delta bit-exactly.
    """
    from repro.kernels import codec as _ck
    from repro.kernels import fingerprint as _fp

    words, pwords = _codec_words(cur, parent_raw, chunk_bytes, pair=True)
    if _on_tpu():
        lanes, q, scale = _ck.int8_fp_lanes(words, pwords)
    elif _interpret_forced():
        lanes, q, scale = _ck.int8_fp_lanes(words, pwords, interpret=True)
    else:
        lanes, q, scale = _ck.int8_fp_ref(words, pwords)
    return _fp.collapse_lanes(lanes), q, scale


def mlstm_scan(q, k, v, i_gate, f_gate, state=None):
    """mLSTM over a sequence.  TPU: chunkwise-parallel Pallas kernel (MXU
    matmuls); portable path: the stabilized lax.scan recurrence.

    The Pallas path currently returns outputs only (fresh-state sequences,
    as in training); callers threading serving state use the scan path.
    """
    from repro.kernels import mlstm as _ml

    if state is None and _on_tpu():
        S = q.shape[1]
        ch = 128
        while S % ch:
            ch //= 2
        h = _ml.mlstm(q, k, v, i_gate, f_gate, chunk=ch)
        # final state for cache continuation comes from the scan path only
        # when requested; training uses h alone.
        return h, None
    if state is None and _interpret_forced():
        S = q.shape[1]
        ch = min(64, S)
        while S % ch:
            ch //= 2
        h = _ml.mlstm(q, k, v, i_gate, f_gate, chunk=ch, interpret=True)
        return h, None
    return ref.naive_mlstm(q, k, v, i_gate, f_gate, state)
