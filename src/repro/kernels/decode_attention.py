"""Pallas TPU kernel for single-token (decode) attention over a KV cache.

Flash-decode structure: the KV cache's sequence axis is the innermost grid
dim; partial (max, sum, acc) statistics accumulate in VMEM scratch and are
finalized on the last block.  On a seq-sharded cache (logical axis ``kv_seq``
-> mesh ``model``) each shard runs this kernel over its local slice and the
partials combine with an LSE-weighted psum in the ops wrapper.

q [B,1,H,D] is tiny; it is broadcast to every kv block, so the kernel is
purely HBM-bandwidth-bound on the cache — its roofline is bytes(cache)/bw.

Validated in interpret mode against ``ref.decode_attention``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import (CompilerParams as _CompilerParams,
                                         MemorySpace as _MemorySpace)

from repro.kernels.ref import NEG_INF


def _decode_kernel(
    qpos_ref,  # SMEM [1] current position (per batch row)
    q_ref,  # [1, H, D] (one batch row, all heads)
    k_ref, v_ref,  # [1, bk, Hkv, D]
    kpos_ref,  # [1, bk] slot positions (-1 = empty)
    o_ref,  # [1, H, D]
    acc_ref, m_ref, l_ref,  # VMEM scratch [H, D], [H, 128], [H, 128]
    *, block_k: int, kv_steps: int, g: int, sm_scale: float,
):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * sm_scale  # [H, D]
    k = k_ref[0].astype(jnp.float32)  # [bk, Hkv, D]
    v = v_ref[0].astype(jnp.float32)
    H = q.shape[0]
    Hkv = k.shape[1]
    # GQA: repeat kv heads across the query-head group
    kh = jnp.repeat(k.transpose(1, 0, 2), g, axis=0)  # [H, bk, D]
    vh = jnp.repeat(v.transpose(1, 0, 2), g, axis=0)
    s = jax.lax.dot_general(
        q[:, None, :], kh, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )[:, 0, :]  # [H, bk]
    kpos = kpos_ref[0]  # [bk]
    valid = (kpos >= 0) & (kpos <= qpos_ref[pl.program_id(0)])
    s = jnp.where(valid[None, :], s, NEG_INF)
    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=-1)
    m_ref[:, 0] = m_new
    pv = jax.lax.dot_general(
        p[:, None, :], vh, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )[:, 0, :]  # [H, D]
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(ik == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-37)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, q_pos, k_pos, *, block_k: int = 512,
                     interpret: bool = False):
    """q [B,1,H,D]; caches [B,S,Hkv,D]; q_pos [B]; k_pos [B,S] -> [B,1,H,D]."""
    B, _, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = H // Hkv
    block_k = min(block_k, S)
    assert S % block_k == 0
    nk = S // block_k
    sm_scale = float(1.0 / (D ** 0.5))
    out = pl.pallas_call(
        functools.partial(
            _decode_kernel, block_k=block_k, kv_steps=nk, g=g, sm_scale=sm_scale
        ),
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec(memory_space=_MemorySpace.SMEM),
            pl.BlockSpec((1, H, D), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((1, block_k, Hkv, D), lambda b, ik: (b, ik, 0, 0)),
            pl.BlockSpec((1, block_k, Hkv, D), lambda b, ik: (b, ik, 0, 0)),
            pl.BlockSpec((1, block_k), lambda b, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, ik: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, D), jnp.float32),
            pltpu.VMEM((H, 128), jnp.float32),
            pltpu.VMEM((H, 128), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q_pos.astype(jnp.int32), q[:, 0], k_cache, v_cache, k_pos.astype(jnp.int32))
    return out[:, None]
