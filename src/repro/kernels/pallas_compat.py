"""Pallas TPU API compatibility: jax renamed ``TPUCompilerParams`` ->
``CompilerParams`` and ``TPUMemorySpace`` -> ``MemorySpace`` around 0.5;
kernels import the names from here so both jax generations work."""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = (getattr(pltpu, "CompilerParams", None)
                  or pltpu.TPUCompilerParams)
MemorySpace = (getattr(pltpu, "MemorySpace", None)
               or pltpu.TPUMemorySpace)
