# The paper (pure infrastructure) has no kernel-level contribution; the
# kernels here serve the *framework's* compute hot-spots: attention
# (prefill + flash-decode over migrating KV caches) and the recurrent
# mixers whose states MS2M replays.  Each has a pure-jnp oracle in ref.py
# and a dispatching wrapper in ops.py.
from repro.kernels import ops, ref  # noqa: F401
