"""Differential suite: epoch-batched (fluid) vs per-message execution.

The fluid engine (docs/scaling.md) is an optimization with a hard
contract: every observable — experiment rows, fleet reports, chaos
invariants, sanitizer verdicts, tiebreak-perturbed runs — must be
bit-identical to the legacy per-message event flow (``REPRO_SIM_FLUID=0``).
These tests run both regimes in-process (the env flag is read at ``Sim``
construction) and diff the results exactly: no tolerances.
"""
import tempfile

import numpy as np
import pytest

from repro.broker.broker import Broker
from repro.cluster.cluster import Cluster
from repro.cluster.sim import Sim
from repro.core import MigrationPolicy, run_fleet_experiment
from repro.core.workload import HashConsumer, run_migration_experiment


def _experiment_row(monkeypatch, fluid, strategy, rate, seed, **kw):
    monkeypatch.setenv("REPRO_SIM_FLUID", "1" if fluid else "0")
    with tempfile.TemporaryDirectory() as root:
        res = run_migration_experiment(strategy, rate, registry_root=root,
                                       seed=seed, **kw)
    return res.row()


# single-pod rows: cutoff-firing high rate, precopy, statefulset identity,
# and the stop-and-copy baseline — every strategy family crosses the
# fluid/exact boundary (mirror attach, pause, checkpoint) at least once
ROW_CONFIGS = [
    ("stop_and_copy", 10.0, 7, {}),
    ("ms2m_individual", 5.0, 3, {}),
    ("ms2m_cutoff", 60.0, 2, {}),
    ("ms2m_precopy", 8.0, 1, {}),
    ("ms2m_statefulset", 12.0, 5, {}),
]


@pytest.mark.parametrize("strategy,rate,seed,kw", ROW_CONFIGS,
                         ids=[c[0] for c in ROW_CONFIGS])
def test_experiment_row_bit_identical(monkeypatch, strategy, rate, seed, kw):
    fluid = _experiment_row(monkeypatch, True, strategy, rate, seed, **kw)
    exact = _experiment_row(monkeypatch, False, strategy, rate, seed, **kw)
    assert fluid == exact


def test_experiment_row_identical_under_sanitizer(monkeypatch):
    monkeypatch.setenv("REPRO_SIM_SANITIZE", "1")
    fluid = _experiment_row(monkeypatch, True, "ms2m_individual", 5.0, 3)
    exact = _experiment_row(monkeypatch, False, "ms2m_individual", 5.0, 3)
    assert fluid == exact


def test_experiment_row_identical_under_tiebreak(monkeypatch):
    """Schedule perturbation: splitmix64 tiebreaks reorder same-instant
    events; the observable row must survive in both regimes."""
    for tb_seed in ("1", "4"):
        monkeypatch.setenv("REPRO_SIM_TIEBREAK", tb_seed)
        fluid = _experiment_row(monkeypatch, True, "ms2m_individual", 5.0, 3)
        exact = _experiment_row(monkeypatch, False, "ms2m_individual", 5.0, 3)
        assert fluid == exact


def _fleet_row(monkeypatch, fluid, *, seed=0, faults=None,
               allow_failures=False, n_pods=3, strategy="ms2m_individual",
               mode="parallel"):
    monkeypatch.setenv("REPRO_SIM_FLUID", "1" if fluid else "0")
    with tempfile.TemporaryDirectory() as root:
        fleet = run_fleet_experiment(
            n_pods, strategy, 8.0, registry_root=root, mode=mode,
            max_concurrent=2, seed=seed, num_nodes=4, faults=faults,
            allow_failures=allow_failures,
            policy=MigrationPolicy(max_attempts=3, retry_backoff_s=1.0))
    return fleet


def test_fleet_report_bit_identical(monkeypatch):
    fluid = _fleet_row(monkeypatch, True)
    exact = _fleet_row(monkeypatch, False)
    assert fluid.row() == exact.row()
    assert [r.strategy for r in fluid.reports] == \
        [r.strategy for r in exact.reports]


def _chaos_pair(monkeypatch, seed):
    from repro.cluster.faults import FaultSchedule

    schedule_rows = None
    out = []
    for fluid in (True, False):
        sched = FaultSchedule.random(
            seed, n_faults=3, t_window=(11.0, 70.0), nodes=("node3",),
            queues=("orders-0", "orders-1"))
        if schedule_rows is None:
            schedule_rows = sched.rows()
        else:
            assert sched.rows() == schedule_rows  # same seed, same faults
        fleet = _fleet_row(monkeypatch, fluid, seed=seed, faults=sched,
                           allow_failures=True, n_pods=2)
        ok = all(r.state_verified for r in fleet.reports)
        for f in fleet.failures:
            ok = ok and bool(f.get("rolled_back") and f.get("source_serving")
                             and f.get("source_verified"))
        out.append((fleet.row(), ok))
    return out


@pytest.mark.parametrize("seed", range(5))
def test_chaos_differential(monkeypatch, seed):
    (row_f, ok_f), (row_e, ok_e) = _chaos_pair(monkeypatch, seed)
    assert ok_f and ok_e
    assert row_f == row_e


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(5, 21))
def test_chaos_differential_extended(monkeypatch, seed):
    (row_f, ok_f), (row_e, ok_e) = _chaos_pair(monkeypatch, seed)
    assert ok_f and ok_e
    assert row_f == row_e


# -- engine unit tests --------------------------------------------------------

def test_wait_not_empty_pools_ready_condition():
    """Satellite: a non-empty queue hands every waiter one permanently
    triggered condition instead of allocating a fresh Condition per call."""
    sim = Sim()
    broker = Broker(sim)
    q = broker.declare_queue("orders")
    q.publish({"token": 1})
    c1 = q.wait_not_empty()
    c2 = q.wait_not_empty()
    assert c1 is c2 and c1.triggered


def test_census_counters():
    sim = Sim(census=True)
    fired = []
    sim.call_after(1.0, lambda: fired.append("a"), category="message")
    sim.call_after(2.0, lambda: fired.append("b"), category="heartbeat")
    sim.call_after(3.0, lambda: fired.append("c"))
    sim.run(until=10.0)
    stats = sim.stats()
    assert fired == ["a", "b", "c"]
    assert stats["census_enabled"] and stats["events_total"] == 3
    assert stats["events"]["message"] == 1
    assert stats["events"]["heartbeat"] == 1
    assert stats["events"]["other"] == 1


def test_census_disabled_by_default():
    sim = Sim()
    sim.call_after(1.0, lambda: None)
    sim.run(until=2.0)
    stats = sim.stats()
    assert not stats["census_enabled"] and stats["events"] is None


def test_halt_source_keeps_one_inflight_arrival():
    """Legacy stop-flag semantics: arrivals <= now land, plus exactly the
    first one after now (the producer's drawn in-flight sleep), then the
    source closes."""
    sim = Sim(fluid=True)
    broker = Broker(sim)
    q = broker.declare_queue("orders")
    q.attach_source(lambda: (1.0, {"n": 1}))  # arrivals at t=1,2,3,...
    sim.run(until=3.5)
    q.halt_source()
    sim.run(until=100.0)
    q.sync(sim.now)
    # t=1,2,3 landed plus the in-flight t=4 arrival; closed after
    assert q.depth() == 4
    assert q.total_published == 4


def test_fleet_state_arrays():
    with tempfile.TemporaryDirectory() as root:
        cluster = Cluster(root, num_nodes=2)
        sim, api, broker = cluster.sim, cluster.api, cluster.broker
        pods = []
        for i in range(3):
            q = broker.declare_queue(f"q-{i}")
            q.attach_source(lambda: (0.5, {"token": 7}))

            def boot(i=i, q=q):
                pod = yield from api.create_pod(
                    f"p-{i}", f"node{i % 2}", HashConsumer(), q,
                    processing_ms=10.0)
                pod.start()
                pods.append(pod)

            sim.process(boot(), name=f"boot-{i}")
        sim.run(until=20.0)
        state = api.fleet_state()
        assert state["pods"] == sorted(p.name for p in pods)
        assert state["n_processed"].dtype == np.int64
        # fleet_state syncs: the arrays match a direct per-pod walk
        by_name = {p.name: p for p in pods}
        for j, name in enumerate(state["pods"]):
            p = by_name[name]
            assert state["n_processed"][j] == p.worker.n_processed
            assert state["queue_depth"][j] == p.queue.depth()
            assert state["last_msg_id"][j] == p.worker.last_msg_id
        assert state["n_processed"].sum() > 0


def test_fluid_flag_off_via_constructor():
    sim = Sim(fluid=False)
    assert not sim.fluid_enabled
    sim2 = Sim()
    assert sim2.fluid_enabled
