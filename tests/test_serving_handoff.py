"""Serving handoff subsystem: exactly-once completion, checkpoint/replay
bit-exactness, dual-serving cutover, and fault-tolerance properties.

Covers the three layers of the subsystem:

  * workers — ``HashServingWorker`` (pure-python lane hash) and
    ``ServingWorker`` (real KV-cache engine) checkpoint mid-generation and
    replay bit-exactly;
  * ledger — first-completion-wins dedup gives exactly-once completion
    even when both replicas finish the same request in the dual window;
  * experiment — end-to-end ``run_serving_experiment`` runs are
    state-verified, exactly-once, survive tiebreak perturbation, tear
    down cleanly under the sanitizer, and keep the exactly-once guarantee
    under injected mid-handoff faults (deterministic + randomized).
"""
import math
import tempfile

import numpy as np
import pytest

from repro.analysis.stats import latency_summary, percentile, percentiles
from repro.broker.broker import Message
from repro.core.workload import open_loop_gaps
from repro.serving.handoff import (
    CompletionLedger,
    HashServingWorker,
    run_serving_experiment,
    serving_reference_fold,
    slot_aligned_chunk_bytes,
)


class _FakeSim:
    now = 0.0


def _payload(rid, prompt, budget):
    return {"request_id": rid, "prompt": prompt, "max_new_tokens": budget}


def _publish_all(worker, payloads):
    for i, p in enumerate(payloads):
        worker.process(Message(i, p, 0.0))


def _mixed_payloads(n, seed=0):
    rng = np.random.default_rng(seed)
    return [_payload(i, [int(t) for t in rng.integers(0, 100, 3)],
                     int(rng.integers(1, 9))) for i in range(n)]


# ---------------------------------------------------------------- workers

def test_hash_worker_fold_deterministic():
    payloads = _mixed_payloads(40)
    a, b = HashServingWorker(), HashServingWorker()
    _publish_all(a, payloads)
    _publish_all(b, payloads)
    assert a.state_equal(b)


def test_hash_worker_checkpoint_replay_bit_exact():
    """Checkpoint mid-stream (with requests in flight in the slots), load
    into a fresh worker, replay the suffix: bit-identical to an
    uninterrupted fold."""
    payloads = _mixed_payloads(50, seed=3)
    ref = HashServingWorker()
    _publish_all(ref, payloads)

    src = HashServingWorker()
    _publish_all(src, payloads[:23])
    assert int(np.count_nonzero(src.slot_req >= 0)) > 0  # mid-generation
    tree = src.state_tree()

    dst = HashServingWorker()
    dst.load_state(tree)
    assert dst.state_equal(src)
    for i, p in enumerate(payloads[23:], start=23):
        dst.process(Message(i, p, 0.0))
    assert dst.state_equal(ref)


def test_hash_worker_ledger_exactly_once_on_replay():
    """Replaying the same suffix into both source and restored copy
    completes each request once; the second finish is a dedup, not a
    second delivery."""
    ledger = CompletionLedger(_FakeSim())
    payloads = _mixed_payloads(30, seed=5)
    for i in range(30):
        ledger.submit(i)
    a = HashServingWorker(ledger=ledger, name="src")
    _publish_all(a, payloads)
    a.flush()
    n_dup_before = len(ledger.duplicates)
    b = HashServingWorker(ledger=ledger, name="dst")
    _publish_all(b, payloads)  # full replay on the second replica
    b.flush()
    assert ledger.exactly_once
    assert len(ledger.delivered) == 30
    assert len(ledger.duplicates) > n_dup_before  # replays were suppressed
    for rec in ledger.delivered.values():
        assert rec["by"] == "src"  # first completion won


def test_engine_worker_mid_generation_checkpoint_replay():
    """Real KV-cache engine: checkpoint with generation in flight (slot
    arrays carry request id / position / generated tokens), restore, and
    replay to a state bit-equal to the uninterrupted run."""
    jax = pytest.importorskip("jax")
    from repro import configs
    from repro.models import transformer as T
    from repro.serving import ServingEngine
    from repro.serving.handoff import ServingWorker

    cfg = configs.get_smoke("paper_consumer")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)

    def make(name):
        eng = ServingEngine(cfg, params, num_slots=2, max_seq=64, name=name)
        return ServingWorker(eng, decode_rounds=2)

    rng = np.random.default_rng(11)
    payloads = [_payload(i, [int(t) for t in rng.integers(1, 50, 2)],
                         int(rng.integers(2, 7))) for i in range(8)]

    ref = make("ref")
    _publish_all(ref, payloads)
    ref.flush()

    src = make("src")
    _publish_all(src, payloads[:4])
    assert any(s["request_id"] >= 0 for s in src.slot_table())
    tree = src.state_tree()
    dst = make("dst")
    dst.load_state(tree)
    assert dst.state_equal(src)
    for i, p in enumerate(payloads[4:], start=4):
        dst.process(Message(i, p, 0.0))
    dst.flush()
    assert dst.state_equal(ref)


def test_slot_aligned_chunk_bytes():
    w = HashServingWorker(num_slots=4, lane_words=1024)
    assert slot_aligned_chunk_bytes(w) == 1024 * 8

    jax = pytest.importorskip("jax")
    from repro import configs
    from repro.models import transformer as T
    from repro.serving import ServingEngine
    from repro.serving.handoff import ServingWorker

    cfg = configs.get_smoke("paper_consumer")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, num_slots=4, max_seq=32)
    chunk = slot_aligned_chunk_bytes(ServingWorker(eng))
    assert chunk > 0
    # the chunk divides every cache leaf's per-slot extent, so a dirty
    # slot never smears its fingerprint into a neighbour's chunk
    for leaf in jax.tree.leaves(eng.cache):
        per_slot = int(leaf.nbytes) // 4
        assert per_slot % chunk == 0 or math.gcd(per_slot, chunk) == chunk


# ----------------------------------------------------------------- ledger

def test_ledger_first_completion_wins():
    led = CompletionLedger(_FakeSim())
    led.submit(7)
    assert led.complete(7, by="a")
    assert not led.complete(7, by="b")
    assert led.delivered[7]["by"] == "a"
    assert led.duplicates and led.duplicates[0][0] == 7
    assert led.exactly_once
    led.submit(8)
    assert not led.exactly_once  # pending request
    assert led.pending() == [8]


# ------------------------------------------------------------- experiment

def test_handoff_end_to_end_flat():
    with tempfile.TemporaryDirectory() as root:
        r = run_serving_experiment("serving_handoff", 8.0,
                                   registry_root=root, seed=0)
    assert r.exactly_once and r.state_verified
    assert r.lost == 0
    assert r.delivered == r.published
    assert r.listeners_left == 0 and r.mirrors_left == 0
    assert r.downtime < 5.0  # cutover window, not a stop-the-world gap
    lat = r.latency()
    assert lat["p99"] < 10.0


def test_handoff_beats_stop_then_replay_p99():
    """The acceptance criterion: dual-serving handoff has lower p99 than
    stop-then-replay on the same stream."""
    res = {}
    for scheme in ("serving_handoff", "ms2m_statefulset"):
        with tempfile.TemporaryDirectory() as root:
            res[scheme] = run_serving_experiment(scheme, 8.0,
                                                 registry_root=root, seed=0)
    for r in res.values():
        assert r.exactly_once and r.state_verified
    assert (res["serving_handoff"].latency()["p99"]
            < res["ms2m_statefulset"].latency()["p99"])


def test_handoff_tiebreak_perturbation():
    """Schedule perturbation: same run under three tiebreak seeds stays
    state-verified and completes the identical request set exactly
    once."""
    outcomes = []
    for ts in (None, 1, 2):
        with tempfile.TemporaryDirectory() as root:
            r = run_serving_experiment("serving_handoff", 8.0,
                                       registry_root=root, seed=0,
                                       tiebreak_seed=ts)
        assert r.exactly_once and r.state_verified
        outcomes.append((r.published, r.delivered, r.lost))
    assert len({o for o in outcomes}) == 1  # same stream, same completions


def test_handoff_sanitized_teardown():
    """Under REPRO_SIM_SANITIZE semantics, the run must leave no live
    listeners and no orphan mirrors (the dual window tears down)."""
    with tempfile.TemporaryDirectory() as root:
        r = run_serving_experiment("serving_handoff", 8.0,
                                   registry_root=root, seed=1, sanitize=True)
    assert r.exactly_once and r.state_verified
    assert r.listeners_left == 0
    assert r.mirrors_left == 0


def test_handoff_mid_fault_exactly_once():
    """Deterministic mid-handoff fault: the target node flaps the moment
    the dual-serving window opens; the attempt rolls back to the
    still-serving source and a retry completes — exactly-once
    throughout."""
    from repro.cluster.faults import parse_fault
    from repro.core.policy import MigrationPolicy

    with tempfile.TemporaryDirectory() as root:
        r = run_serving_experiment(
            "serving_handoff", 8.0, registry_root=root, seed=0,
            faults=[parse_fault(
                "node_flap@dual_serving_begin,node=node1,duration=5")],
            policy=MigrationPolicy(max_attempts=3, retry_backoff_s=1.0),
            allow_failure=True)
    assert not r.failed
    assert r.report.attempts >= 2  # the fault really interrupted a try
    assert r.exactly_once and r.state_verified
    assert r.lost == 0


@pytest.mark.parametrize("seed", range(5))
def test_handoff_chaos_property(seed):
    """Property: under ANY random target-side fault schedule (with retry),
    no request is lost and none completes twice — whether the handoff
    ultimately succeeds or rolls back to the source."""
    from repro.cluster.faults import FaultSchedule
    from repro.core.policy import MigrationPolicy

    schedule = FaultSchedule.random(
        seed, n_faults=2, t_window=(8.0, 40.0),
        nodes=("node1",), queues=("requests",))
    with tempfile.TemporaryDirectory() as root:
        r = run_serving_experiment(
            "serving_handoff", 8.0, registry_root=root, seed=seed,
            faults=schedule,
            policy=MigrationPolicy(max_attempts=3, retry_backoff_s=1.0),
            allow_failure=True)
    assert r.lost == 0
    assert r.duplicates >= 0 and r.exactly_once
    assert r.delivered == r.published
    if r.failed:
        assert r.failure.get("rolled_back")
        assert r.failure.get("source_serving")
    else:
        assert r.state_verified


def test_reference_fold_matches_experiment():
    with tempfile.TemporaryDirectory() as root:
        r = run_serving_experiment("serving_handoff", 8.0,
                                   registry_root=root, seed=2)
    assert r.state_verified  # run_serving_experiment folded the reference
    # and the helper is deterministic in its own right
    payloads = _mixed_payloads(20)
    a = serving_reference_fold(lambda: HashServingWorker(), payloads, 19)
    b = serving_reference_fold(lambda: HashServingWorker(), payloads, 19)
    assert a.state_equal(b)


# ---------------------------------------------------------------- helpers

def test_percentile_interpolation_deterministic():
    vals = [1.0, 2.0, 3.0, 4.0]
    assert percentile(vals, 50.0) == 2.5
    assert percentile(vals, 0.0) == 1.0
    assert percentile(vals, 100.0) == 4.0
    assert percentile([7.0], 99.0) == 7.0
    with pytest.raises(ValueError):
        percentile([], 50.0)
    with pytest.raises(ValueError):
        percentile(vals, 101.0)
    assert percentiles(vals, (50.0, 99.9)) == {
        "p50": 2.5, "p999": percentile(vals, 99.9)}


def test_latency_summary_row():
    row = latency_summary([0.1, 0.2, 0.3, 10.0])
    assert row["n"] == 4
    assert row["p50"] == pytest.approx(0.25, abs=1e-6)
    assert row["p99"] <= row["p999"] <= 10.0
    empty = latency_summary([])
    assert empty["n"] == 0 and empty["p99"] is None


def test_open_loop_gaps_bit_identical_to_legacy():
    rate = 8.0
    gaps = open_loop_gaps(np.random.default_rng(42), rate)
    legacy = np.random.default_rng(42)
    for _ in range(200):
        assert next(gaps) == legacy.exponential(1.0 / rate)


def test_open_loop_gaps_bursts():
    gaps = open_loop_gaps(np.random.default_rng(0), 4.0,
                          burst_factor=10.0, burst_every=10, burst_len=3)
    draws = [next(gaps) for _ in range(1000)]
    burst = [g for n, g in enumerate(draws) if n % 10 < 3]
    calm = [g for n, g in enumerate(draws) if n % 10 >= 3]
    assert np.mean(burst) < np.mean(calm) / 3  # bursts are much denser
    with pytest.raises(ValueError):
        next(open_loop_gaps(np.random.default_rng(0), 0.0))
    with pytest.raises(ValueError):
        next(open_loop_gaps(np.random.default_rng(0), 1.0,
                            burst_factor=2.0, burst_every=2, burst_len=5))
