"""Differential/property harness for the fused fingerprint+encode codec
kernels (kernels/codec.py) against the pinned host oracle
(checkpoint/codecs.py, tests/test_compression_codecs.py).

Layers, innermost out:

  * kernel level — the Pallas kernels in interpret mode vs their
    blockwise jnp lowerings, bit-for-bit, and both vs the plain
    fingerprint kernel (fusion must not change the fingerprints);
  * codec level — ``FusedLeafEncoding.blob(c)`` vs the host codec's
    ``encode`` per chunk, byte-identical, across dtypes/shapes/
    chunk-boundary straddles and dirt patterns;
  * registry level — whole pushed *images* (ids are manifest hashes, so
    id equality pins chunks, fps, accounting and manifests at once)
    under ``REPRO_CODEC_BACKEND=host`` vs ``kernel``;
  * migration level — end-to-end migrated-state verification with both
    backends under multiple seeds.

Run with ``REPRO_FORCE_PALLAS_INTERPRET=1`` to route the fused ops
through the Pallas kernels (CI does); the default CPU run exercises the
jnp lowerings, which the kernel-level tests here pin to the kernels.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint import Registry
from repro.checkpoint import codecs as codecs_mod
from repro.checkpoint.codecs import FusedLeafEncoding, get_codec
from repro.kernels import codec as ck
from repro.kernels import fingerprint as fp
from repro.kernels import ops

try:
    from hypothesis import given, settings
    import conftest as _strat
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CB = 2048  # small 512-aligned chunk grid keeps interpret mode fast

# element counts straddling the word grid (512 B), the quant-block grid
# (256 floats = 2 word rows) and the chunk grid
SIZES = [CB // 4,            # exactly one chunk
         3 * CB // 4 + 7,    # sub-chunk, odd tail
         100,                # sub-row leaf
         129,                # one quant block + 1
         5 * CB // 4,        # two chunks, short second
         2 * (CB // 4) + 1]  # two chunks + one element


def _pair(n, seed=0, kind="stripes", dtype=np.float32):
    rng = np.random.default_rng(seed)
    cur = rng.standard_normal(n).astype(dtype)
    if kind == "clean":
        parent = cur.copy()
    elif kind == "dense":
        parent = rng.standard_normal(n).astype(dtype)
    else:
        parent = cur.copy()
        idx = rng.integers(0, n, size=max(1, n // 50))
        parent[idx] += rng.standard_normal(idx.size).astype(dtype)
    return cur, parent


def _chunks(buf, cb=CB):
    return [buf[i: i + cb] for i in range(0, len(buf), cb)]


# ---------------------------------------------------------------------------
# kernel level: interpret mode vs jnp lowering, bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [CB // 4, 5 * CB // 4, 129])
def test_xor_kernel_interpret_matches_ref(n):
    cur, parent = _pair(n, seed=1)
    words, pwords = ops._codec_words(jnp.asarray(cur), parent.tobytes(),
                                     CB, pair=False)
    lanes_r, xor_r = ck.xor_fp_ref(words, pwords)
    lanes_i, xor_i = ck.xor_fp_lanes(words, pwords, interpret=True)
    np.testing.assert_array_equal(np.asarray(lanes_r), np.asarray(lanes_i))
    np.testing.assert_array_equal(np.asarray(xor_r), np.asarray(xor_i))


@pytest.mark.parametrize("n", [CB // 4, 5 * CB // 4, 129])
def test_int8_kernel_interpret_matches_ref(n):
    cur, parent = _pair(n, seed=2)
    words, pwords = ops._codec_words(jnp.asarray(cur), parent.tobytes(),
                                     CB, pair=True)
    lanes_r, q_r, s_r = ck.int8_fp_ref(words, pwords)
    lanes_i, q_i, s_i = ck.int8_fp_lanes(words, pwords, interpret=True)
    np.testing.assert_array_equal(np.asarray(lanes_r), np.asarray(lanes_i))
    np.testing.assert_array_equal(np.asarray(q_r), np.asarray(q_i))
    np.testing.assert_array_equal(np.asarray(s_r), np.asarray(s_i))


def test_fused_fingerprints_match_plain_fingerprint_kernel():
    """Fusing encode into the fingerprint pass must not change the
    fingerprints — including under the int8 path's zero-row padding."""
    cur, parent = _pair(5 * CB // 4, seed=3)
    plain = np.asarray(ops.chunk_fingerprint(cur, CB))
    fps_x, _ = ops.fused_xor_fingerprint(cur, parent.tobytes(), CB)
    fps_q, _, _ = ops.fused_int8_fingerprint(cur, parent.tobytes(), CB)
    np.testing.assert_array_equal(plain, np.asarray(fps_x))
    np.testing.assert_array_equal(plain, np.asarray(fps_q))


def test_force_interpret_env_routes_fused_ops(monkeypatch):
    cur, parent = _pair(3 * CB // 4 + 7, seed=4)
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    fx_p = ops.fused_xor_fingerprint(cur, parent.tobytes(), CB)
    fq_p = ops.fused_int8_fingerprint(cur, parent.tobytes(), CB)
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "0")
    fx_j = ops.fused_xor_fingerprint(cur, parent.tobytes(), CB)
    fq_j = ops.fused_int8_fingerprint(cur, parent.tobytes(), CB)
    for a, b in zip(fx_p + fq_p, fx_j + fq_j):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pair_rows_pads_to_quant_grid():
    words = jnp.zeros((2, 3, fp.LANES), jnp.uint32)
    assert ck.pair_rows(words).shape == (2, 4, fp.LANES)
    even = jnp.zeros((2, 4, fp.LANES), jnp.uint32)
    assert ck.pair_rows(even) is even


# ---------------------------------------------------------------------------
# codec level: kernel-encoded blobs vs the host oracle, byte-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("kind", ["clean", "stripes", "dense"])
def test_fused_xor_blob_byte_identical_to_host(n, kind):
    cur, parent = _pair(n, seed=n, kind=kind)
    praw = parent.tobytes()
    fenc = FusedLeafEncoding(jnp.asarray(cur), praw, "xor_rle",
                             np.dtype(np.float32), CB)
    codec = get_codec("xor_rle")
    for c, (seg, pseg) in enumerate(zip(_chunks(cur.tobytes()),
                                        _chunks(praw))):
        blob = fenc.blob(c)
        assert blob == codec.encode(seg, pseg, np.dtype(np.float32))
        assert codec.decode(blob, pseg, np.dtype(np.float32)) == seg


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("kind", ["clean", "stripes", "dense"])
def test_fused_int8_blob_byte_identical_to_host(n, kind):
    cur, parent = _pair(n, seed=n + 1, kind=kind)
    praw = parent.tobytes()
    fenc = FusedLeafEncoding(jnp.asarray(cur), praw, "int8",
                             np.dtype(np.float32), CB)
    codec = get_codec("int8")
    for c, (seg, pseg) in enumerate(zip(_chunks(cur.tobytes()),
                                        _chunks(praw))):
        blob = fenc.blob(c)
        assert blob == codec.encode(seg, pseg, np.dtype(np.float32))
        # round trip through the host decoder: same lossy reconstruction
        assert codec.decode(blob, pseg, np.dtype(np.float32)) \
            == codec.decode(codec.encode(seg, pseg, np.dtype(np.float32)),
                            pseg, np.dtype(np.float32))


def test_fused_xor_works_for_sub_word_dtypes():
    """xor_rle operates on raw bytes: int8/uint16 leaves must fuse too."""
    for dtype in (np.uint8, np.int16, np.int64):
        rng = np.random.default_rng(7)
        cur = rng.integers(0, 100, 3 * CB // np.dtype(dtype).itemsize
                           ).astype(dtype)
        parent = cur.copy()
        parent[10:20] += 1
        praw = parent.tobytes()
        # numpy leaves stay numpy (jnp would downcast int64 without x64)
        fenc = FusedLeafEncoding(cur, praw, "xor_rle",
                                 np.dtype(dtype), CB)
        codec = get_codec("xor_rle")
        for c, (seg, pseg) in enumerate(zip(_chunks(cur.tobytes()),
                                            _chunks(praw))):
            assert fenc.blob(c) == codec.encode(seg, pseg, np.dtype(dtype))


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(pair=_strat.codec_leaf_pairs(max_elems=2048))
    def test_fused_blobs_match_host_property(pair):
        cur, parent = pair
        praw = parent.tobytes()
        for name in ("xor_rle", "int8"):
            fenc = FusedLeafEncoding(jnp.asarray(cur), praw, name,
                                     np.dtype(np.float32), CB)
            codec = get_codec(name)
            for c, (seg, pseg) in enumerate(zip(_chunks(cur.tobytes()),
                                                _chunks(praw))):
                assert fenc.blob(c) == codec.encode(seg, pseg,
                                                    np.dtype(np.float32))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fused_blobs_match_host_property():
        pass


# ---------------------------------------------------------------------------
# registry level: whole images identical across backends
# ---------------------------------------------------------------------------

def _push_chain(root, backend, monkeypatch, cb=CB):
    monkeypatch.setenv("REPRO_CODEC_BACKEND", backend)
    rng = np.random.default_rng(11)
    reg = Registry(root, chunk_bytes=cb)
    w = rng.standard_normal(3000).astype(np.float32)
    kv = rng.standard_normal(1200).astype(np.float32)
    ints = rng.integers(0, 255, 5000).astype(np.uint8)
    odd = np.array([1.5, 2.5, 3.5])  # float64: int8 falls back to host
    ids, reports = [], []
    parent = reg.push_image(
        {"state": {"w": w, "kv": kv, "ints": ints, "odd": odd}}).image_id
    ids.append(parent)
    for step in range(3):
        kv = kv.copy()
        kv[rng.integers(0, kv.size, 40)] += \
            rng.standard_normal(40).astype(np.float32)
        ints = ints.copy()
        ints[:17] += 1
        tree = {"w": w, "kv": kv, "ints": ints, "odd": odd}
        for comp, exact in [("xor_rle", True), ("int8", False),
                            ("auto", False)]:
            rep = reg.push_delta({"state": tree}, parent,
                                 compression=comp, exact=exact)
            ids.append(rep.image_id)
            reports.append((rep.wire_bytes, rep.delta_bytes,
                            rep.enc_raw_bytes, rep.fp_bytes,
                            rep.fp_clean_chunks, rep.lossy,
                            rep.written_bytes, rep.deduped_bytes))
            parent = rep.image_id
    flush = reg.push_delta({"state": tree}, parent, compression="int8",
                           exact=True)
    ids.append(flush.image_id)
    pulled, _ = reg.pull_image(flush.image_id)
    got = pulled["state"]
    for k, v in tree.items():
        np.testing.assert_array_equal(got[k], v)
    return ids, reports


def test_registry_images_identical_across_backends(tmp_path, monkeypatch):
    """Image ids are manifest hashes: equality pins every chunk key,
    every fingerprint and every accounting field across the host and
    kernel encode paths at once."""
    ids_h, rep_h = _push_chain(str(tmp_path / "host"), "host", monkeypatch)
    ids_k, rep_k = _push_chain(str(tmp_path / "kernel"), "kernel",
                               monkeypatch)
    assert ids_h == ids_k
    assert rep_h == rep_k


def test_fused_path_engages_only_where_valid(tmp_path, monkeypatch):
    reg = Registry(str(tmp_path), chunk_bytes=CB)
    f32 = np.arange(CB, dtype=np.float32)
    f64 = np.arange(CB, dtype=np.float64)
    full = reg.push_image({"state": {"a": f32, "b": f64}})
    memo = {}
    args = dict(parent=full.image_id, name="state", n=f32.nbytes // CB,
                memo=memo)
    assert reg._fused_leaf(f32, "xor_rle", "float32", f32.nbytes,
                           i=0, **args) is not None
    assert reg._fused_leaf(f32, "int8", "float32", f32.nbytes,
                           i=0, **args) is not None
    # int8 kernel is f32-only; xor still fuses for f64
    args64 = dict(parent=full.image_id, name="state",
                  n=f64.nbytes // CB, memo=memo)
    assert reg._fused_leaf(f64, "int8", "float64", f64.nbytes,
                           i=1, **args64) is None
    assert reg._fused_leaf(f64, "xor_rle", "float64", f64.nbytes,
                           i=1, **args64) is not None
    # "none" never fuses; host backend disables fusion wholesale
    assert reg._fused_leaf(f32, "none", "float32", f32.nbytes,
                           i=0, **args) is None
    monkeypatch.setenv("REPRO_CODEC_BACKEND", "host")
    assert reg._fused_leaf(f32, "xor_rle", "float32", f32.nbytes,
                           i=0, **args) is None


def test_unaligned_chunk_grid_disables_fusion_not_correctness(tmp_path,
                                                              monkeypatch):
    """A chunk grid off the 512-byte word layout can't fuse — pushes
    must silently take the host path, not crash."""
    monkeypatch.setenv("REPRO_CODEC_BACKEND", "kernel")
    reg = Registry(str(tmp_path), chunk_bytes=1000)
    base = {"a": np.arange(2000, dtype=np.float32)}
    full = reg.push_image({"state": base})
    mut = {"a": base["a"] + 1.0}
    delta = reg.push_delta({"state": mut}, full.image_id,
                           compression="xor_rle")
    pulled, _ = reg.pull_image(delta.image_id)
    np.testing.assert_array_equal(pulled["state"]["a"], mut["a"])


def test_codec_backend_env_validated(monkeypatch):
    monkeypatch.setenv("REPRO_CODEC_BACKEND", "gpu")
    with pytest.raises(ValueError, match="REPRO_CODEC_BACKEND"):
        codecs_mod.codec_backend()


# ---------------------------------------------------------------------------
# migration level: end-to-end verification under multiple seeds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [2, 7])
def test_migration_identical_across_backends(tmp_path, seed, monkeypatch):
    from repro.core import MigrationPolicy, run_migration_experiment
    from test_compression_codecs import StripedBlobConsumer

    rows = {}
    for backend in ("host", "kernel"):
        monkeypatch.setenv("REPRO_CODEC_BACKEND", backend)
        r = run_migration_experiment(
            "ms2m_precopy", 10.0,
            registry_root=str(tmp_path / backend), seed=seed,
            worker_factory=StripedBlobConsumer, chunk_bytes=64 * 1024,
            policy=MigrationPolicy(compression="auto",
                                   precopy_max_rounds=3))
        assert r.verified and r.report.state_verified
        rows[backend] = r.row()
    assert rows["host"] == rows["kernel"]


# ---------------------------------------------------------------------------
# roofline calibration plumbing
# ---------------------------------------------------------------------------

def test_timing_constants_from_roofline_is_opt_in():
    """Measured throughput only enters via the constructor; the class
    defaults (which every regression timeline is pinned to) stay the
    paper-fitted constants."""
    from repro.cluster.cluster import TimingConstants

    d = TimingConstants()
    assert d.codec_Bps == 1.2e9 and d.fingerprint_Bps == 24e9
    cal = {"calibration": {"codec_Bps": 5e8, "fingerprint_Bps": 1e9}}
    tc = TimingConstants.from_roofline(cal)
    assert tc.codec_Bps == 5e8 and tc.fingerprint_Bps == 1e9
    assert tc.checkpoint_s == d.checkpoint_s
    assert TimingConstants.from_roofline(cal, codec_Bps=7e8).codec_Bps == 7e8
    # a bare calibration dict (no wrapper) is accepted too
    assert TimingConstants.from_roofline(
        {"codec_Bps": 2e8, "fingerprint_Bps": 0}).fingerprint_Bps == 24e9


# ---------------------------------------------------------------------------
# pallas_compat shims
# ---------------------------------------------------------------------------

def test_pallas_compat_exports_usable_shims():
    from jax.experimental.pallas import tpu as pltpu

    from repro.kernels import pallas_compat

    assert pallas_compat.CompilerParams in (
        getattr(pltpu, "CompilerParams", None),
        getattr(pltpu, "TPUCompilerParams", None))
    assert pallas_compat.MemorySpace in (
        getattr(pltpu, "MemorySpace", None),
        getattr(pltpu, "TPUMemorySpace", None))
    # the construction every kernel in this repo performs
    params = pallas_compat.CompilerParams(
        dimension_semantics=("parallel", "arbitrary"))
    assert tuple(params.dimension_semantics) == ("parallel", "arbitrary")
