"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs, plus prefill/decode equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.models.common import split_params

ARCHS = configs.list_archs(include_paper=True)


def _batch(cfg, B=2, S=16, key=1):
    tokens = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "audio_frames":
        batch["frames"] = jnp.full((B, cfg.encoder_seq, cfg.d_model), 0.01)
    if cfg.frontend == "image_patches":
        batch["patch_embeds"] = jnp.full((B, cfg.num_patches, cfg.d_model), 0.01)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = configs.get_smoke(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, aux = T.lm_forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    loss, metrics = T.lm_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    assert 5.0 < float(loss) < 10.0  # ~ln(padded_vocab) at init


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    from repro.train import step as steplib
    cfg = configs.get_smoke(arch)
    tcfg = steplib.TrainStepConfig(remat="none", lr_peak=1e-3,
                                   warmup_steps=1, total_steps=4)
    params, _ = split_params(T.init_lm(jax.random.PRNGKey(0), cfg))
    from repro.optim import adamw
    opt = adamw.adamw_init(params, tcfg.opt)
    step_fn = jax.jit(steplib.build_train_step(cfg, tcfg))
    batch = _batch(cfg)
    l0 = None
    for s in range(3):
        params, opt, m = step_fn(params, opt, batch,
                                 jnp.asarray(s, jnp.int32))
        if l0 is None:
            l0 = float(m["loss"])
    assert np.isfinite(float(m["loss"]))
    assert float(m["loss"]) < l0 + 0.5  # training on a fixed batch descends
    for leaf in jax.tree.leaves(params):
        assert not bool(jnp.isnan(leaf).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_equivalence(arch):
    """prefill(S) then decode(1) == forward(S+1) on the last position."""
    import dataclasses
    cfg = configs.get_smoke(arch)
    if cfg.num_experts:
        # dropless capacity: capacity-induced token drops differ between a
        # 26-token forward and a 1-token decode by design, not by bug
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    cache = T.init_cache(cfg, B, 32)
    logits_p, cache = T.lm_prefill(params, batch, cfg, cache)
    fwd_logits, _ = T.lm_forward(params, batch, cfg)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(fwd_logits),
                               rtol=1e-4, atol=1e-4)
    tok = batch["tokens"][:, -1:]
    pos = jnp.full((B, 1), S, jnp.int32)
    logits_d, cache = T.lm_decode_step(params, tok, pos, cfg, cache)
    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    ext.pop("labels")
    logits_f, _ = T.lm_forward(params, ext, cfg)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(logits_f[:, -1]),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("arch", ["paper_consumer", "gemma3_4b",
                                  "recurrentgemma_2b", "xlstm_350m",
                                  "granite_moe_1b_a400m"])
def test_append_matches_sequential_decode(arch):
    """lm_append (batched replay) == sequential lm_decode_step fold."""
    cfg = configs.get_smoke(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    B, K = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, K), 0,
                              cfg.vocab_size)
    c_seq = T.init_cache(cfg, B, 32)
    c_app = T.init_cache(cfg, B, 32)
    if cfg.is_encoder_decoder:
        pytest.skip("append for enc-dec requires enc_out in cache")
    logits_seq = None
    for t in range(K):
        logits_seq, c_seq = T.lm_decode_step(
            params, toks[:, t:t + 1], jnp.full((B, 1), t, jnp.int32), cfg,
            c_seq)
    positions = jnp.broadcast_to(jnp.arange(K)[None], (B, K))
    logits_app, c_app = T.lm_append(params, toks, positions, cfg, c_app)
    np.testing.assert_allclose(np.asarray(logits_app[:, -1]),
                               np.asarray(logits_seq[:, 0]),
                               rtol=1e-4, atol=1e-4)
    for a, b in zip(jax.tree.leaves(c_seq), jax.tree.leaves(c_app)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full (published) configs carry the exact assigned hyperparams."""
    spec = {
        "codeqwen1_5_7b": (32, 4096, 32, 32, 13440, 92416),
        "gemma3_4b": (34, 2560, 8, 4, 10240, 262144),
        "chatglm3_6b": (28, 4096, 32, 2, 13696, 65024),
        "smollm_360m": (32, 960, 15, 5, 2560, 49152),
        "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
        "llama4_maverick_400b_a17b": (48, 5120, 40, 8, 8192, 202048),
        "granite_moe_1b_a400m": (24, 1024, 16, 8, 512, 49155),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen2_vl_72b": (80, 8192, 64, 8, 29568, 152064),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
    }
    if arch not in spec:
        pytest.skip("paper consumer has no external spec")
    cfg = configs.get_config(arch)
    L, d, H, kv, ff, V = spec[arch]
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.num_heads == H and cfg.num_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == V


def test_int8_kv_cache_decode_close():
    """Quantized KV serving stays close to the bf16 fold (per-head int8)."""
    import dataclasses
    base = configs.get_smoke("paper_consumer")
    q8 = dataclasses.replace(base, kv_cache_dtype="int8")
    params = T.init_lm(jax.random.PRNGKey(0), base)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, base.vocab_size)
    def run(cfg):
        cache = T.init_cache(cfg, B, 32)
        logits = None
        for t in range(S):
            logits, cache = T.lm_decode_step(
                params, toks[:, t:t+1], jnp.full((B, 1), t, jnp.int32),
                cfg, cache)
        return logits
    lf = run(base)
    lq = run(q8)
    # int8 quantization error is bounded; logits must stay close
    err = float(jnp.abs(lf - lq).max())
    assert err < 0.15, err


def test_moe_local_routing_matches_global():
    """The scatter-free local-routing MoE == global pool at dropless
    capacity (the §Perf A optimization preserves semantics)."""
    import dataclasses
    cfg = dataclasses.replace(configs.get_smoke("granite_moe_1b_a400m"),
                              capacity_factor=8.0)
    from repro.models import moe as moelib
    p = moelib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.1
    out_l, aux_l = moelib.moe_forward(
        p, x, dataclasses.replace(cfg, moe_routing="local"))
    out_g, aux_g = moelib.moe_forward(
        p, x, dataclasses.replace(cfg, moe_routing="global"))
    np.testing.assert_allclose(np.asarray(out_l, np.float32),
                               np.asarray(out_g, np.float32),
                               rtol=1e-5, atol=1e-6)
    assert abs(float(aux_l) - float(aux_g)) < 1e-6


def test_moe_expert_counts():
    l4 = configs.get_config("llama4_maverick_400b_a17b")
    assert l4.num_experts == 128 and l4.num_experts_per_tok == 1
    gr = configs.get_config("granite_moe_1b_a400m")
    assert gr.num_experts == 32 and gr.num_experts_per_tok == 8


def test_moe_routing_mass_conservation():
    """Tokens that fit capacity emerge weighted; dropped tokens pass zero."""
    from repro.models import moe as moelib
    cfg = configs.get_smoke("granite_moe_1b_a400m")
    p = moelib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.1
    out, aux = moelib.moe_forward(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    assert not bool(jnp.isnan(out).any())
