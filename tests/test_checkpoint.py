"""Registry (FCC analogue) + checkpointer: roundtrip, dedup, immutability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpoint import Checkpointer, Registry


def test_roundtrip_mixed_tree(tmp_path):
    reg = Registry(str(tmp_path))
    tree = {
        "a": jnp.arange(1000, dtype=jnp.float32),
        "b": (jnp.ones((3, 4), jnp.bfloat16), np.int64(7)),
        "c": {"nested": jnp.zeros((2, 2, 2), jnp.int32)},
    }
    rep = reg.push_image({"state": tree})
    out, pulled = reg.pull_image(rep.image_id)
    got = out["state"]
    np.testing.assert_array_equal(got["a"], tree["a"])
    np.testing.assert_array_equal(np.asarray(got["b"][0], np.float32),
                                  np.asarray(tree["b"][0], np.float32))
    assert got["b"][1] == 7
    assert pulled == rep.total_bytes


def test_dedup_second_push_writes_only_delta(tmp_path):
    reg = Registry(str(tmp_path))
    weights = {"w": jnp.ones((512, 512))}
    state1 = {"cache": jnp.zeros(4096)}
    state2 = {"cache": jnp.ones(4096)}
    r1 = reg.push_image({"weights": weights, "state": state1})
    r2 = reg.push_image({"weights": weights, "state": state2})
    assert r1.written_bytes == r1.total_bytes  # cold registry
    assert r2.written_bytes < 0.05 * r2.total_bytes + 32_768  # only the delta


def test_image_id_is_content_hash(tmp_path):
    reg = Registry(str(tmp_path))
    t = {"x": jnp.arange(10)}
    r1 = reg.push_image({"s": t})
    r2 = reg.push_image({"s": t})
    assert r1.image_id == r2.image_id  # same content, same identity
    r3 = reg.push_image({"s": {"x": jnp.arange(10) + 1}})
    assert r3.image_id != r1.image_id


def test_checkpointer_latest_and_restore(tmp_path):
    reg = Registry(str(tmp_path))
    ck = Checkpointer(reg, "worker0", interval_steps=2)
    for step in range(5):
        ck.maybe_save(step, {"params": {"w": jnp.full((4,), step)}})
    ck.wait()
    step, trees = ck.restore_latest()
    assert step == 4
    np.testing.assert_array_equal(trees["params"]["w"], np.full((4,), 4))


@given(data=st.lists(st.integers(min_value=0, max_value=255),
                     min_size=1, max_size=64))
@settings(max_examples=25, deadline=None)
def test_chunk_store_content_addressing(tmp_path_factory, data):
    from repro.checkpoint.registry import ChunkStore
    store = ChunkStore(str(tmp_path_factory.mktemp("cs")))
    blob = bytes(data)
    k1, new1 = store.put(blob)
    k2, new2 = store.put(blob)
    assert k1 == k2 and new1 and not new2
    assert store.get(k1) == blob
