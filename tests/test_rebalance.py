"""Predictive rebalance controller: decision-table units for the pure
scorers, proactive-drain-beats-reactive end-to-end, disabled-by-default
bit-identity, sanitizer + tiebreak-perturbation robustness, and the
fluid <-> per-message differential with the controller in the loop."""
import math

import numpy as np
import pytest

from repro.cluster import Fault
from repro.cluster.controller import (
    RebalanceConfig,
    move_cost_bytes,
    move_score,
    predicted_messages_at_risk,
    run_rebalance_scenario,
)
from repro.core.workload import (
    ARRIVAL_SCHEDULES,
    diurnal_rate,
    flash_crowd_rate,
    make_arrival_gaps,
    modulated_open_loop_gaps,
    open_loop_gaps,
)


# ---------------------------------------------------------------------------
# decision table: the pure scorers
# ---------------------------------------------------------------------------

def test_messages_at_risk_zero_arrivals_is_just_the_backlog():
    # λ = 0: the catch-up window adds nothing beyond the standing backlog
    assert predicted_messages_at_risk(0.0, 10.0, 40.0, 30.0) == 40.0


def test_messages_at_risk_finite_catchup_inside_horizon():
    # catch-up = 40/(10-2) = 5 s < horizon: exposure is the catch-up time
    assert predicted_messages_at_risk(2.0, 10.0, 40.0, 30.0) == \
        pytest.approx(40.0 + 2.0 * 5.0)


def test_messages_at_risk_saturated_is_capped_by_horizon():
    # λ >= μ: catch-up diverges; the horizon bounds the exposure instead
    # of the score going infinite (which would starve every other signal)
    risk = predicted_messages_at_risk(6.0, 6.0, 200.0, 30.0)
    assert math.isfinite(risk)
    assert risk == pytest.approx(200.0 + 6.0 * 30.0)


def test_messages_at_risk_long_but_finite_catchup_is_also_capped():
    # catch-up = 900/(10-9) = 900 s >> horizon
    assert predicted_messages_at_risk(9.0, 10.0, 900.0, 30.0) == \
        pytest.approx(900.0 + 9.0 * 30.0)


def test_move_cost_scales_with_both_zone_legs():
    near = move_cost_bytes(1e6, 0, 0)
    far = move_cost_bytes(1e6, 2, 1)
    assert near == pytest.approx(1e6)
    assert far == pytest.approx(4e6)  # 1 + registry(2) + source(1) legs
    assert move_cost_bytes(0.0, 0, 0) == 1.0  # floor: never divide by ~0


def test_suspect_saturated_backlog_outranks_safe_idle_pod():
    # the table row the controller exists for: a flapping node holding a
    # saturated queue must outrank a healthy near-empty one
    hot = move_score(1.0, predicted_messages_at_risk(6.0, 6.0, 200.0, 30.0),
                     move_cost_bytes(8e6, 1, 1))
    idle = move_score(0.25, predicted_messages_at_risk(1.0, 10.0, 2.0, 30.0),
                      move_cost_bytes(8e6, 1, 1))
    assert hot > 50.0 * idle


def test_cheaper_state_wins_at_equal_risk():
    mar = predicted_messages_at_risk(4.0, 8.0, 50.0, 30.0)
    small = move_score(1.0, mar, move_cost_bytes(1e5, 1, 1))
    big = move_score(1.0, mar, move_cost_bytes(1e8, 1, 1))
    assert small > big  # messages-at-risk *per byte moved*


# ---------------------------------------------------------------------------
# arrival schedules (core.workload)
# ---------------------------------------------------------------------------

def test_steady_schedule_is_bit_identical_to_open_loop_gaps():
    a = open_loop_gaps(np.random.default_rng(7), 6.0)
    b = make_arrival_gaps("steady", np.random.default_rng(7), 6.0)
    assert [next(a) for _ in range(200)] == [next(b) for _ in range(200)]


def test_modulated_gaps_are_deterministic_per_seed():
    for schedule in ARRIVAL_SCHEDULES:
        a = make_arrival_gaps(schedule, np.random.default_rng(3), 5.0)
        b = make_arrival_gaps(schedule, np.random.default_rng(3), 5.0)
        assert [next(a) for _ in range(300)] == [next(b) for _ in range(300)]


def test_diurnal_rate_oscillates_and_flash_crowd_steps():
    r = diurnal_rate(period_s=100.0, depth=0.5)
    assert r(25.0) == pytest.approx(1.5)   # peak of the sine
    assert r(75.0) == pytest.approx(0.5)   # trough
    f = flash_crowd_rate(at_s=30.0, duration_s=20.0, factor=4.0)
    assert f(10.0) == 1.0 and f(40.0) == 4.0 and f(60.0) == 1.0


def test_flash_crowd_compresses_gaps_during_the_burst():
    rng = np.random.default_rng(11)
    gaps = modulated_open_loop_gaps(
        rng, 5.0, flash_crowd_rate(at_s=30.0, duration_s=30.0, factor=8.0))
    t, before, during = 0.0, [], []
    for _ in range(600):
        g = next(gaps)
        t += g
        if t < 30.0:
            before.append(g)
        elif t < 60.0:
            during.append(g)
    assert during, "burst window produced no arrivals"
    assert np.mean(during) < np.mean(before) / 3.0


def test_unknown_schedule_is_rejected():
    with pytest.raises(ValueError, match="steady"):
        make_arrival_gaps("lunar", np.random.default_rng(0), 5.0)


# ---------------------------------------------------------------------------
# end-to-end scenarios
# ---------------------------------------------------------------------------

def _flap_story():
    """node1 flaps once early (8 s) and once late, longer (25 s): the
    first flap is the controller's tell, the second is the exposure the
    baseline eats in place."""
    return [Fault("node_flap", at=20.0, node="node1", duration=8.0),
            Fault("node_flap", at=70.0, node="node1", duration=25.0)]


def _scenario(tmp_path, tag, **kw):
    kw.setdefault("n_pods", 4)
    kw.setdefault("num_nodes", 3)
    kw.setdefault("message_rate", 5.0)
    kw.setdefault("t_end", 100.0)
    kw.setdefault("sample_dt", 1.0)
    return run_rebalance_scenario(
        registry_root=str(tmp_path / f"reg-{tag}"), **kw)


def test_proactive_drain_beats_reactive_on_node_flap(tmp_path):
    base = _scenario(tmp_path, "base", faults=_flap_story(), seed=0)
    ctrl = _scenario(tmp_path, "ctrl", faults=_flap_story(), seed=0,
                     controller=RebalanceConfig())
    assert base.all_verified and ctrl.all_verified
    assert base.n_moves == 0
    assert ctrl.n_moves > 0               # it actually acted...
    assert ctrl.moved_wire_bytes > 0
    # ...ahead of the long flap: service exposure strictly improves
    assert ctrl.unserved_queue_seconds < base.unserved_queue_seconds
    kinds = {e["kind"] for e in ctrl.events}
    assert "rebalance_suspect" in kinds and "rebalance_move" in kinds


def test_reactive_default_is_deterministic_and_verified(tmp_path):
    # controller=None is the default: two identical runs, bit-identical
    # rows — the no-controller path carries zero nondeterminism from the
    # controller module being imported/loaded
    a = _scenario(tmp_path, "a", faults=_flap_story(), seed=1)
    b = _scenario(tmp_path, "b", faults=_flap_story(), seed=1)
    assert a.all_verified
    assert a.row() == b.row()
    assert a.n_moves == 0 and a.moved_wire_bytes == 0


def test_existing_experiment_rows_unchanged_by_controller_module(tmp_path):
    # loading the controller subsystem must not perturb the pre-existing
    # fleet experiment: same call, same row, before and after the import
    # machinery above has pulled in repro.cluster.controller
    from repro.core import run_fleet_experiment

    r1 = run_fleet_experiment(3, "ms2m_individual", 8.0,
                              registry_root=str(tmp_path / "f1"), seed=2)
    r2 = run_fleet_experiment(3, "ms2m_individual", 8.0,
                              registry_root=str(tmp_path / "f2"), seed=2)
    assert r1.all_verified
    assert r1.row() == r2.row()


def test_controller_survives_sanitizer_and_tiebreak_perturbation(tmp_path):
    # runtime sanitizer on + 5 different event-tiebreak seeds: the
    # controller's conclusions may shift with scheduling order, but every
    # run must verify and conserve messages end-to-end
    for ts in range(5):
        r = _scenario(tmp_path, f"ts{ts}", faults=_flap_story(), seed=3,
                      controller=RebalanceConfig(), sanitize=True,
                      tiebreak_seed=ts, t_end=90.0)
        assert r.all_verified, f"tiebreak_seed={ts} failed verification"
        assert r.processed_total == r.published_total, \
            f"tiebreak_seed={ts} lost/duplicated messages"


@pytest.mark.parametrize("schedule", ["diurnal", "flash_crowd"])
def test_fluid_and_per_message_rows_match_with_controller(tmp_path, schedule):
    # PR 9's fluid epochs must stay bit-identical with the controller in
    # the loop reading fleet_state() snapshots every tick
    kw = dict(faults=_flap_story(), seed=4, schedule=schedule,
              controller=RebalanceConfig(), t_end=90.0)
    fluid = _scenario(tmp_path, f"fl-{schedule}", fluid=True, **kw)
    exact = _scenario(tmp_path, f"pm-{schedule}", fluid=False, **kw)
    assert fluid.all_verified
    assert fluid.row() == exact.row()
