"""Serving engine: continuous batching correctness + MS2M migratability."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.broker.broker import Message
from repro.models import transformer as T
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke("paper_consumer")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_single_request_matches_plain_decode(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, num_slots=2, max_seq=64)
    prompt = [5, 7, 11]
    eng.submit(Request(0, prompt, max_new_tokens=6))
    eng.step(16)
    assert len(eng.completions) == 1
    got = eng.completions[0].tokens
    # reference: plain greedy decode
    import jax.numpy as jnp
    cache = T.init_cache(cfg, 1, 64)
    logits = None
    for t, tok in enumerate(prompt):
        logits, cache = T.lm_decode_step(
            params, jnp.asarray([[tok]], jnp.int32),
            jnp.asarray([[t]], jnp.int32), cfg, cache)
    want = []
    tok = int(jnp.argmax(logits[0, -1]))
    pos = len(prompt)
    for _ in range(6):
        want.append(tok)
        logits, cache = T.lm_decode_step(
            params, jnp.asarray([[tok]], jnp.int32),
            jnp.asarray([[pos]], jnp.int32), cfg, cache)
        tok = int(jnp.argmax(logits[0, -1]))
        pos += 1
    assert got == want


def test_concurrent_requests_complete(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, num_slots=2, max_seq=64)
    for i in range(5):  # more requests than slots -> queueing
        eng.submit(Request(i, [3 + i, 9], max_new_tokens=4))
    for _ in range(60):
        eng.step()
        if len(eng.completions) == 5:
            break
    assert sorted(c.request_id for c in eng.completions) == list(range(5))
    assert all(len(c.tokens) == 4 for c in eng.completions)


def test_engine_is_ms2m_migratable(setup):
    """checkpoint -> replay message suffix == uninterrupted engine."""
    cfg, params = setup
    msgs = [Message(i, {"request_id": i, "prompt": [2 + i, 4],
                        "max_new_tokens": 3}, 0.0) for i in range(6)]
    a = ServingEngine(cfg, params, num_slots=2, max_seq=64)
    for m in msgs:
        a.process(m)
    b = ServingEngine(cfg, params, num_slots=2, max_seq=64)
    for m in msgs[:3]:
        b.process(m)
    snap = b.state_tree()
    c = ServingEngine(cfg, params, num_slots=2, max_seq=64)
    c.load_state(snap)
    for m in msgs[3:]:
        c.process(m)
    assert c.state_equal(a), "engine replay diverged from full fold"
