"""Percentile/latency helper edge cases (analysis/stats.py and its
benchmarks/stats.py re-export): the tail math must be deterministic and
well-defined at the degenerate ends — n=1, ties, p999 on arrays shorter
than 1000 samples — because benchmark report rows are diffed bit-for-bit
across runs."""
import numpy as np
import pytest

from repro.analysis.stats import (
    LATENCY_PERCENTILES,
    latency_summary,
    percentile,
    percentiles,
    summarize_spans,
)


def test_percentile_single_sample_is_that_sample():
    for p in (0.0, 50.0, 99.0, 99.9, 100.0):
        assert percentile([7.25], p) == 7.25


def test_percentile_all_ties_is_the_tie():
    xs = [3.5] * 9
    for p in (0.0, 37.0, 99.9, 100.0):
        assert percentile(xs, p) == 3.5


def test_p999_on_short_arrays_interpolates_toward_max():
    """With n << 1000 the p999 rank lands between the last two order
    statistics — it must interpolate, not index out of range, and it can
    never exceed the max."""
    xs = list(range(10))  # rank = 0.999 * 9 = 8.991
    got = percentile(xs, 99.9)
    assert 8.0 < got < 9.0
    assert got == pytest.approx(8.991)
    assert percentile(xs, 100.0) == 9.0


def test_percentile_matches_numpy_default_method():
    rng = np.random.default_rng(3)
    xs = rng.standard_normal(257).tolist()
    for p in (0.0, 12.5, 50.0, 99.0, 99.9, 100.0):
        assert percentile(xs, p) == pytest.approx(
            float(np.percentile(xs, p)), abs=1e-12)


def test_percentile_rejects_out_of_range_and_empty():
    with pytest.raises(ValueError):
        percentile([1.0], 101.0)
    with pytest.raises(ValueError):
        percentile([1.0], -0.5)
    with pytest.raises(ValueError):
        percentile([], 50.0)


def test_percentiles_key_naming_drops_decimal_point():
    out = percentiles([1.0, 2.0], LATENCY_PERCENTILES)
    assert set(out) == {"p50", "p99", "p999"}
    assert percentiles([5.0], (25.0,)) == {"p25": 5.0}


def test_latency_summary_empty_is_all_none_row():
    row = latency_summary([])
    assert row["n"] == 0
    assert row["mean"] is None and row["max"] is None
    assert row["p50"] is None and row["p999"] is None


def test_latency_summary_rounding_and_fields():
    row = latency_summary([0.12345678, 0.2, 0.3], ndigits=4)
    assert row["n"] == 3
    assert row["mean"] == round((0.12345678 + 0.2 + 0.3) / 3, 4)
    assert row["max"] == 0.3
    assert row["p50"] == 0.2
    unrounded = latency_summary([0.12345678], ndigits=None)
    assert unrounded["mean"] == 0.12345678


def test_summarize_spans_empty_and_ties():
    assert summarize_spans([]) == {"p50": None, "p99": None}
    out = summarize_spans([2.0, 2.0, 2.0])
    assert out == {"p50": 2.0, "p99": 2.0}


def test_benchmarks_stats_reexports_same_objects():
    """The operator CLI (PYTHONPATH=src) and the benchmarks must share
    one implementation, not two drifting copies."""
    import benchmarks.stats as bstats
    import repro.analysis.stats as astats

    assert bstats.percentile is astats.percentile
    assert bstats.latency_summary is astats.latency_summary
    assert bstats.summarize_spans is astats.summarize_spans
    assert bstats.percentiles is astats.percentiles
    assert bstats.LATENCY_PERCENTILES is astats.LATENCY_PERCENTILES
