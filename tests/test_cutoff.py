"""Unit + property tests for the Threshold-Based Cutoff math (Eqs. 1-5)."""
import math

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cutoff import (
    CutoffController,
    RateEstimator,
    batched_cutoff_threshold,
    cutoff_threshold,
    expected_catchup_time,
    replay_time_bound,
    stable_for_live_migration,
)

pos = st.floats(min_value=1e-3, max_value=1e4, allow_nan=False,
                allow_infinity=False)


def test_eq5_paper_example():
    # paper baseline: mu=20 msg/s; at lambda=4 and T_replay_max=45,
    # T_cutoff = 45*20/4 = 225 s
    assert cutoff_threshold(45.0, 20.0, 4.0) == pytest.approx(225.0)
    assert cutoff_threshold(45.0, 20.0, 16.0) == pytest.approx(56.25)


def test_zero_rate_is_unbounded():
    assert cutoff_threshold(10.0, 20.0, 0.0) == math.inf


@given(t=pos, mu=pos, lam=pos)
@settings(max_examples=200, deadline=None)
def test_eq5_guarantee(t, mu, lam):
    """Replay of messages accumulated for exactly T_cutoff takes <= T_replay_max."""
    t_cut = cutoff_threshold(t, mu, lam)
    if math.isfinite(t_cut):
        assert replay_time_bound(lam, t_cut, mu) <= t * (1 + 1e-9)


@given(t=pos, mu=pos, lam=pos)
@settings(max_examples=100, deadline=None)
def test_threshold_monotonicity(t, mu, lam):
    # higher lambda -> shorter admissible window; higher mu -> longer
    assert cutoff_threshold(t, mu, 2 * lam) <= cutoff_threshold(t, mu, lam)
    assert cutoff_threshold(t, 2 * mu, lam) >= cutoff_threshold(t, mu, lam)


@given(t=pos, mu=pos, lam=pos,
       speedup=st.floats(min_value=1.0, max_value=100.0))
@settings(max_examples=100, deadline=None)
def test_batched_threshold_extends_window(t, mu, lam, speedup):
    assert batched_cutoff_threshold(t, mu, lam, speedup) >= \
        cutoff_threshold(t, mu, lam) * (1 - 1e-9)


def test_catchup_diverges_at_saturation():
    assert expected_catchup_time(20.0, 20.0, 10.0) == math.inf
    assert expected_catchup_time(21.0, 20.0, 10.0) == math.inf
    assert expected_catchup_time(10.0, 20.0, 10.0) == pytest.approx(1.0)


def test_stability_guard():
    assert stable_for_live_migration(4.0, 20.0)
    assert not stable_for_live_migration(19.5, 20.0)


def test_rate_estimator_converges():
    est = RateEstimator(halflife=5.0)
    t = 0.0
    for _ in range(500):
        t += 0.1  # 10 events/s
        est.observe(t)
    assert est.rate == pytest.approx(10.0, rel=0.05)


def test_controller_threshold_tracks_estimates():
    c = CutoffController(t_replay_max=10.0, mu_fallback=20.0, lam_fallback=5.0,
                         use_estimates=True)
    # no observations -> fallbacks: 10*20/5 = 40
    assert c.threshold() == pytest.approx(40.0)
    t = 0.0
    for _ in range(2000):
        t += 0.05  # service events at 20/s
        c.observe_service(t)
    t = 0.0
    for _ in range(1000):
        t += 0.1  # arrivals at 10/s
        c.observe_arrival(t)
    assert c.threshold() == pytest.approx(10.0 * c.mu / c.lam, rel=1e-6)
    assert c.mu == pytest.approx(20.0, rel=0.1)
    assert c.lam == pytest.approx(10.0, rel=0.1)
    assert c.should_cutoff(accum_started=0.0, now=c.threshold() + 1)
    assert not c.should_cutoff(accum_started=0.0, now=c.threshold() - 1)
