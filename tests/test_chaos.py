"""Property-based chaos suite: randomized seeded fault schedules against
single-pod and fleet migrations.

The core crash-consistency invariants, asserted over arbitrary
target-side fault schedules (node crashes/flaps, link degradation,
registry outages, broker stalls):

  * every migration that completes is ``state_verified`` — the target's
    state equals an independent reference fold of the published log, so
    there is no message loss, duplication or reordering;
  * every exhausted-retries failure was rolled back: the source pod is
    still serving its primary queue and its state is drain-consistent
    (equals the reference fold of everything it processed);
  * the same seed reproduces bit-identical ``FleetReport`` rows.

The schedule/run helpers are shared with ``benchmarks/chaos.py`` (the
>= 100-schedule sweep behind ``results/chaos.json``); fixed-seed
regressions for the same machinery live in ``tests/test_faults.py``.
"""
import json

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from benchmarks.chaos import SCHEMES, _run_one  # noqa: E402
from repro.cluster import FaultSchedule  # noqa: E402
from repro.core import MigrationPolicy, run_migration_experiment  # noqa: E402

CHAOS = dict(deadline=None, print_blob=True,
             suppress_health_check=[HealthCheck.too_slow,
                                    HealthCheck.data_too_large])


@settings(max_examples=12, **CHAOS)
@given(seed=st.integers(0, 2 ** 16),
       scheme=st.sampled_from(SCHEMES),
       n_faults=st.integers(1, 3))
def test_fleet_chaos_invariants(seed, scheme, n_faults):
    """Completed => verified; failed => rolled back with the source still
    serving and drain-consistent — for any target-side fault schedule."""
    outcome = _run_one(scheme, seed, n_faults)
    assert outcome["invariant_ok"], outcome
    row = outcome["row"]
    # accounting sanity: attempts cover every outcome at least once, and
    # recovered only counts completed migrations
    assert row["attempts"] >= row["n_migrated"] + row["n_failed"]
    assert row["recovered"] <= row["n_migrated"]


@settings(max_examples=10, **CHAOS)
@given(seed=st.integers(0, 2 ** 16),
       scheme=st.sampled_from(SCHEMES),
       n_faults=st.integers(1, 3))
def test_single_pod_chaos_invariants(seed, scheme, n_faults, tmp_path_factory):
    """Same invariants through the single-migration harness: either the
    migration (eventually) verifies, or the rolled-back source serves."""
    schedule = FaultSchedule.random(
        seed, n_faults=n_faults, t_window=(10.0, 70.0),
        nodes=("node1", "node2"), queues=("orders",))
    root = str(tmp_path_factory.mktemp("chaos-reg"))
    r = run_migration_experiment(
        scheme, 8.0, registry_root=root, seed=seed,
        faults=schedule, allow_failure=True,
        policy=MigrationPolicy(max_attempts=3, retry_backoff_s=1.0))
    if r.failed:
        f = r.failure
        assert f["rolled_back"], f
        assert f["source_serving"], f
        assert f["source_verified"], f
    else:
        assert r.verified
        assert r.report.state_verified


@settings(max_examples=5, **CHAOS)
@given(seed=st.integers(0, 2 ** 16),
       scheme=st.sampled_from(SCHEMES))
def test_same_seed_reproduces_bit_identical_fleet_rows(seed, scheme):
    """Determinism: one seed, two runs, identical FleetReport rows (and
    identical injected schedules)."""
    a = _run_one(scheme, seed, 2)
    b = _run_one(scheme, seed, 2)
    assert a["schedule"] == b["schedule"]
    assert (json.dumps(a["row"], sort_keys=True)
            == json.dumps(b["row"], sort_keys=True))
