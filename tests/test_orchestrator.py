"""ClusterMigrationOrchestrator: concurrent fleets, rolling StatefulSet
migration with identity handoff, node drain."""
import pytest

from repro.core import (
    ClusterMigrationOrchestrator,
    HashConsumer,
    PodMigrationSpec,
    run_fleet_experiment,
)


def test_parallel_fleet_migrates_concurrently_and_verifies(tmp_path):
    fleet = run_fleet_experiment(
        5, "ms2m_individual", 8.0, registry_root=str(tmp_path / "reg"),
        mode="parallel", max_concurrent=4, seed=2)
    assert fleet.n_migrated == 5
    assert fleet.peak_concurrency >= 4  # genuinely concurrent migrations
    assert all(r.state_verified for r in fleet.reports)
    assert fleet.all_verified
    assert fleet.max_downtime < 5.0  # every pod kept MS2M's short cutover


def test_concurrency_limit_is_respected(tmp_path):
    fleet = run_fleet_experiment(
        5, "ms2m_individual", 6.0, registry_root=str(tmp_path / "reg"),
        mode="parallel", max_concurrent=2, seed=3)
    assert fleet.n_migrated == 5
    assert fleet.peak_concurrency == 2
    assert fleet.all_verified


def test_parallel_fleet_with_precopy_strategy(tmp_path):
    fleet = run_fleet_experiment(
        4, "ms2m_precopy", 8.0, registry_root=str(tmp_path / "reg"),
        mode="parallel", max_concurrent=4, seed=1)
    assert fleet.n_migrated == 4
    assert fleet.all_verified
    assert all(r.precopy_rounds >= 1 for r in fleet.reports)


def test_rolling_statefulset_is_sequential_and_verified(tmp_path):
    fleet = run_fleet_experiment(
        4, "ms2m_statefulset", 6.0, registry_root=str(tmp_path / "reg"),
        mode="rolling", seed=4)
    assert fleet.n_migrated == 4
    assert fleet.peak_concurrency == 1  # one replica at a time
    assert fleet.all_verified
    assert all(r.strategy == "ms2m_statefulset" for r in fleet.reports)
    # rolling => migrations do not overlap in time
    spans = sorted((r.t_start, r.t_end) for r in fleet.reports)
    for (_, end_prev), (start_next, _) in zip(spans, spans[1:]):
        assert start_next >= end_prev


def test_drain_node_moves_every_pod_and_hands_off_identity(tmp_path):
    from repro.cluster.cluster import Cluster

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=3)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    stop = {"flag": False}
    pods = {}

    for i in range(4):
        qname = f"orders-{i}"
        broker.declare_queue(qname)

        def producer(i=i, qname=qname):
            while not stop["flag"]:
                yield 0.2
                broker.publish(qname, {"token": (i * 131) % 997})

        sim.process(producer())
        identity = "consumer-0" if i == 0 else None  # one sticky replica

        def boot(i=i, qname=qname, identity=identity):
            pod = yield from api.create_pod(
                f"consumer-{i}", "node0", HashConsumer(),
                broker.queues[qname], statefulset_identity=identity)
            pod.start()
            pods[i] = pod

        sim.process(boot())

    sim.run(until=8.0)
    orch = ClusterMigrationOrchestrator(api, HashConsumer, max_concurrent=3)
    done = orch.drain_node("node0")
    sim.run(stop_when=done)
    fleet = done.value
    stop["flag"] = True
    sim.run(until=sim.now + 1.0)

    assert fleet.n_migrated == 4
    assert api.nodes["node0"].pods == {}  # node fully evacuated
    for target in fleet.targets:
        assert target.node.name != "node0"
        assert not target.deleted
    # the sticky replica was moved with the StatefulSet strategy and its
    # identity is now held by the target pod
    by_strategy = {r.strategy for r in fleet.reports}
    assert "ms2m_statefulset" in by_strategy
    holder = api.statefulsets.identities["consumer-0"]
    assert holder is not None and holder != "consumer-0"
    assert holder in api.pods


def test_drain_refuses_when_no_other_node(tmp_path):
    from repro.cluster.cluster import Cluster

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=1)
    orch = ClusterMigrationOrchestrator(cluster.api, HashConsumer)
    with pytest.raises(RuntimeError):
        orch.drain_node("node0")


def test_drain_refuses_when_all_other_nodes_dead(tmp_path):
    """'No alive target' is about liveness, not topology: other nodes
    exist but are all down."""
    from repro.cluster.cluster import Cluster

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=3)
    cluster.api.kill_node("node1")
    cluster.api.kill_node("node2")
    orch = ClusterMigrationOrchestrator(cluster.api, HashConsumer)
    with pytest.raises(RuntimeError, match="no alive node"):
        orch.drain_node("node0")


def _boot_fleet(cluster, n, node="node0"):
    """n producer/consumer pairs on one node; returns (pods, stop flag)."""
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    stop = {"flag": False}
    pods = {}
    for i in range(n):
        qname = f"orders-{i}"
        broker.declare_queue(qname)

        def producer(i=i, qname=qname):
            while not stop["flag"]:
                yield 0.2
                broker.publish(qname, {"token": (i * 131) % 997})

        sim.process(producer())

        def boot(i=i, qname=qname):
            pod = yield from api.create_pod(
                f"consumer-{i}", node, HashConsumer(), broker.queues[qname])
            pod.start()
            pods[i] = pod

        sim.process(boot())
    sim.run(until=8.0)
    return pods, stop


def test_drain_node_with_custom_target_picker(tmp_path):
    from repro.cluster.cluster import Cluster

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=4)
    sim, api = cluster.sim, cluster.api
    pods, stop = _boot_fleet(cluster, 3)

    picked = []

    def everything_to_node3(pod):
        picked.append(pod.name)
        return "node3"  # ignore the round-robin default

    orch = ClusterMigrationOrchestrator(api, HashConsumer)
    done = orch.drain_node("node0", target_node_for=everything_to_node3)
    sim.run(stop_when=done)
    fleet = done.value
    stop["flag"] = True
    sim.run(until=sim.now + 1.0)

    assert sorted(picked) == [f"consumer-{i}" for i in range(3)]
    assert fleet.n_migrated == 3 and fleet.n_failed == 0
    assert api.nodes["node0"].pods == {}
    assert all(t.node.name == "node3" for t in fleet.targets)


def test_dead_target_node_fails_spec_not_fleet(tmp_path):
    """A spec pointing at a node that died mid-fleet is recorded in
    FleetReport.failures; every other spec completes, and the failed
    migration leaves no orphan mirror behind."""
    from repro.cluster.cluster import Cluster

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=4)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    pods, stop = _boot_fleet(cluster, 3)
    api.kill_node("node3")  # dies before its spec's create_pod runs

    orch = ClusterMigrationOrchestrator(api, HashConsumer, max_concurrent=3)
    specs = [
        PodMigrationSpec(pod=pods[0], queue="orders-0", target_node="node1"),
        PodMigrationSpec(pod=pods[1], queue="orders-1", target_node="node3"),
        PodMigrationSpec(pod=pods[2], queue="orders-2", target_node="node2"),
    ]
    done = orch.migrate_fleet(specs)
    sim.run(stop_when=done)
    fleet = done.value
    stop["flag"] = True
    sim.run(until=sim.now + 1.0)

    assert fleet.n_migrated == 2 and fleet.n_failed == 1
    failure = fleet.failures[0]
    assert failure["pod"] == "consumer-1"
    assert failure["target_node"] == "node3"
    assert "dead" in failure["error"]
    assert fleet.row()["n_failed"] == 1
    # survivors moved; the failed source pod is still serving
    assert {t.queue.name for t in fleet.targets} == {"orders-0", "orders-2"}
    assert not pods[1].deleted
    # the dead spec's secondary was detached on failure (no double-buffer)
    assert broker._mirrors["orders-1"] == []


def test_invalid_spec_fails_spec_not_fleet(tmp_path):
    """Validation errors (identity handoff on a non-StatefulSet strategy)
    are isolated per spec too."""
    from repro.cluster.cluster import Cluster

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=3)
    sim = cluster.sim
    pods, stop = _boot_fleet(cluster, 2)

    orch = ClusterMigrationOrchestrator(cluster.api, HashConsumer)
    specs = [
        PodMigrationSpec(pod=pods[0], queue="orders-0", target_node="node1",
                         strategy="ms2m_individual", identity="consumer-0"),
        PodMigrationSpec(pod=pods[1], queue="orders-1", target_node="node2"),
    ]
    done = orch.migrate_fleet(specs)
    sim.run(stop_when=done)
    fleet = done.value
    stop["flag"] = True

    assert fleet.n_migrated == 1 and fleet.n_failed == 1
    assert "ms2m_statefulset" in fleet.failures[0]["error"]


def test_spec_defaults_roundtrip():
    # PodMigrationSpec is a plain dataclass usable without the harness
    spec = PodMigrationSpec(pod=None, queue="q", target_node="node1")
    assert spec.strategy == "ms2m_individual"
    assert spec.identity is None
    assert spec.policy is None
    # target_node=None defers to the orchestrator's placement policy
    assert PodMigrationSpec(pod=None, queue="q").target_node is None


def test_fleet_experiment_rejects_single_node(tmp_path):
    """num_nodes=1 used to silently 'migrate' every pod onto its own node
    (source node{i % max(1, ...)} == target node0); now it is a clear
    error, in every mode."""
    for mode in ("parallel", "rolling", "drain"):
        with pytest.raises(ValueError, match="num_nodes >= 2"):
            run_fleet_experiment(
                2, "ms2m_individual", 8.0,
                registry_root=str(tmp_path / "reg"), mode=mode, num_nodes=1)


def test_migration_experiment_rejects_single_node(tmp_path):
    from repro.core import run_migration_experiment

    with pytest.raises(ValueError, match="num_nodes >= 2"):
        run_migration_experiment("ms2m_individual", 8.0,
                                 registry_root=str(tmp_path / "reg"),
                                 num_nodes=1)


# ---------------------------------------------------------------------------
# placement tie-break: lexicographic (queued_bytes, n_flows), not their sum
# ---------------------------------------------------------------------------

def test_tiebreak_is_lexicographic_not_mixed_unit_sum():
    """Two equidistant candidates: A's registry link holds ~2 in-flight
    bytes across 5 flows, B's holds ~4 bytes in 1 flow.  The old mixed-unit
    sum (queued_bytes + n_flows: 7 vs 5) ranked B first — one in-flight
    byte outweighing a whole flow.  Bytes-then-flows must pick A."""
    from types import SimpleNamespace

    from repro.cluster.network import LinkSpec, NetworkTopology
    from repro.cluster.sim import Sim
    from repro.core.orchestrator import make_topology_aware_placement

    sim = Sim()
    topo = NetworkTopology(
        "tiebreak", {"src": "home", "a": "zb", "b": "zc"},
        registry_zone="home",
        link_specs={"intra": LinkSpec(1e9),
                    "cross": LinkSpec(1.0)}).bind(sim)

    def occupy(node, nbytes, n):
        link = topo.registry_link(node)
        for _ in range(n):
            sim.process(link.transfer(nbytes))

    occupy("a", 0.4, 5)   # queued ~2 bytes, 5 flows  -> old sum 7
    occupy("b", 4.0, 1)   # queued ~4 bytes, 1 flow   -> old sum 5
    sim.run(until=0.001)  # admit the flows; ~nothing drains at 1 B/s
    link_a, link_b = topo.registry_link("a"), topo.registry_link("b")
    assert link_a.queued_bytes < link_b.queued_bytes
    assert (link_a.queued_bytes + link_a.n_flows
            > link_b.queued_bytes + link_b.n_flows)  # the sum misranks

    pick = make_topology_aware_placement(
        SimpleNamespace(topology=topo), {})
    pod = SimpleNamespace(node=SimpleNamespace(name="src"), worker=None)
    candidates = [SimpleNamespace(name=n, pods={}) for n in ("a", "b")]
    assert pick(pod, candidates) == "a"
    assert pick(pod, list(reversed(candidates))) == "a"  # order-independent
