"""Heartbeat failure-detector regressions: exactly-once death events,
re-detection after revival, and exactly-once message delivery across a
node flap (soft partition + revive)."""
from repro.cluster import Cluster, Fault
from repro.core import HashConsumer
from repro.core.workload import reference_fold


def test_on_node_dead_fires_exactly_once_per_death(tmp_path):
    cluster = Cluster(str(tmp_path / "reg"), num_nodes=3)
    sim, api = cluster.sim, cluster.api
    dead = []
    api.start_heartbeats(on_node_dead=dead.append)
    sim.run(until=5.0)
    api.kill_node("node1")
    sim.run(until=60.0)  # many heartbeat intervals after the timeout
    assert dead == ["node1"]


def test_second_death_after_revive_is_redetected(tmp_path):
    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2)
    sim, api = cluster.sim, cluster.api
    dead = []
    api.start_heartbeats(on_node_dead=dead.append)
    sim.run(until=4.0)
    api.kill_node("node1")
    sim.run(until=20.0)
    assert dead == ["node1"]
    api.revive_node("node1")
    sim.run(until=30.0)
    assert dead == ["node1"]  # a healthy revived node emits nothing
    api.kill_node("node1")
    sim.run(until=50.0)
    assert dead == ["node1", "node1"]  # the second death is re-detected


def test_flap_shorter_than_timeout_is_not_reported(tmp_path):
    """A partition that heals inside the heartbeat timeout never surfaces
    as a death event."""
    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2)
    sim, api = cluster.sim, cluster.api
    dead = []
    api.start_heartbeats(on_node_dead=dead.append)
    sim.run(until=4.0)
    api.partition_node("node1")
    sim.run(until=8.0)  # timeout is 6s; revive at 8s - 4s down < detection
    api.revive_node("node1")
    sim.run(until=30.0)
    assert dead == []


def test_partitioned_detected_then_revived_then_killed_again(tmp_path):
    """partition -> detected -> revive -> hard kill: two death events."""
    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2)
    sim, api = cluster.sim, cluster.api
    dead = []
    api.start_heartbeats(on_node_dead=dead.append)
    sim.run(until=2.0)
    api.partition_node("node1")
    sim.run(until=20.0)
    assert dead == ["node1"]
    api.revive_node("node1")
    sim.run(until=26.0)
    api.kill_node("node1")
    sim.run(until=60.0)
    assert dead == ["node1", "node1"]


def test_flapping_node_pods_resume_without_double_delivery(tmp_path):
    """Pods on a flapped (partitioned, then revived) node stall in place
    and resume afterwards; every message is folded exactly once, even the
    one that was mid-service when the node dropped (it is requeued and
    redelivered, deduplicated by id)."""
    # wide in-flight windows so the partition reliably lands mid-service
    faults = [Fault("node_flap", at=5.5, node="node0", duration=4.0)]
    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2, faults=faults)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    q = broker.declare_queue("orders")
    worker = HashConsumer()
    holder = {}

    def boot():
        pod = yield from api.create_pod("c0", "node0", worker, q,
                                        processing_ms=400.0)
        pod.start()
        holder["pod"] = pod

    sim.process(boot())
    tokens = []

    def producer():
        i = 0
        while sim.now < 20.0:
            yield 0.5
            broker.publish("orders", {"token": (i * 13) % 997})
            tokens.append((i * 13) % 997)
            i += 1

    sim.process(producer())
    sim.run(until=5.7)
    pod = holder["pod"]
    assert not pod.deleted  # a flap does NOT kill the pod (kill_node does)
    n_at_partition = worker.n_processed
    sim.run(until=9.0)
    # nothing was folded while the node was "offline"
    assert worker.n_processed == n_at_partition
    sim.run(until=60.0)
    assert q.depth() == 0  # resumed and drained the backlog
    # exactly-once: the fold equals the reference fold of the full log
    ref = reference_fold(HashConsumer, tokens, worker.last_msg_id)
    assert ref.state_equal(worker)
    assert worker.n_processed == len(tokens)
