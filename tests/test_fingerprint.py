"""Device-side chunk fingerprinting: Pallas (interpret mode) vs the
blockwise jnp lowering, bit-exactly, plus the dirty-detection semantics
the registry relies on."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import fingerprint as fp
from repro.kernels import ops

CB = 64 * 1024  # chunk bytes used throughout


def _fps_ref(x, chunk_bytes=CB):
    words = fp.chunked_words(x, chunk_bytes)
    return np.asarray(fp.collapse_lanes(fp.fingerprint_lanes_ref(words)))


@pytest.mark.parametrize("n,dtype", [
    (300_000, np.float32),     # multi-chunk, word-sized elements
    (50_000, np.float64),      # 8-byte elements
    (123_456, np.int8),        # sub-word elements, odd tail
    (77_777, np.uint16),       # 2-byte grouping, odd tail
    (100, np.float32),         # single chunk, sub-row leaf
])
def test_interpret_matches_jnp_lowering(n, dtype):
    rng = np.random.default_rng(0)
    x = rng.integers(0, 127, n).astype(dtype)
    words = fp.chunked_words(x, CB)
    ref = fp.collapse_lanes(fp.fingerprint_lanes_ref(words))
    pal = fp.collapse_lanes(fp.fingerprint_lanes(words, interpret=True))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


def test_force_interpret_env_routes_through_pallas(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    x = np.arange(40_000, dtype=np.float32)
    via_pallas = np.asarray(ops.chunk_fingerprint(x, CB))
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "0")
    via_jnp = np.asarray(ops.chunk_fingerprint(x, CB))
    np.testing.assert_array_equal(via_pallas, via_jnp)


def test_chunk_grid_matches_registry_chunk_count():
    for nbytes in (1, CB - 4, CB, CB + 4, 3 * CB + 100):
        n = nbytes // 4
        if n == 0:
            continue
        x = np.zeros(n, np.float32)
        want_chunks = -(-x.nbytes // CB)
        assert _fps_ref(x).shape == (want_chunks, fp.FP_WORDS)


def test_single_element_change_dirties_only_its_chunk():
    x = np.zeros(10 * CB // 4, np.float32)
    base = _fps_ref(x)
    for chunk in (0, 4, 9):
        y = x.copy()
        y[chunk * (CB // 4) + 17] = 1.0
        diff = (base != _fps_ref(y)).any(axis=1)
        assert list(np.flatnonzero(diff)) == [chunk]


def test_equal_content_equal_fingerprint_across_positions():
    """Content addressing: a chunk's fingerprint depends on its content
    only, not on which chunk slot it occupies."""
    pattern = np.arange(CB // 4, dtype=np.float32)
    x = np.concatenate([pattern, np.zeros(CB // 4, np.float32), pattern])
    fps = _fps_ref(x)
    np.testing.assert_array_equal(fps[0], fps[2])
    assert (fps[0] != fps[1]).any()


def test_order_sensitivity_within_chunk():
    x = np.arange(CB // 4, dtype=np.float32)
    y = x.copy()
    y[1000], y[2000] = y[2000], y[1000]  # swap two unequal elements
    assert (_fps_ref(x) != _fps_ref(y)).any()


def test_bit_reinterpretation_not_value_hash():
    """-0.0 == 0.0 numerically but differs bitwise: the fingerprint must
    see bits (the registry chunks raw bytes)."""
    x = np.zeros(1024, np.float32)
    y = x.copy()
    y[3] = -0.0
    assert (_fps_ref(x) != _fps_ref(y)).any()


def test_jax_and_numpy_inputs_agree():
    x = np.random.default_rng(1).standard_normal(30_000).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(ops.chunk_fingerprint(x, CB)),
        np.asarray(ops.chunk_fingerprint(jnp.asarray(x), CB)))


def test_bfloat16_words():
    x = jnp.arange(5000, dtype=jnp.bfloat16)
    out = np.asarray(ops.chunk_fingerprint(x, CB))
    assert out.shape == (1, fp.FP_WORDS)
    y = jnp.concatenate([x[:100] + 1, x[100:]])
    assert (np.asarray(ops.chunk_fingerprint(y, CB)) != out).any()
