"""MS2M applied to training workers: optimizer state must survive
image+replay migration bit-exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.broker.broker import Message
from repro.core.trainer_worker import TrainerWorker
from repro.data import DataConfig
from repro.optim import adamw
from repro.train import step as steplib


def _make_factory():
    cfg = configs.get_smoke("paper_consumer")
    tcfg = steplib.TrainStepConfig(
        remat="none", lr_peak=1e-3, warmup_steps=2, total_steps=1000,
        opt=adamw.AdamWConfig(weight_decay=0.01))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    return lambda: TrainerWorker(cfg, tcfg, dcfg)


def test_trainer_state_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import Registry
    make = _make_factory()
    w = make()
    for i in range(5):
        w.process(Message(i, {"batch_id": i}, 0.0))
    reg = Registry(str(tmp_path))
    rep = reg.push_image({"state": w.state_tree()})
    w2 = make()
    trees, _ = reg.pull_image(rep.image_id)
    w2.load_state(trees["state"])
    assert w2.state_equal(w)
    # both continue identically
    w.process(Message(5, {"batch_id": 5}, 0.0))
    w2.process(Message(5, {"batch_id": 5}, 0.0))
    assert w2.state_equal(w)


def test_trainer_replay_determinism():
    """fold(0..n) == fold(0..k) -> checkpoint -> fold(k..n): the MS2M
    premise for training state (incl. Adam moments)."""
    make = _make_factory()
    a, b = make(), make()
    msgs = [Message(i, {"batch_id": i}, 0.0) for i in range(8)]
    for m in msgs:
        a.process(m)
    for m in msgs[:4]:
        b.process(m)
    snap = b.state_tree()
    c = make()
    c.load_state(snap)
    for m in msgs[4:]:
        c.process(m)
    assert c.state_equal(a), "replay from checkpoint diverged from full fold"


def test_trainer_migration_through_cluster(tmp_path):
    from repro.core import run_migration_experiment
    make = _make_factory()
    r = run_migration_experiment(
        "ms2m_statefulset", 4.0, registry_root=str(tmp_path),
        worker_factory=make, seed=0, t_migrate=5.0, settle_time=2.0)
    assert r.verified
    assert r.report.replayed_messages > 0
