"""Exact failure recovery: image + journal-suffix replay == full fold."""
import pytest

from repro.broker.broker import Message
from repro.cluster.cluster import Cluster
from repro.core import HashConsumer
from repro.core.journal import Journal, JournaledQueue, recover_worker


def test_journal_replay_range(tmp_path):
    from repro.checkpoint import Registry
    reg = Registry(str(tmp_path))
    j = Journal(reg, "q", segment_size=4)
    for i in range(11):
        j.append(Message(i, {"token": i * 3}, 0.0))
    msgs = j.replay_range(3, 9)
    assert [m.msg_id for m in msgs] == list(range(3, 10))
    j.flush()
    assert j.replay_range(0)[0].payload == {"token": 0}


def test_exact_recovery_after_node_kill(tmp_path):
    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2)
    sim, api = cluster.sim, cluster.api
    jq = JournaledQueue(cluster.broker, "orders", cluster.registry)
    worker = HashConsumer()
    holder = {}

    def boot():
        pod = yield from api.create_pod("c0", "node0", worker, jq.queue)
        pod.start()
        holder["pod"] = pod

    sim.process(boot())
    published = []

    def producer():
        i = 0
        while sim.now < 120.0:
            yield 0.25
            jq.publish({"token": (i * 17) % 997})
            published.append((i * 17) % 997)
            i += 1

    sim.process(producer())

    def checkpointer():
        while sim.now < 120.0:
            pod = holder.get("pod")
            if pod and not pod.deleted:
                ckpt = yield from api.checkpoint_pod(pod)
                yield from api.build_and_push_image(ckpt, "ft")
            yield 3.0

    sim.process(checkpointer())
    sim.run(until=30.0)
    api.kill_node("node0")  # messages consumed since the last image die here

    rec = sim.process(recover_worker(
        api, cluster.registry, jq.journal, "ft",
        lambda: HashConsumer(), "node1", jq.queue, "c0-recovered"))
    sim.run(until=100.0)
    new_pod = rec.value
    nw = new_pod.worker
    assert nw.n_processed + 0 >= 0 and nw.last_msg_id > worker.last_msg_id

    # exactness: recovered state == reference fold of the FULL log 0..last
    ref = HashConsumer()
    for i, tok in enumerate(published[: nw.last_msg_id + 1]):
        ref.process(Message(i, {"token": tok}, 0.0))
    assert ref.state_equal(nw), "journaled recovery diverged from full fold"
