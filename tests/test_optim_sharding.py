"""Optimizer, gradient compression, sharding rules, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress_gradients,
    decompress_gradients,
    ef_init,
)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"x": jnp.array([3.0, -2.0, 1.5])}
    state = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_weight_decay_shrinks():
    cfg = AdamWConfig(lr=0.01, weight_decay=0.5)
    params = {"w": jnp.ones((8,))}
    state = adamw_init(params, cfg)
    zero_grads = {"w": jnp.zeros((8,))}
    for _ in range(10):
        params, state, _ = adamw_update(params, zero_grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_factored_matches_full_direction():
    """Factored 2nd moment approximates the full one (same step sign)."""
    k = jax.random.PRNGKey(0)
    g = jax.random.normal(k, (256, 256))
    p = {"w": jnp.zeros((256, 256))}
    full = AdamWConfig(lr=1e-2, weight_decay=0.0)
    fact = AdamWConfig(lr=1e-2, weight_decay=0.0, factored=True)
    sf = adamw_init(p, full)
    sa = adamw_init(p, fact)
    pf, _, _ = adamw_update(p, {"w": g}, sf, full)
    pa, _, _ = adamw_update(p, {"w": g}, sa, fact)
    # same sign on >99% of coordinates
    agree = np.mean(np.sign(pf["w"]) == np.sign(pa["w"]))
    assert agree > 0.99


def test_grad_clipping():
    from repro.optim import clip_by_global_norm
    g = {"a": jnp.full((100,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(100.0)
    from repro.optim import global_norm
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


@given(st.integers(min_value=1, max_value=2000),
       st.integers(min_value=0, max_value=100))
@settings(max_examples=25, deadline=None)
def test_compression_error_feedback_bounded(n, seed):
    """EF invariant: per-step dequant error is carried, not accumulated —
    |residual| stays bounded by one quantization step."""
    rng = np.random.default_rng(seed)
    g = {"x": jnp.asarray(rng.normal(0, 1, n).astype(np.float32))}
    ef = ef_init(g)
    for _ in range(5):
        comp, ef = compress_gradients(g, ef)
        deq = decompress_gradients(comp, g)
    scale = float(jnp.abs(g["x"]).max()) / 127.0
    assert float(jnp.abs(ef["x"]).max()) <= scale * 1.01 + 1e-6


def test_compression_roundtrip_small_error():
    rng = np.random.default_rng(0)
    g = {"x": jnp.asarray(rng.normal(0, 1, 4096).astype(np.float32))}
    comp, _ = compress_gradients(g, ef_init(g))
    deq = decompress_gradients(comp, g)
    rel = float(jnp.linalg.norm(deq["x"] - g["x"]) / jnp.linalg.norm(g["x"]))
    assert rel < 0.01
    assert comp["x"]["q"].dtype == jnp.int8


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_rules_divisibility_fallback():
    import os
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import AxisRules, logical_to_spec
    mesh = jax.make_mesh((1,), ("model",))
    rules = AxisRules({"heads": "model", "mlp": "model"})
    # size-1 axis divides everything
    spec = logical_to_spec(("heads", "mlp"), mesh, rules, dims=(8, 128))
    assert spec == P("model", None)  # 'model' consumed by first dim


def test_rules_drop_nondivisible(monkeypatch):
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import AxisRules, logical_to_spec
    # fake mesh with model=16 via the real helper on a 1-device mesh is not
    # possible; emulate with a Mesh of 1 but patch size lookup
    from repro.sharding import rules as R
    mesh = jax.make_mesh((1,), ("model",))
    monkeypatch.setattr(R, "_mesh_axis_size", lambda m, a: 16)
    rules = AxisRules({"heads": "model"})
    spec = logical_to_spec(("heads",), mesh, rules, dims=(15,))
    assert spec == P(None)  # 15 % 16 != 0 -> dropped
    assert rules.dropped


def test_rules_absent_mesh_axes_filtered():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import DEFAULT_RULES, logical_to_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 'batch' maps to ('pod','data'); 'pod' absent on single-pod mesh
    spec = logical_to_spec(("batch", None), mesh, DEFAULT_RULES,
                           dims=(8, 8))
    assert spec == P("data", None)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_batches_deterministic():
    from repro.data import DataConfig, SyntheticTokenDataset
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=1)
    ds1, ds2 = SyntheticTokenDataset(cfg), SyntheticTokenDataset(cfg)
    b1, b2 = ds1.batch(7), ds2.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds1.batch(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_host_sharding_partitions():
    from repro.data import DataConfig, SyntheticTokenDataset
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8, seed=0)
    ds = SyntheticTokenDataset(cfg)
    h0 = ds.batch(3, host_id=0, num_hosts=2)
    h1 = ds.batch(3, host_id=1, num_hosts=2)
    assert h0["tokens"].shape == (4, 8)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_labels_are_next_tokens():
    from repro.data import DataConfig, SyntheticTokenDataset
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2, seed=0)
    b = SyntheticTokenDataset(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
