"""End-to-end behaviour of the paper's system: the four migration
strategies, the cutoff mechanism's guarantee, failure recovery, and the
claims bands at reduced repeat count."""
import os
import tempfile

import pytest

from repro.core import (
    HashConsumer,
    cutoff_threshold,
    expected_catchup_time,
    run_migration_experiment,
)

STRATEGIES = ("stop_and_copy", "ms2m_individual", "ms2m_cutoff",
              "ms2m_statefulset")


@pytest.fixture()
def tmp_registry(tmp_path):
    return str(tmp_path / "registry")


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_migration_preserves_state(strategy, tmp_registry):
    r = run_migration_experiment(strategy, 6.0, registry_root=tmp_registry,
                                 seed=3)
    assert r.verified, f"{strategy}: migrated state != reference fold"
    assert r.migration_time > 0
    assert r.downtime > 0


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("rate", [2.0, 10.0, 16.0])
def test_no_message_loss_or_duplication(strategy, rate, tmp_registry):
    r = run_migration_experiment(
        strategy, rate, registry_root=f"{tmp_registry}-{strategy}-{rate}",
        seed=7)
    assert r.verified  # reference fold equality == no loss, no dup, in order


def test_ms2m_downtime_beats_stop_and_copy(tmp_registry):
    sac = run_migration_experiment("stop_and_copy", 10.0,
                                   registry_root=tmp_registry + "a", seed=0)
    ms2m = run_migration_experiment("ms2m_individual", 10.0,
                                    registry_root=tmp_registry + "b", seed=0)
    assert ms2m.downtime < 0.1 * sac.downtime  # paper: ~97% reduction


def test_cutoff_bounds_replay_time(tmp_registry):
    """Eq. 5 guarantee: with the cutoff, replay after the source stop is
    bounded by ~T_replay_max even at high λ."""
    t_replay_max = 20.0
    r = run_migration_experiment(
        "ms2m_cutoff", 18.0, registry_root=tmp_registry, seed=1,
        t_replay_max=t_replay_max)
    assert r.verified
    assert r.report.cutoff_fired
    # downtime = remaining-drain (bounded by T_replay_max) + restore
    # remainder + switch; the *replay* share must respect the bound:
    assert r.report.phases.get("message_replay", 0.0) <= t_replay_max * 1.5


def test_cutoff_does_not_fire_at_low_rate(tmp_registry):
    r = run_migration_experiment("ms2m_cutoff", 2.0,
                                 registry_root=tmp_registry, seed=1)
    assert not r.report.cutoff_fired
    assert r.downtime < 3.0


def test_individual_migration_time_diverges_near_saturation(tmp_registry):
    fast = run_migration_experiment("ms2m_individual", 4.0,
                                    registry_root=tmp_registry + "a", seed=2)
    slow = run_migration_experiment("ms2m_individual", 18.0,
                                    registry_root=tmp_registry + "b", seed=2)
    assert slow.migration_time > 2.5 * fast.migration_time
    # matches M/M/1: backlog/(mu-lambda) blow-up
    assert expected_catchup_time(18.0, 20.0, 100) > \
        expected_catchup_time(4.0, 20.0, 100)


def test_statefulset_identity_exclusivity():
    from repro.cluster.cluster import StatefulSetController
    sts = StatefulSetController()
    sts.claim("consumer-0", "pod-a")
    with pytest.raises(RuntimeError):
        sts.claim("consumer-0", "pod-b")
    sts.release("consumer-0")
    sts.claim("consumer-0", "pod-b")  # ok after release


def test_node_failure_recovery_via_image(tmp_path):
    """FT path: kill the node mid-service; controller restores the latest
    image on another node and continues — worker state restored."""
    from repro.cluster.cluster import Cluster

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    q = broker.declare_queue("orders")
    worker = HashConsumer()

    def boot():
        pod = yield from api.create_pod("c0", "node0", worker, q)
        pod.start()
        return pod

    boot_done = sim.process(boot())
    tokens = []

    def producer():
        i = 0
        while sim.now < 60.0:
            yield 0.2
            broker.publish("orders", {"token": i * 31 % 997})
            tokens.append(i * 31 % 997)
            i += 1

    sim.process(producer())
    sim.run(until=5.0)
    pod = boot_done.value

    def checkpointer():
        while sim.now < 60.0 and not pod.deleted:
            ckpt = yield from api.checkpoint_pod(pod)
            yield from api.build_and_push_image(ckpt, "ft")
            yield 2.0

    sim.process(checkpointer())
    sim.run(until=30.0)
    api.kill_node("node0")
    assert pod.deleted

    image_id = cluster.registry.resolve("ft")
    assert image_id is not None
    new_worker = HashConsumer()

    def recover():
        meta = yield from api.pull_and_restore(image_id, new_worker)
        new_worker.skip_until = meta["last_msg_id"]
        new_pod = yield from api.create_pod("c0r", "node1", new_worker, q)
        new_pod.start()
        return new_pod

    sim.process(recover())
    sim.run(until=90.0)
    assert new_worker.n_processed > 0
    assert new_worker.last_msg_id > worker.last_msg_id  # made progress


def test_heartbeat_failure_detector(tmp_path):
    from repro.cluster.cluster import Cluster

    cluster = Cluster(str(tmp_path / "reg"), num_nodes=2)
    sim, api = cluster.sim, cluster.api
    dead = []
    api.start_heartbeats(on_node_dead=dead.append)
    sim.run(until=5.0)
    api.kill_node("node1")
    sim.run(until=20.0)
    assert dead == ["node1"]


def test_claims_bands_fast():
    """One-seed version of benchmarks/claims.py core bands."""
    from benchmarks.claims import run_claims

    claims = run_claims(repeats=1)
    failed = [c["claim"] for c in claims if not c["pass"]]
    assert not failed, f"claims failed: {failed}"
