"""Broker semantics (secondary queues, ordering) and sim-kernel behaviour."""
import pytest

from repro.broker.broker import Broker
from repro.cluster.sim import Sim


def test_sim_time_ordering():
    sim = Sim()
    log = []

    def p(name, delay):
        yield delay
        log.append((sim.now, name))

    sim.process(p("b", 2.0))
    sim.process(p("a", 1.0))
    sim.process(p("c", 3.0))
    sim.run()
    assert log == [(1.0, "a"), (2.0, "b"), (3.0, "c")]


def test_sim_condition_wakeup():
    sim = Sim()
    cond = sim.condition()
    got = []

    def waiter():
        v = yield cond
        got.append((sim.now, v))

    sim.process(waiter())
    sim.call_at(5.0, lambda: cond.trigger("x"))
    sim.run()
    assert got == [(5.0, "x")]


def test_sim_any_of():
    sim = Sim()
    c1, c2 = sim.condition(), sim.condition()
    got = []

    def waiter():
        yield sim.any_of(c1, c2)
        got.append(sim.now)

    sim.process(waiter())
    sim.call_at(3.0, c2.trigger)
    sim.call_at(7.0, c1.trigger)
    sim.run()
    assert got == [3.0]


def test_any_of_detaches_losers_no_callback_growth():
    """Regression: the fleet driver builds a fresh any_of over the same
    still-active conditions on every wakeup; the losers' callback lists
    must not grow across iterations (each winner detaches its round)."""
    sim = Sim()
    a, b = sim.condition(), sim.condition()
    for _ in range(100):
        c = sim.condition()
        out = sim.any_of(a, b, c)
        c.trigger()
        assert out.triggered
    assert len(a._callbacks) == 0
    assert len(b._callbacks) == 0


def test_any_of_with_already_triggered_condition():
    sim = Sim()
    a, b = sim.condition(), sim.condition()
    b.trigger("v")
    out = sim.any_of(a, b)
    assert out.triggered and out.value == "v"
    assert len(a._callbacks) == 0  # the pending loser was detached too


def test_sub_process_return_values():
    sim = Sim()

    def child():
        yield 1.0
        return 42

    def parent():
        v = yield from child()
        return v + 1

    done = sim.process(parent())
    sim.run()
    assert done.value == 43


def test_secondary_queue_mirrors_from_attach_point():
    sim = Sim()
    broker = Broker(sim)
    q = broker.declare_queue("q")
    broker.publish("q", {"n": 0})
    q.try_get()  # message 0 was CONSUMED before the attach
    sec = broker.attach_secondary("q")
    broker.publish("q", {"n": 1})
    broker.publish("q", {"n": 2})
    assert sec.depth() == 2  # consumed message 0 is not mirrored
    m1 = sec.try_get()
    assert m1.msg_id == 1  # ids preserved across the mirror
    broker.detach_secondary("q", sec.name)
    broker.publish("q", {"n": 3})
    assert sec.depth() == 1  # no mirroring after detach


def test_secondary_queue_mirrors_unconsumed_backlog():
    """The accumulation buffer must cover every id the consumer has not
    folded yet: unconsumed backlog present at attach time is copied into
    the mirror (in id order, ahead of post-attach publishes).  Without
    this, a behind-the-queue source (e.g. one just resumed by a migration
    rollback) checkpoints below the backlog ids and the target loses
    them — neither image nor mirror would hold them."""
    sim = Sim()
    broker = Broker(sim)
    broker.declare_queue("q")
    broker.publish("q", {"n": 0})
    broker.publish("q", {"n": 1})  # both still unconsumed
    sec = broker.attach_secondary("q")
    broker.publish("q", {"n": 2})
    assert sec.depth() == 3
    assert [sec.try_get().msg_id for _ in range(3)] == [0, 1, 2]


def test_queue_ids_monotone():
    sim = Sim()
    broker = Broker(sim)
    q = broker.declare_queue("q")
    ids = [broker.publish("q", {}).msg_id for _ in range(10)]
    assert ids == list(range(10))
    assert q.peek_last_id() == 9


def test_pod_requeues_message_interrupted_by_pause(tmp_path):
    """A message in service when the pod pauses returns to the queue front."""
    from repro.cluster.cluster import Cluster
    from repro.core import HashConsumer

    cluster = Cluster(str(tmp_path), num_nodes=1)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    q = broker.declare_queue("q")
    worker = HashConsumer()

    def boot():
        pod = yield from api.create_pod("p", "node0", worker, q)
        pod.start()
        return pod

    done = sim.process(boot())
    broker.publish("q", {"token": 1})
    sim.run(until=3.02)  # pod created at t=3; service takes 50 ms
    pod = done.value
    pod.pause()
    sim.run(until=4.0)
    assert worker.n_processed == 0
    assert q.depth() == 1  # requeued, not lost
    pod.resume()
    pod.wake()
    sim.run(until=5.0)
    assert worker.n_processed == 1


def _boot_one_pod(tmp_path, qname="q"):
    from repro.cluster.cluster import Cluster
    from repro.core import HashConsumer

    cluster = Cluster(str(tmp_path), num_nodes=1)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    q = broker.declare_queue(qname)
    worker = HashConsumer()

    def boot():
        pod = yield from api.create_pod("p", "node0", worker, q)
        pod.start()
        return pod

    done = sim.process(boot())
    sim.run(until=3.0)
    return cluster, done.value, worker, q


def test_paused_pod_contributes_no_sim_events(tmp_path):
    """The old loop busy-polled a paused pod at 20 Hz; the condition-based
    stall contributes ZERO events, so the heap fully drains while paused."""
    cluster, pod, worker, q = _boot_one_pod(tmp_path)
    sim = cluster.sim
    pod.pause()
    sim.run(until=4.0)      # let any in-flight wind down
    assert sim._heap == []  # nothing scheduled: no 0.05 s poll ticks
    sim.run(until=10_000.0)
    assert sim.now == 10_000.0 and sim._heap == []


def test_resume_alone_wakes_a_stalled_pod(tmp_path):
    cluster, pod, worker, q = _boot_one_pod(tmp_path)
    sim, broker = cluster.sim, cluster.broker
    pod.pause()
    sim.run(until=4.0)
    broker.publish("q", {"token": 3})  # arrives while stalled
    sim.run(until=5.0)
    assert worker.n_processed == 0
    pod.resume()  # no explicit wake() needed: resume releases the stall
    sim.run(until=6.0)
    assert worker.n_processed == 1


def test_node_recovery_wakes_stalled_pods(tmp_path):
    cluster, pod, worker, q = _boot_one_pod(tmp_path)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    node = api.nodes["node0"]
    node.alive = False  # transient partition: pods stay scheduled
    broker.publish("q", {"token": 5})
    sim.run(until=6.0)
    assert worker.n_processed == 0  # stalled on the dead node, no spinning
    assert sim._heap == []
    api.revive_node("node0")
    sim.run(until=8.0)
    assert node.alive
    assert worker.n_processed == 1
