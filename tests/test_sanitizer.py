"""Runtime-sanitizer coverage: both reconstructed historical leaks (the
PR 1 ``on_processed`` listener leak and the PR 4 ``any_of`` loser-callback
leak), conflicting double-triggers, the stale-pause watchpoint, and the
quiescence audit — each reported with creation-site provenance."""
import pytest

from repro.analysis.sanitizer import SanitizerViolation
from repro.cluster.cluster import Cluster
from repro.cluster.sim import Sim
from repro.core import HashConsumer


# -- historical leak 1: any_of loser callbacks --------------------------------
def test_anyof_loser_callback_leak_detected_with_creation_site():
    """Reconstruction of the pre-PR 4 ``any_of``: losers were never
    detached, so a driver loop racing fresh conditions against one
    long-lived condition grew its callback list by one per wakeup.  The
    sanitizer must trip on the growth and point at the long-lived
    condition's creation site."""
    sim = Sim(sanitize=True)
    wake = sim.condition("driver:wake")  # long-lived, never triggers

    def leaky_any_of(*conds):
        out = sim.condition("any")

        def fire(value=None):
            out.trigger(value)  # historical bug: losers stay attached

        for c in conds:
            c.on_trigger(fire)
        return out

    with pytest.raises(SanitizerViolation) as ei:
        for i in range(200):  # default threshold is 64
            done = sim.condition(f"done{i}")
            leaky_any_of(done, wake)
            done.trigger()
    assert ei.value.kind == "callback_leak"
    assert "driver:wake" in str(ei.value)
    assert any("test_sanitizer.py" in frame for frame in ei.value.created)


def test_fixed_anyof_does_not_trip_the_sanitizer():
    """The shipped ``any_of`` detaches losers: the same driver pattern
    must run clean under the sanitizer."""
    sim = Sim(sanitize=True)
    wake = sim.condition("driver:wake")
    for i in range(200):
        done = sim.condition(f"done{i}")
        sim.any_of(done, wake)
        done.trigger()
    assert len(wake._callbacks) == 0


# -- historical leak 2: on_processed listeners --------------------------------
def test_on_processed_listener_leak_detected(tmp_path):
    """Reconstruction of the pre-PR 1 sync-condition leak: every
    migration chained a listener onto the source pod and never removed
    it.  The sanitizer must trip on the listener-list growth."""
    cluster = Cluster(str(tmp_path), num_nodes=2, sanitize=True)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    q = broker.declare_queue("orders")
    holder = {}

    def boot():
        pod = yield from api.create_pod("c0", "node0", HashConsumer(), q)
        holder["pod"] = pod

    sim.process(boot())
    sim.run()
    pod = holder["pod"]

    with pytest.raises(SanitizerViolation) as ei:
        for i in range(200):  # one leaked listener per "migration"
            pod.add_on_processed(lambda p, m: None)
    assert ei.value.kind == "listener_leak"
    assert "'c0'" in str(ei.value)
    assert any("test_sanitizer.py" in frame for frame in ei.value.site)


def test_migrations_run_clean_under_sanitizer(tmp_path):
    """The shipped migration path deregisters everything: repeated
    migrations of one lineage must not trip any sanitizer check."""
    from repro.core import MigrationManager

    cluster = Cluster(str(tmp_path), num_nodes=3, sanitize=True)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    broker.declare_queue("orders")
    stop = {"flag": False}

    def producer():
        while not stop["flag"]:
            yield 0.1
            broker.publish("orders", {"token": 7})

    sim.process(producer())
    holder = {}

    def boot():
        pod = yield from api.create_pod("consumer-0", "node0",
                                        HashConsumer(),
                                        broker.queues["orders"])
        pod.start()
        holder["pod"] = pod

    sim.process(boot())
    sim.run(until=5.0)

    mgr = MigrationManager(api, HashConsumer, "orders")
    pod = holder["pod"]
    for hop, node in enumerate(["node1", "node2"]):
        done = mgr.migrate("ms2m_individual", pod, node)
        sim.run(stop_when=done)
        _, pod = done.value
    stop["flag"] = True
    sim.run(until=sim.now + 2.0)
    assert pod.worker.n_processed > 0
    assert sim.sanitizer.stats["conditions"] > 0


# -- conflicting double-trigger -----------------------------------------------
def test_double_trigger_with_conflicting_value_raises():
    sim = Sim(sanitize=True)
    c = sim.condition("result")
    c.trigger("a")
    c.trigger()     # idempotent re-trigger: the kernel contract, legal
    c.trigger("a")  # same value: legal
    with pytest.raises(SanitizerViolation) as ei:
        c.trigger("b")
    assert ei.value.kind == "double_trigger"
    assert any("test_sanitizer.py" in frame for frame in ei.value.created)


# -- stale-pause watchpoint ---------------------------------------------------
def test_stale_pause_after_rollback_restore_detected(tmp_path):
    """A pod restored to service by a rollback is owned by nobody; a
    later ``pause()`` is the stale-cutoff-deadline bug class (PR 5) and
    must raise with the restore site."""
    cluster = Cluster(str(tmp_path), num_nodes=2, sanitize=True)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    q = broker.declare_queue("orders")
    holder = {}

    def boot():
        pod = yield from api.create_pod("c0", "node0", HashConsumer(), q)
        pod.start()
        holder["pod"] = pod

    sim.process(boot())
    sim.run()
    pod = holder["pod"]

    sim.sanitizer.protect_pod(pod)  # what rollback() arms after a restore
    with pytest.raises(SanitizerViolation) as ei:
        pod.pause()
    assert ei.value.kind == "stale_pause"
    assert not pod.paused  # the violation fired before the pause landed

    sim.sanitizer.unprotect_pod(pod)  # what a new MigrationContext does
    pod.pause()
    assert pod.paused


def test_cutoff_timer_disarms_cleanly_after_rollback(tmp_path):
    """Integration: a migration that fails and rolls back leaves its
    cutoff deadline armed in the heap; when it fires after ``closed`` it
    must disarm (counted) rather than pause the restored source."""
    from repro.core import MigrationManager, MigrationPolicy
    from repro.core.migration import MigrationError

    cluster = Cluster(str(tmp_path), num_nodes=3, sanitize=True)
    sim, api, broker = cluster.sim, cluster.api, cluster.broker
    broker.declare_queue("orders")
    stop = {"flag": False}

    def producer():
        while not stop["flag"]:
            yield 0.05
            broker.publish("orders", {"token": 3})

    sim.process(producer())
    holder = {}

    def boot():
        pod = yield from api.create_pod("consumer-0", "node0",
                                        HashConsumer(),
                                        broker.queues["orders"])
        pod.start()
        holder["pod"] = pod

    sim.process(boot())
    sim.run(until=5.0)

    def saboteur():
        yield 8.0  # mid-transfer, before the cutoff deadline
        api.kill_node("node1")

    sim.process(saboteur())
    mgr = MigrationManager(api, HashConsumer, "orders",
                           policy=MigrationPolicy(t_replay_max=2.0))
    done = mgr.migrate("ms2m_individual", holder["pod"], "node1")
    with pytest.raises(MigrationError):
        sim.run(stop_when=done)
    # drain the rest of the heap: the stale deadline fires in here — with
    # the ctx.closed guard it must disarm, not pause the restored source
    stop["flag"] = True
    sim.run(until=sim.now + 60.0)
    assert not holder["pod"].paused
    assert holder["pod"].serving


# -- quiescence audit ---------------------------------------------------------
def test_dangling_waiter_reported_at_quiescence():
    sim = Sim(sanitize=True)
    never = sim.condition("reply")  # nothing will ever trigger this

    def stuck():
        yield never

    sim.process(stuck(), name="stuck-proc")
    sim.run()
    with pytest.raises(SanitizerViolation) as ei:
        sim.assert_quiescent()
    assert ei.value.kind == "dangling"
    assert "stuck-proc" in str(ei.value)
    assert "reply" in str(ei.value)


def test_idle_service_loops_are_allowlisted():
    """Pods parked on queue/wake/stall conditions are the idle steady
    state, not leaks: the default allowlist must pass them."""
    sim = Sim(sanitize=True)
    for suffix in (":not_empty", ":wake", ":stall", ":down"):
        cond = sim.condition(f"pod-0{suffix}")

        def parked(c=cond):
            yield c

        sim.process(parked(), name=f"idle{suffix}")
    sim.run()
    sim.assert_quiescent()  # no raise


def test_inflight_link_flow_reported():
    sim = Sim(sanitize=True)
    link = sim.link(1e6, name="reg-link")

    def mover():
        yield from link.transfer(5e6)  # 5 s of wire time

    sim.process(mover(), name="mover")
    sim.run(until=1.0)  # stop mid-flight
    leaks = sim.sanitizer.dangling()
    assert any("reg-link" in entry for entry in leaks)
    sim.run()  # let it finish: the flow departs
    sim.assert_quiescent()


def test_sanitizer_off_has_no_provenance_and_no_checks():
    # explicit False: overrides a REPRO_SIM_SANITIZE=1 env (the CI
    # sanitized job runs this file with the env set)
    sim = Sim(sanitize=False)
    c = sim.condition("x")
    assert sim.sanitizer is None
    assert not hasattr(c, "created")
    c.trigger("a")
    c.trigger("b")  # no sanitizer, no raise (contract: first value wins)
    assert c.value == "a"
    sim.assert_quiescent()  # no-op
