"""Smoke tests for the operator CLI (repro.launch.migrate) — flag
parsing, listings, exit codes, and short end-to-end runs with the cheap
hash-fold consumer."""
import json

import pytest

from repro.launch.migrate import main


def test_list_strategies_prints_registry(capsys):
    assert main(["--list-strategies"]) == 0
    out = capsys.readouterr().out
    for name in ("stop_and_copy", "ms2m_individual", "ms2m_cutoff",
                 "ms2m_statefulset", "ms2m_precopy", "ms2m_adaptive"):
        assert name in out
    assert "wants_cutoff" in out  # control-plane flags are shown


def test_list_topologies_prints_presets(capsys):
    assert main(["--list-topologies"]) == 0
    out = capsys.readouterr().out
    for name in ("flat", "two_zone", "edge_wan"):
        assert name in out


@pytest.mark.parametrize("argv", [
    ["--no-such-flag"],
    ["--strategy", "not_a_strategy"],
    ["--topology", "not_a_topology"],
    ["--compression", "not_a_codec"],
    ["--strat", "ms2m_individual"],       # abbreviations are disabled
])
def test_bad_flags_exit_2(argv):
    with pytest.raises(SystemExit) as ei:
        main(argv)
    assert ei.value.code == 2


def test_run_hash_consumer_default_strategy(capsys, tmp_path):
    rc = main(["--hash-consumer", "--rate", "6",
               "--registry", str(tmp_path / "reg")])
    out = capsys.readouterr().out
    assert rc == 0
    row = json.loads(out[:out.rindex("}") + 1])
    assert row["strategy"] == "ms2m_individual"
    assert row["verified"] is True
    assert row["attempts"] == 1
    assert "[migrate] downtime=" in out


@pytest.mark.parametrize("strategy,extra", [
    ("stop_and_copy", []),
    ("ms2m_cutoff", ["--t-replay-max", "30"]),
    ("ms2m_precopy", ["--compression", "int8"]),
    ("ms2m_statefulset", ["--topology", "two_zone"]),
])
def test_strategy_topology_compression_combinations(capsys, tmp_path,
                                                    strategy, extra):
    rc = main(["--hash-consumer", "--rate", "6", "--strategy", strategy,
               "--registry", str(tmp_path / "reg")] + extra)
    out = capsys.readouterr().out
    assert rc == 0
    row = json.loads(out[:out.rindex("}") + 1])
    assert row["strategy"] == strategy and row["verified"] is True


def test_events_flag_prints_trace(capsys, tmp_path):
    rc = main(["--hash-consumer", "--rate", "6", "--events",
               "--registry", str(tmp_path / "reg")])
    out = capsys.readouterr().out
    assert rc == 0
    assert '"kind": "phase"' in out
    assert '"kind": "migration_end"' in out


def test_fault_flag_recovers_via_retry(capsys, tmp_path):
    rc = main(["--hash-consumer", "--rate", "6",
               "--fault", "node_flap@30,node=node1,duration=60",
               "--max-attempts", "3", "--retry-backoff", "1",
               "--registry", str(tmp_path / "reg")])
    out = capsys.readouterr().out
    assert rc == 0
    row = json.loads(out[:out.rindex("}") + 1])
    assert row["verified"] is True and row["attempts"] >= 2


def test_fault_flag_exhausted_retries_reports_failure(capsys, tmp_path):
    rc = main(["--hash-consumer", "--rate", "6",
               "--fault", "registry_outage@10.5,duration=500",
               "--max-attempts", "2",
               "--registry", str(tmp_path / "reg")])
    out = capsys.readouterr().out
    assert rc == 1
    row = json.loads(out[:out.rindex("}") + 1])
    assert row["failed"] is True and row["attempts"] == 2
    assert row["rolled_back"] is True and row["source_serving"] is True
    assert "FAILED after 2 attempt(s)" in out


def test_bad_fault_spec_is_a_clear_error(tmp_path):
    with pytest.raises(ValueError, match="fault spec"):
        main(["--hash-consumer", "--fault", "bogus",
              "--registry", str(tmp_path / "reg")])


def test_list_strategies_includes_serving_handoff(capsys):
    assert main(["--list-strategies"]) == 0
    assert "serving_handoff" in capsys.readouterr().out


def test_serving_workload_handoff(capsys, tmp_path):
    rc = main(["--workload", "serving", "--hash-consumer", "--rate", "8",
               "--strategy", "serving_handoff",
               "--registry", str(tmp_path / "reg")])
    out = capsys.readouterr().out
    assert rc == 0
    row = json.loads(out[:out.rindex("}") + 1])
    assert row["strategy"] == "serving_handoff"
    assert row["exactly_once"] is True
    assert row["state_verified"] is True
    assert row["lost"] == 0
    assert row["latency"]["p99"] is not None
    assert "[migrate] p50=" in out


def test_serving_workload_baseline_scheme(capsys, tmp_path):
    rc = main(["--workload", "serving", "--hash-consumer", "--rate", "8",
               "--strategy", "ms2m_statefulset",
               "--registry", str(tmp_path / "reg")])
    out = capsys.readouterr().out
    assert rc == 0
    row = json.loads(out[:out.rindex("}") + 1])
    assert row["exactly_once"] is True and row["state_verified"] is True
