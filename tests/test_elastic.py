"""Elastic scale-out of a partitioned stateful service (paper §III-C):
moved buckets stay exact; untouched buckets never pause."""
import numpy as np
import pytest

from repro.cluster.cluster import Cluster
from repro.core.elastic import PartitionedService, bucket_of


def test_bucket_router_stable():
    assert bucket_of(42, 64) == bucket_of(42, 64)
    assert 0 <= bucket_of(12345, 64) < 64


def test_scale_out_preserves_all_bucket_states(tmp_path):
    rng = np.random.default_rng(0)
    cluster = Cluster(str(tmp_path), num_nodes=3)
    sim = cluster.sim
    svc = PartitionedService(cluster, "orders", num_buckets=32,
                             num_instances=2)
    sim.process(svc.boot())
    published = []  # (queue_msg_id, key, token) in fold order per bucket

    def producer():
        while sim.now < 120.0:
            yield float(rng.exponential(0.1))  # ~10 msg/s
            key = int(rng.integers(0, 1000))
            token = int(rng.integers(0, 997))
            msg = svc.publish(key, token)
            published.append((msg.msg_id, key, token))

    sim.process(producer())
    sim.run(until=20.0)

    n_before = [w.n_processed for w in svc.workers]
    done = sim.process(svc.scale_out("node2"))
    sim.run(stop_when=done)
    sim.run(until=sim.now + 30.0)

    # service kept flowing on donors during the operation
    assert all(w.n_processed > n for w, n in zip(svc.workers[:2], n_before))
    # ownership covers all buckets exactly once; instance 2 owns ~1/3
    owners = list(svc.ownership.values())
    assert sorted(set(owners)) == [0, 1, 2]
    assert owners.count(2) == pytest.approx(32 // 3, abs=2)

    # drain and verify: per-bucket digests equal the reference fold
    sim.run(until=150.0)
    ref = svc.reference_fold(published)
    for b in range(32):
        owner = svc.ownership[b]
        got = svc.workers[owner].digests.get(b)
        assert got is not None, f"bucket {b} lost"
        assert np.uint64(got) == ref[b], f"bucket {b} state diverged"
