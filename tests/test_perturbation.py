"""Virtual-time schedule perturbation: kernel-level permutation
semantics, and the bit-identity gate on flat-topology migration
timelines (the full 5-seed CI sweep is the slow-marked test)."""
import pytest

from repro.analysis.perturb import (canon, perturb_regressions,
                                    regression_row, tiebreak)
from repro.cluster.sim import Sim, _mix64


def _tie_order(tiebreak_seed, n=6):
    """Fire n processes at the same instant; return completion order."""
    sim = Sim(tiebreak_seed=tiebreak_seed)
    log = []

    def proc(tag):
        yield 1.0
        log.append(tag)

    for i in range(n):
        sim.process(proc(i), name=f"p{i}")
    sim.run()
    return log


def test_mix64_is_bijective_per_seed():
    for seed in (0, 1, 7):
        outs = {_mix64(i, seed) for i in range(20_000)}
        assert len(outs) == 20_000


def test_tiebreak_permutes_equal_time_events_only():
    base = _tie_order(None)
    assert base == list(range(6))  # unperturbed: submission order
    orders = {tuple(_tie_order(s)) for s in range(8)}
    assert len(orders) > 1  # the seeds actually permute tie order
    for order in orders:
        assert sorted(order) == list(range(6))  # same events, same time


def test_tiebreak_is_deterministic_per_seed():
    assert _tie_order(3) == _tie_order(3)


def test_distinct_timestamps_never_reorder():
    sim = Sim(tiebreak_seed=5)
    log = []

    def proc(tag, delay):
        yield delay
        log.append(tag)

    for i, delay in enumerate([0.3, 0.1, 0.2]):
        sim.process(proc(i, delay))
    sim.run()
    assert log == [1, 2, 0]  # strictly by virtual time


def test_tiebreak_env_var_plumbs_into_nested_sims(monkeypatch):
    with tiebreak(42):
        assert Sim().tiebreak_seed == 42
    assert Sim().tiebreak_seed is None


def test_flat_regression_row_bit_identical_under_one_seed():
    """Fast slice of the CI gate: one strategy, one tie-break seed."""
    base = canon(regression_row("ms2m_individual"))
    perturbed = canon(regression_row("ms2m_individual", tiebreak_seed=3))
    assert perturbed == base


@pytest.mark.slow
def test_flat_regression_timelines_bit_identical_across_5_seeds():
    """The full acceptance gate: every strategy's flat-topology timeline
    is bit-identical across all 5 tie-break perturbation seeds."""
    report = perturb_regressions((1, 2, 3, 4, 5))
    assert report["ok"], report


@pytest.mark.slow
def test_chaos_invariant_holds_under_perturbation():
    from repro.analysis.perturb import perturb_chaos

    report = perturb_chaos((1, 2, 3), chaos_seeds=(10_000,))
    assert report["ok"], report
